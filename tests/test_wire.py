"""Wire subsystem: codec round-trip bounds, Pallas pack/unpack parity,
frame protocol, Eq. 3 adaptation, and end-to-end generation through
quantized frames."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chunking import optimal_chunk_size
from repro.kernels import (
    dequantize_op,
    dequantize_ref,
    dequantize_unpack,
    quantize_op,
    quantize_ref,
    quantize_pack,
)
from repro.wire import (
    CODECS,
    Frame,
    decode_hidden,
    encode_hidden,
    get_codec,
    iter_frames,
)
from conftest import reduced_model


# ---------------------------------------------------------------- codecs

def _rows(t=17, d=64, seed=0):
    return np.random.default_rng(seed).normal(size=(t, d)).astype(np.float32)


def test_bytes_per_token_accounting():
    d = 4096
    assert get_codec("fp16").bytes_per_token(d) == 2 * d          # 8 KiB anchor
    assert get_codec("bf16-trunc").bytes_per_token(d) == 2 * d
    assert get_codec("int8").bytes_per_token(d) == d + 4
    assert get_codec("int4").bytes_per_token(d) == d / 2 + 4


@pytest.mark.parametrize("name", sorted(CODECS))
def test_payload_size_matches_accounting(name):
    x = _rows()
    codec = get_codec(name)
    assert len(codec.encode(x)) == int(x.shape[0] * codec.bytes_per_token(x.shape[1]))


def test_codec_roundtrip_error_bounds():
    x = _rows(t=23, d=128, seed=1)
    absmax = np.abs(x).max(axis=-1, keepdims=True)

    err16 = np.abs(get_codec("fp16").roundtrip(x) - x)
    assert (err16 <= np.abs(x) * 2.0**-10 + 1e-7).all()           # fp16 rounding

    errbf = np.abs(get_codec("bf16-trunc").roundtrip(x) - x)
    assert (errbf <= np.abs(x) * 2.0**-7 + 1e-7).all()            # 8-bit mantissa trunc

    err8 = np.abs(get_codec("int8").roundtrip(x) - x)
    assert (err8 <= absmax / 127.0 * 0.5001 + 1e-7).all()         # half a quant step

    err4 = np.abs(get_codec("int4").roundtrip(x) - x)
    assert (err4 <= absmax / 7.0 * 0.5001 + 1e-7).all()
    # fidelity ordering within each family (bf16 vs int8 depends on row stats)
    assert err4.max() > err8.max()
    assert errbf.max() > err16.max()


def test_codec_degenerate_rows():
    """All-zero rows survive absmax quantization (scale fallback)."""
    x = np.zeros((3, 32), np.float32)
    x[1] = _rows(1, 32)[0]
    for name in ("int8", "int4"):
        y = get_codec(name).roundtrip(x)
        assert np.all(y[0] == 0) and np.all(y[2] == 0)
        assert np.abs(y[1] - x[1]).max() < np.abs(x[1]).max()


# ------------------------------------------------------------- framing

def test_frame_roundtrip_and_stream():
    codec = get_codec("int8")
    x = _rows(t=9, d=48, seed=2)
    up = encode_hidden(codec, x, req_id=7, offset=120, kind="prefill")
    down = encode_hidden(get_codec("fp16"), x[:3], req_id=8, offset=0,
                         kind="deep", want_deep=False)
    frames = list(iter_frames(up + down))
    assert len(frames) == 2
    f0, f1 = frames
    assert (f0.req_id, f0.offset, f0.kind_name, f0.n_tokens) == (7, 120, "prefill", 9)
    assert f0.want_deep and not f1.want_deep
    assert f1.kind_name == "deep" and f1.codec.name == "fp16"
    assert np.allclose(decode_hidden(f0, 48), codec.roundtrip(x))
    # single-frame strict parse rejects trailing bytes
    with pytest.raises(ValueError):
        Frame.from_bytes(up + down)
    with pytest.raises(ValueError):
        Frame.from_bytes(up[:10])


# ------------------------------------------------- kernel parity (interpret)

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("shape", [(13, 64), (256, 128), (1, 256), (40, 384)])
def test_pallas_quantize_matches_ref(bits, shape):
    x = jnp.asarray(_rows(*shape, seed=sum(shape) + bits))
    pk, sk = quantize_pack(x, bits=bits, bt=16, interpret=True)
    pr, sr = quantize_ref(x, bits=bits)
    assert pk.dtype == jnp.int8 and pk.shape == pr.shape
    assert np.array_equal(np.asarray(pk), np.asarray(pr))
    assert np.allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    dk = dequantize_unpack(pk, sk, bits=bits, bt=16, interpret=True)
    dr = dequantize_ref(pr, sr, bits=bits)
    assert dk.shape == x.shape
    assert np.allclose(np.asarray(dk), np.asarray(dr), rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("bits", [8, 4])
def test_pallas_pack_matches_codec_bytes(bits):
    """The accelerator pack and the host codec produce the same wire bytes."""
    x = _rows(t=11, d=96, seed=bits)
    codec = get_codec("int8" if bits == 8 else "int4")
    payload = codec.encode(x)
    scales = np.frombuffer(payload, "<f4", count=11)
    body = np.frombuffer(payload, np.int8, offset=4 * 11).reshape(11, -1)
    pk, sk = quantize_pack(jnp.asarray(x), bits=bits, interpret=True)
    # scales may differ by 1 ulp across compilers; packed values by at most
    # one quantization step at rounding boundaries
    assert np.allclose(scales, np.asarray(sk).ravel(), rtol=1e-6)
    assert np.abs(body.astype(np.int32) - np.asarray(pk, np.int32)).max() <= 1
    # and the decoded rows agree to within one scale quantum
    dec = codec.decode(payload, 11, 96)
    dk = np.asarray(dequantize_unpack(pk, sk, bits=bits, interpret=True))
    assert np.abs(dec - dk).max() <= np.asarray(sk).max() + 1e-7


def test_quantize_op_dispatch():
    """ops-level dispatch: reference and interpret paths agree (CPU)."""
    x = jnp.asarray(_rows(t=8, d=64, seed=9))
    for bits in (8, 4):
        p1, s1 = quantize_op(x, bits=bits, impl="reference")
        p2, s2 = quantize_op(x, bits=bits, impl="interpret")
        assert np.array_equal(np.asarray(p1), np.asarray(p2))
        d1 = dequantize_op(p1, s1, bits=bits, impl="reference")
        d2 = dequantize_op(p2, s2, bits=bits, impl="interpret")
        assert np.allclose(np.asarray(d1), np.asarray(d2), rtol=1e-6, atol=1e-7)


# -------------------------------------------------------- Eq. 3 adaptation

def test_optimal_chunk_grows_on_thinner_wire():
    g = lambda t: 0.05 + 2e-4 * t
    kw = dict(prompt_len=2048, beta_up=5e6, g=g, mu=64, pipeline_len=4)
    chunks = {
        name: optimal_chunk_size(
            hidden_bytes_per_token=get_codec(name).bytes_per_token(4096), **kw
        )
        for name in ("fp16", "int8", "int4")
    }
    assert chunks["fp16"] <= chunks["int8"] <= chunks["int4"]
    assert chunks["int4"] >= 2 * chunks["fp16"]


# ------------------------------------------------------- engine via frames

@pytest.fixture(scope="module")
def setup():
    from repro.core import split_model

    cfg, model, params = reduced_model("internlm2-1.8b")
    return cfg, split_model(cfg, params)


def _prefill_through_engine(cfg, sp, codec_name, plen=24, chunk=8):
    from repro.serving import CloudEngine
    from repro.wire import encode_hidden as enc

    codec = get_codec(codec_name)
    eng = CloudEngine(sp, n_slots=2, max_len=64, max_batch_tokens=16,
                      wire_codec=codec_name)
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, plen))[None]
    sh, _, _ = sp.input_model.apply(sp.input_params, toks, return_hidden=True)
    sh = np.asarray(sh[0], np.float32)
    assert eng.add_request(0, plen + 8)
    out = []
    for off in range(0, plen, chunk):
        eng.submit_frame(enc(codec, sh[off:off + chunk], req_id=0,
                             offset=off, kind="prefill"))
        for r in eng.drain():
            frame = Frame.from_bytes(eng.encode_result(r))
            assert (frame.req_id, frame.offset) == (0, r.offset)
            out.append(decode_hidden(frame, cfg.d_model))
    return np.concatenate(out, 0), sh


def test_engine_frames_match_direct_path(setup):
    """fp16 frames reproduce the bare-array engine path; int8 stays within
    quantization error; int4 degrades monotonically."""
    cfg, sp = setup
    deep16, sh = _prefill_through_engine(cfg, sp, "fp16")
    ref, _, _ = sp.middle_model.apply(
        sp.middle_params, None, inputs_embeds=jnp.asarray(sh)[None],
        return_hidden=True,
    )
    ref = np.asarray(ref[0])
    scale = np.abs(ref).max()
    assert np.abs(deep16 - ref).max() < 2e-2 * scale              # fp16 wire ≈ exact

    deep8, _ = _prefill_through_engine(cfg, sp, "int8")
    err8 = np.abs(deep8 - ref).max()
    assert err8 < 0.15 * scale

    deep4, _ = _prefill_through_engine(cfg, sp, "int4")
    err4 = np.abs(deep4 - ref).max()
    assert err8 < err4 < 0.8 * scale


def test_engine_rejects_deep_frames(setup):
    cfg, sp = setup
    from repro.serving import CloudEngine

    eng = CloudEngine(sp, n_slots=2, max_len=64)
    data = encode_hidden(get_codec("fp16"), _rows(2, cfg.d_model),
                         req_id=0, offset=0, kind="deep")
    with pytest.raises(ValueError):
        eng.submit_frame(data)


# --------------------------------------- fleet: accept-rate vs codec

def _fleet(codec, n=80, backend=None):
    from repro.data import SPECBENCH, sample_workload
    from repro.serving import run_fleet

    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=n, rate_per_s=6)
    return run_fleet("hat", reqs, rng=np.random.default_rng(1),
                     wire_codec=codec,
                     overrides=dict(uplink_bps=5e6, downlink_bps=10e6))


def test_fleet_int8_cuts_ttft_with_bounded_accept_delta():
    """Acceptance anchor: ≥25% TTFT cut at 5 MB/s; accept-rate penalty stays
    within the calibrated band."""
    m16 = _fleet("fp16")
    m8 = _fleet("int8")
    s16, s8 = m16.summary(), m8.summary()
    assert s8["ttft_mean_ms"] < 0.75 * s16["ttft_mean_ms"]
    delta = s16["accept_length"] - s8["accept_length"]
    assert -0.05 <= delta <= 0.4
    # Eq. 3 picks chunks at least as large on the thinner wire
    c16 = np.mean([max(r.chunk_sizes) for r in m16.requests if r.chunk_sizes])
    c8 = np.mean([max(r.chunk_sizes) for r in m8.requests if r.chunk_sizes])
    assert c8 >= c16 - 1


# ---------------------- end-to-end generation through quantized frames

@pytest.fixture(scope="module")
def trained():
    """Small trained HAT system (teacher + distilled adapter) so greedy
    token streams are stable under quantization noise."""
    from repro.configs import get_config
    from repro.core import init_adapter, make_distill_step, split_model
    from repro.data import markov_corpus, token_batches
    from repro.models import Model
    from repro.training import AdamW, train_loop

    cfg = get_config("internlm2-1.8b").reduced()
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(rng, cfg.vocab_size, 12_000)
    params, _ = train_loop(model, params, AdamW(lr=3e-3),
                           token_batches(rng, corpus, 8, 32),
                           max_steps=50, log_every=0)
    split = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    opt = AdamW(lr=1e-3)
    step = make_distill_step(split, model, params, opt)
    ost = opt.init(adapter)
    for i, b in zip(range(60), token_batches(rng, corpus, 8, 32)):
        adapter, ost, _ = step(adapter, ost, jnp.asarray(b["tokens"][:, :32]))
    return cfg, split, adapter, corpus


def test_generation_through_int8_matches_fp16_stream(trained):
    """End-to-end: the int8 wire's accepted-token stream tracks the fp16
    path within the expected acceptance delta (real quantization error,
    no statistical penalty)."""
    from repro.data import RequestSpec
    from repro.serving import RealBackend, run_fleet

    cfg, split, adapter, corpus = trained
    reqs = [
        RequestSpec(req_id=i, device_id=0, arrival_s=2.0 * i, prompt_len=24,
                    max_new_tokens=16, prompt=corpus[200 * i:200 * i + 24]
                    .astype(np.int32))
        for i in range(3)
    ]
    streams, accepts = {}, {}
    for codec in ("fp16", "int8"):
        m = run_fleet(
            "hat", reqs, rng=np.random.default_rng(3), n_devices=1,
            wire_codec=codec, overrides={"d_model": cfg.d_model},
            backend=RealBackend(split, adapter_params=adapter, max_len=256,
                                wire_codec=codec),
        )
        assert m.summary()["n"] == len(reqs)
        streams[codec] = {r.req_id: r.generated for r in m.requests}
        accepts[codec] = m.summary()["accept_length"]

    total = agree = 0
    for rid in streams["fp16"]:
        a, b = streams["fp16"][rid], streams["int8"][rid]
        assert len(a) == len(b) == 16
        agree += sum(x == y for x, y in zip(a, b))
        total += len(a)
    assert agree / total >= 0.7, f"int8 stream diverged: {agree}/{total}"
    # quantization may cost some speculation efficiency but not break it
    assert accepts["int8"] >= 1.0
    assert abs(accepts["fp16"] - accepts["int8"]) <= 0.8
