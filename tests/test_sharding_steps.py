"""Sharding rules + step builders on a single-device mesh (the 512-device
production meshes are exercised by repro.launch.dryrun, which owns the
device-count override)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import (
    constrain,
    make_rules,
    param_shardings,
    spec_for_name,
    use_rules,
)
from repro.models import Model


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_constrain_identity_without_rules():
    x = jnp.ones((2, 3))
    assert constrain(x, "act_btd") is x


def test_rules_table(mesh):
    r = make_rules(mesh)
    assert r.spec("attn_q") == P(None, "model")
    assert r.spec("kv_cache") == P("data", None, "model", None)
    assert spec_for_name(r, "*attn_q") == P(None, None, "model")
    r2 = make_rules(mesh, fsdp_params=True)
    assert r2.spec("mlp_in") == P("data", "model")


def test_param_shardings_cover_model(mesh):
    cfg = get_config("internlm2-1.8b").reduced()
    m = Model(cfg)
    spec = m.param_spec()
    shardings = param_shardings(make_rules(mesh), spec)
    ap = m.abstract_params()
    assert jax.tree.structure(shardings) == jax.tree.structure(ap)
    for s, a in zip(jax.tree.leaves(shardings), jax.tree.leaves(ap)):
        assert len(s.spec) <= len(a.shape)


@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_build_step_lowers_on_tiny_mesh(mesh, shape_name):
    """Full pipeline minus scale: build + lower the production step for a
    REDUCED config with tiny stand-in shapes on the 1x1 mesh."""
    import dataclasses

    from repro.configs.base import InputShape
    from repro.launch.steps import build_step, lower_step

    cfg = get_config("internlm2-1.8b").reduced()
    shape = SHAPES[shape_name]
    small = InputShape(shape.name, seq_len=32, global_batch=2, kind=shape.kind)
    built = build_step(cfg, small, mesh, dtype=jnp.float32)
    lowered = lower_step(built, mesh)
    compiled = lowered.compile()
    assert compiled.cost_analysis() is not None


def test_hat_verify_step_builds(mesh):
    from repro.configs.base import InputShape
    from repro.launch.steps import build_step, lower_step

    cfg = get_config("internlm2-1.8b").reduced()
    small = InputShape("decode_32k", seq_len=64, global_batch=2, kind="decode")
    built = build_step(cfg, small, mesh, kind="hat_verify", dtype=jnp.float32)
    compiled = lower_step(built, mesh).compile()
    # output: deep hidden [B, T_verify, d]
    assert built.meta["verify_T"] == 8
