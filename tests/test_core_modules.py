"""Chunking (Eq. 3), monitoring (Eqs. 1-2), parallel drafting (Eq. 6)."""
import numpy as np
import pytest

from repro.core import (
    DelayPredictor,
    Ewma,
    StateMonitor,
    chunk_offsets,
    chunk_prompt,
    optimal_chunk_size,
    parallel_draft_steps,
)


def test_ewma_matches_eq1():
    e = Ewma(alpha=0.8)
    e.update(10.0)
    assert e.get() == 10.0
    e.update(20.0)
    assert abs(e.get() - (0.8 * 10 + 0.2 * 20)) < 1e-9


def test_delay_predictor_learns_linear():
    g = DelayPredictor(alpha=0.5)
    for t in (64, 256, 1024, 4096):
        for _ in range(5):
            g.update(t, 0.01 + t * 1e-5)
    for t in (128, 512, 2048):
        pred = g.predict(t)
        true = 0.01 + t * 1e-5
        assert abs(pred - true) / true < 0.6
    # extrapolates monotonically
    assert g.predict(8192) > g.predict(4096)


def test_chunk_prompt_invariants():
    for plen in (1, 17, 128, 1000):
        for cs in (1, 32, 128, 2048):
            chunks = chunk_prompt(plen, cs)
            assert sum(chunks) == plen
            assert all(0 < c <= cs for c in chunks)
            offs = chunk_offsets(chunks)
            assert offs[0] == 0 and offs[-1] + chunks[-1] == plen


def _g_affine(base, slope):
    return lambda t: base + slope * t


def test_eq3_balances_upload_and_compute():
    A, beta, P = 8192.0, 8e6, 4
    g = _g_affine(0.04, 1.4e-4)
    x = optimal_chunk_size(
        prompt_len=2048, hidden_bytes_per_token=A, beta_up=beta,
        g=g, mu=64, pipeline_len=P, align=1, min_chunk=1,
    )
    lhs = x * A / beta
    rhs = (g(64) + g(64 + x)) / P
    assert abs(lhs - rhs) / rhs < 0.1      # crossing found


def test_eq3_monotonicity():
    g = _g_affine(0.04, 1.4e-4)
    common = dict(prompt_len=4096, hidden_bytes_per_token=8192.0,
                  g=g, mu=64, pipeline_len=4)
    fast = optimal_chunk_size(beta_up=20e6, **common)
    slow = optimal_chunk_size(beta_up=2e6, **common)
    assert fast >= slow                    # faster uplink -> larger chunks
    p1 = optimal_chunk_size(beta_up=8e6, pipeline_len=1,
                            **{k: v for k, v in common.items() if k != "pipeline_len"})
    p8 = optimal_chunk_size(beta_up=8e6, pipeline_len=8,
                            **{k: v for k, v in common.items() if k != "pipeline_len"})
    assert p1 >= p8                        # deeper pipeline -> smaller chunks OK


def test_eq3_cold_start_and_clamping():
    x = optimal_chunk_size(
        prompt_len=1000, hidden_bytes_per_token=8192, beta_up=8e6,
        g=lambda t: 0.0, mu=0,
    )
    assert x == 128                        # cold-start fallback
    x2 = optimal_chunk_size(
        prompt_len=40, hidden_bytes_per_token=8192, beta_up=8e6,
        g=_g_affine(0.04, 1e-4), mu=0,
    )
    assert x2 <= 40 + 8                    # never (much) beyond the prompt


def test_eq6_parallel_draft_steps():
    n = parallel_draft_steps(
        draft_len=4, hidden_bytes_per_token=8192, beta_up=8e6,
        beta_down=12e6, g_mu=0.045, gamma=0.01,
    )
    rt = 4 * 8192 / 8e6 + 0.045 + 4 * 8192 / 12e6
    assert n == int(rt / 0.01)
    assert parallel_draft_steps(
        draft_len=4, hidden_bytes_per_token=8192, beta_up=8e6,
        beta_down=12e6, g_mu=0.045, gamma=1e9,
    ) == 0


def test_state_monitor_roundtrip():
    m = StateMonitor(alpha=0.8)
    for i in range(20):
        m.record_batch(100 + i, 0.02 + i * 1e-4)
        m.record_device(3, gamma=0.005, beta_up=8e6, beta_down=12e6)
    assert 100 < m.mu.get() < 120
    assert m.predict_delay() > 0
    d = m.device(3)
    assert abs(d.beta_up.get() - 8e6) < 1.0


def test_delay_predictor_negative_slope_clamps():
    """Regression: noisy bins giving the tail a negative slope must not
    extrapolate to negative delays (would break the Eq. 3 cost compare)."""
    g = DelayPredictor(alpha=0.5)
    g.update(64, 0.05)
    g.update(256, 0.01)                   # downward tail
    far = g.predict(1 << 18)
    assert far >= 0.0
    # interpolation between populated bins is clamped too
    g2 = DelayPredictor(alpha=0.5)
    g2.update(64, 0.0)
    g2.update(4096, 0.0)
    assert g2.predict(512) >= 0.0


def test_delay_predictor_edge_bins():
    g = DelayPredictor()
    assert g.predict(100) == 0.0          # empty: no observations yet
    g.update(128, 0.02)                   # single populated bin
    assert g.predict(128) == pytest.approx(0.02)
    assert g.predict(256) >= 0.02         # scales up beyond the sample
    assert g.predict(1) == pytest.approx(0.02)   # never scales below it
    assert g.predict(0) == g.predict(1)   # tokens clamped to >= 1


def test_state_monitor_device_state_creation():
    m = StateMonitor()
    assert m.devices == {}
    d = m.device(7)                       # lazily created, then cached
    assert m.device(7) is d
    assert d.gamma.get(123.0) == 123.0    # untouched EWMA falls to default
    m.record_device(7, beta_up=5e6)       # partial update touches one EWMA
    assert d.beta_up.get() == 5e6
    assert d.beta_down.value is None
