"""Concurrent EngineRuntime: cross-session continuous batching.

Load-bearing guarantees:
  * token parity — the concurrent scheduler emits byte-identical token
    streams to the sequential path (same seeds), including SSM archs whose
    speculative rollback goes through the engine's slot snapshot/restore
    while other sessions' jobs ride in the same batched steps;
  * the concurrent mode actually batches across requests (fewer, fuller
    engine steps than sequential);
  * t_step bucketing bounds the jit compile count at O(log max_len) across
    mixed chunk/strip widths;
  * engine utilization is observable from FleetMetrics.summary.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.core import init_adapter, split_model
from repro.data import RequestSpec
from repro.serving import (
    CloudEngine,
    CloudServer,
    DeviceClient,
    EngineJob,
    EngineRuntime,
    LoopbackTransport,
    ServeConfig,
)
from repro.serving.engine import bucket_t_step
from repro.serving.scheduling import budgeted_admission


@pytest.fixture(scope="module")
def setup():
    cfg, model, params = reduced_model("internlm2-1.8b")
    return cfg, model, params, split_model(cfg, params)


def _specs(cfg, rng, n, *, prompt_len=16, new=6, stagger=0.1):
    return [
        RequestSpec(
            req_id=i, device_id=i, arrival_s=stagger * i,
            prompt_len=prompt_len, max_new_tokens=new,
            prompt=rng.integers(3, cfg.vocab_size, prompt_len).astype(np.int32),
        )
        for i in range(n)
    ]


def _runtimes(config, sp, *, adapter=None, n_slots=4, max_len=64, seed=6):
    mk = lambda conc: EngineRuntime(
        config, sp, adapter_params=adapter,
        rng=np.random.default_rng(seed), n_slots=n_slots, max_len=max_len,
        concurrent=conc,
    )
    return mk(False), mk(True)


# ---------------------------------------------------------------------------
# token parity
# ---------------------------------------------------------------------------


def test_concurrent_matches_sequential_u_shape(setup):
    cfg, model, params, sp = setup
    rng = np.random.default_rng(5)
    reqs = _specs(cfg, rng, 4)
    config = ServeConfig.u_shape(n_devices=4, wire_codec="fp16",
                                 dynamic_chunks=False, fixed_chunk=8)
    seq, con = _runtimes(config, sp)
    m_seq, m_con = seq.serve(reqs), con.serve(reqs)
    toks = lambda m: {r.req_id: r.generated for r in m.requests}
    assert toks(m_seq) == toks(m_con)
    # the concurrent scheduler actually batches across requests
    s_seq, s_con = m_seq.summary(), m_con.summary()
    assert s_con["cloud_steps"] < s_seq["cloud_steps"]
    assert (s_con["batch_tokens_per_step_mean"]
            > s_seq["batch_tokens_per_step_mean"])
    assert s_con["ttft_mean_ms"] > 0 and s_con["tbt_mean_ms"] > 0
    assert s_con["cloud_delay_mean_ms"] > 0


def test_concurrent_matches_sequential_hat_drafting(setup):
    """Speculative decoding under interleaving: drafts and verify strips of
    4 sessions share engine steps, token streams stay identical."""
    cfg, model, params, sp = setup
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(3)
    reqs = _specs(cfg, rng, 4, new=8)
    config = ServeConfig.hat(n_devices=4, wire_codec="fp16",
                             dynamic_chunks=False, fixed_chunk=8)
    seq, con = _runtimes(config, sp, adapter=adapter)
    m_seq, m_con = seq.serve(reqs), con.serve(reqs)
    toks = lambda m: {r.req_id: r.generated for r in m.requests}
    assert toks(m_seq) == toks(m_con)
    acc = lambda m: {r.req_id: (r.rounds, r.drafted, r.accepted)
                     for r in m.requests}
    assert acc(m_seq) == acc(m_con)


def test_concurrent_ssm_rollback_under_interleaving():
    """SSM middles carry state, not positions: rejection rollback must
    restore exactly the right slot while other sessions' jobs keep flowing
    through the same batched steps (and padded rows must not advance any
    slot's recurrent state)."""
    cfg, model, params = reduced_model("xlstm-350m")
    sp = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    reqs = _specs(cfg, rng, 3, new=6)
    config = ServeConfig.hat(n_devices=3, wire_codec="fp32",
                             dynamic_chunks=False, fixed_chunk=8)
    seq, con = _runtimes(config, sp, adapter=adapter, n_slots=3, max_len=128)
    m_seq, m_con = seq.serve(reqs), con.serve(reqs)
    toks = lambda m: {r.req_id: r.generated for r in m.requests}
    assert toks(m_seq) == toks(m_con)


def test_concurrent_more_sessions_than_slots(setup):
    """Sessions beyond the slot pool wait in the admission queue and still
    finish with the right tokens once slots free up."""
    cfg, model, params, sp = setup
    rng = np.random.default_rng(9)
    reqs = _specs(cfg, rng, 5)
    config = ServeConfig.u_shape(n_devices=5, wire_codec="fp16",
                                 dynamic_chunks=False, fixed_chunk=8)
    seq, con = _runtimes(config, sp, n_slots=2)
    m_seq, m_con = seq.serve(reqs), con.serve(reqs)
    toks = lambda m: {r.req_id: r.generated for r in m.requests}
    assert toks(m_seq) == toks(m_con)
    assert len(m_con.requests) == 5
    assert con.server.engine.kv.active == 0            # all slots released
    assert con.server.engine.kv.peak_active <= 2


# ---------------------------------------------------------------------------
# recompile regression: t_step bucketing
# ---------------------------------------------------------------------------


def test_bucket_t_step():
    assert [bucket_t_step(t, 64) for t in (1, 2, 3, 5, 8, 9, 33, 64)] == \
        [1, 2, 4, 8, 8, 16, 64, 64]
    assert bucket_t_step(40, 48) == 48                  # clamped to max_len


def test_recompile_bounded_across_mixed_widths(setup):
    """Mixed chunk widths compile at most log2(max_len)+1 step variants."""
    cfg, model, params, sp = setup
    max_len = 64
    eng = CloudEngine(sp, n_slots=2, max_len=max_len, max_batch_tokens=64)
    assert eng.add_request(0, max_len)
    rng = np.random.default_rng(0)
    off = 0
    for t in (1, 2, 3, 4, 5, 6, 7, 9, 11, 13):          # 10 distinct widths
        sh = rng.normal(size=(t, cfg.d_model)).astype(np.float32)
        eng.submit(EngineJob(0, sh, off, "prefill"))
        eng.drain()
        off += t
    bound = int(math.log2(max_len)) + 1
    assert eng.jit_compiles <= bound, (eng.jit_compiles, bound)
    # sanity: distinct widths far exceed the compiled variants
    assert eng.steps == 10


def test_bucket_padding_at_slot_capacity_keeps_last_rows_exact(setup):
    """A job ending exactly at max_len gets bucketed pad rows whose cache
    positions fall PAST the slot — those writes must be dropped, not
    clamped onto the slot's real last row (regression: duplicate scatter
    indices at S-1 nondeterministically clobbered the last token's KV)."""
    cfg, model, params, sp = setup
    max_len = 16
    eng = CloudEngine(sp, n_slots=2, max_len=max_len, max_batch_tokens=64)
    assert eng.add_request(0, max_len)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, max_len))[None]
    sh, _, _ = sp.input_model.apply(sp.input_params, toks, return_hidden=True)
    sh = np.asarray(sh[0], np.float32)
    ref, _, _ = sp.middle_model.apply(
        sp.middle_params, None, inputs_embeds=jnp.asarray(sh)[None],
        return_hidden=True,
    )
    # prefill [0, 13), then a 3-row verify strip ending at max_len: its
    # bucketed width (4) spans position 16, one past the slot
    eng.submit(EngineJob(0, sh[:13], 0, "prefill"))
    eng.drain()
    eng.submit(EngineJob(0, sh[13:16], 13, "verify"))
    (res,) = eng.drain()
    err = float(np.abs(res.deep - np.asarray(ref[0][13:16])).max())
    assert err < 1e-3, err


def test_summary_reports_engine_utilization(setup):
    cfg, model, params, sp = setup
    rng = np.random.default_rng(11)
    reqs = _specs(cfg, rng, 2, new=4)
    config = ServeConfig.u_shape(n_devices=2, wire_codec="fp16",
                                 dynamic_chunks=False, fixed_chunk=8)
    m = EngineRuntime(config, sp, rng=np.random.default_rng(1), n_slots=2,
                      max_len=64).serve(reqs)
    s = m.summary()
    assert s["cloud_steps"] == len(m.cloud_batch_tokens) > 0
    assert s["batch_tokens_per_step_mean"] > 0
    assert s["engine_jit_compiles"] >= 1
    # simulator runs report the same keys (engine compiles = 0)
    from repro.serving import SimulatorRuntime
    from repro.data import SPECBENCH, sample_workload

    w = sample_workload(SPECBENCH, np.random.default_rng(0), n_requests=10,
                        rate_per_s=8)
    s2 = SimulatorRuntime(ServeConfig.hat(),
                          rng=np.random.default_rng(1)).serve(w).summary()
    assert s2["cloud_steps"] > 0
    assert s2["batch_tokens_per_step_mean"] > 0
    assert s2["engine_jit_compiles"] == 0


# ---------------------------------------------------------------------------
# shared admission policy
# ---------------------------------------------------------------------------


def test_budgeted_admission_semantics():
    class J:
        def __init__(self, kind, tokens, slot=0):
            self.kind, self.tokens, self.slot = kind, tokens, slot

        def __repr__(self):
            return f"J({self.kind},{self.tokens},s{self.slot})"

    jobs = [J("prefill", 100, 0), J("verify", 4, 1), J("verify", 3, 2),
            J("prefill", 300, 3)]
    chosen, rest = budgeted_admission(
        jobs, 64, tokens_of=lambda j: j.tokens, slot_of=lambda j: j.slot
    )
    # verifies first, oversized prefills wait their turn
    assert [j.kind for j in chosen] == ["verify", "verify"]
    assert [j.tokens for j in rest] == [100, 300]       # original order kept
    # an oversized job alone is admitted, not starved
    chosen2, rest2 = budgeted_admission(
        rest, 64, tokens_of=lambda j: j.tokens, slot_of=lambda j: j.slot
    )
    assert [j.tokens for j in chosen2] == [100]
    # one job per slot
    jobs3 = [J("prefill", 4, 0), J("prefill", 4, 0)]
    chosen3, rest3 = budgeted_admission(
        jobs3, 64, tokens_of=lambda j: j.tokens, slot_of=lambda j: j.slot
    )
    assert len(chosen3) == 1 and len(rest3) == 1
    # no budget = batch everything (naive baselines)
    chosen4, rest4 = budgeted_admission(
        jobs, None, tokens_of=lambda j: j.tokens
    )
    assert len(chosen4) == 4 and not rest4
