"""U-Medusa baseline pieces + the roofline HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.medusa import (
    accept_best_path,
    build_tree_paths,
    init_medusa,
    medusa_logits,
    medusa_loss,
)
from repro.roofline.hlo_parse import analyze_hlo
from conftest import reduced_model

# ---------------------------------------------------------------- medusa ----


def test_medusa_heads_shapes(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    mp, _ = init_medusa(cfg, key)
    deep = jax.random.normal(key, (2, 5, cfg.d_model))
    lg = medusa_logits(mp, deep)
    assert lg.shape == (4, 2, 5, cfg.vocab_size)
    loss = medusa_loss(mp, deep, jax.random.randint(key, (2, 5), 0, cfg.vocab_size))
    assert np.isfinite(float(loss))


def test_medusa_tree_paths(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    mp, _ = init_medusa(cfg, key)
    paths = build_tree_paths(mp, jax.random.normal(key, (cfg.d_model,)), tree_size=8)
    assert len(paths) == 8
    assert all(len(p) == 4 for p in paths)


def test_accept_best_path():
    paths = [[1, 2, 3, 4], [1, 5, 6, 7], [9, 9, 9, 9]]
    rows = [np.array([1, 5, 0, 0, 0]), np.array([1, 5, 6, 0, 0]),
            np.array([1, 0, 0, 0, 0])]
    pi, n, bonus = accept_best_path(paths, rows)
    assert (pi, n) == (1, 3) and bonus == 0


# --------------------------------------------------------------- roofline ---

_SYNTH = """\
HloModule test, is_scheduled=true

%loop_body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %mm = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%mm), replica_groups={}, to_apply=%add_comp
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%iv, %ar)
}

%loop_cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], f32[8,16]{1,0}) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%iv, %n), direction=LT
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %init = (s32[], f32[8,16]{1,0}) tuple(%arg)
  %w = (s32[], f32[8,16]{1,0}) while(%init), condition=%loop_cond, body=%loop_body, backend_config={"known_trip_count":{"n":"10"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_hlo_parser_loop_multipliers():
    c = analyze_hlo(_SYNTH)
    # dot: 2*8*16*16 flops, x10 trips
    assert c.flops == pytest.approx(2 * 8 * 16 * 16 * 10)
    # all-reduce result f32[8,16] at native-bf16 width (2B) x10
    assert c.collective_bytes == pytest.approx(8 * 16 * 2 * 10)
    assert c.max_trip == 10 and c.n_while == 1
    assert c.hbm_bytes > 0


def test_hlo_parser_on_real_dryrun_artifact():
    import glob, os

    files = sorted(glob.glob("reports/dryrun/*.hlo.txt"))
    if not files:
        pytest.skip("no dry-run HLO artifacts saved")
    # prefer a heavyweight artifact; small decode steps have tiny flops
    pick = next((f for f in files if "train" in f or "prefill" in f), files[0])
    c = analyze_hlo(open(pick).read())
    assert c.flops > 1e6 and c.hbm_bytes > 1e6
    assert c.max_trip > 1                     # layer scan detected
