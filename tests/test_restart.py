"""Cloud-restart survival: whole-pool checkpoint/restore and the
slot-lifecycle bugs it exposed.

Layered cheapest-first, like ``test_net.py``/``test_chaos.py``:

* ``save_state``/``load_state`` units — structure-preserving snapshots,
  every corruption mode a typed :class:`CheckpointError`, no JAX model;
* :class:`SlotKVManager` typed-error + accounting regressions (the bare
  ``assert`` removal satellite: denial must stay loud under ``python -O``
  and release must return every charged block);
* launcher supervision units with fake processes — ``_wait_workers`` must
  tolerate a supervised (planned or policy-allowed) cloud death instead
  of reaping healthy workers;
* :class:`CloudEngine` whole-pool checkpoint round trips (dense KV and
  SSM archs): restore into a fresh engine, byte-identical step results vs
  the uninterrupted engine, corrupt checkpoints surface typed errors;
* the tentpole over real sockets: a :class:`CloudService` checkpoints
  mid-generation, *dies*, and a fresh service restores on the same port
  under a bumped restart epoch — the device resumes, replays the frames
  the checkpoint rolled back, and finishes with tokens byte-identical to
  an uninterrupted loopback run; sessions absent from the checkpoint
  surface as :class:`SessionLostError`; a resume arriving exactly at the
  grace boundary deterministically beats the sweep.
"""
import os
import socket
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from conftest import reduced_model
from repro.net.errors import SessionLostError, TransportError
from repro.serving.kv_manager import (
    KVAccountingError,
    KVAdmissionError,
    KVBudget,
    KVError,
    SlotKVManager,
)
from repro.training.checkpoint import CheckpointError, load_state, save_state

ARCH = "internlm2-1.8b"
SSM_ARCH = "xlstm-350m"


# ---------------------------------------------------------------------------
# save_state / load_state: structure-preserving snapshots
# ---------------------------------------------------------------------------


def _sample_state():
    return {
        "ints": {1: 2, 3: -4},
        "strs": {"a": "b", "empty": ""},
        "mixed": [True, False, None, 1.5, "x", (1, 2, "three")],
        "blob": b"\x00\x01\xffbytes",
        "arr": np.arange(12, dtype=np.float32).reshape(3, 4),
        "nested": {"deep": [{"k": np.array([1, 2], np.int64)}]},
    }


def _assert_state_equal(a, b):
    if isinstance(a, dict):
        assert set(a) == set(b)
        for k in a:
            _assert_state_equal(a[k], b[k])
    elif isinstance(a, (list, tuple)):
        assert type(a) is type(b) and len(a) == len(b)
        for x, y in zip(a, b):
            _assert_state_equal(x, y)
    elif isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        assert np.asarray(a).dtype == np.asarray(b).dtype
        assert np.array_equal(np.asarray(a), np.asarray(b))
    else:
        assert a == b and type(a) is type(b)


def test_state_roundtrip_preserves_structure(tmp_path):
    path = tmp_path / "ckpt"
    save_state(str(path), _sample_state(), extra={"kind": "test"})
    state, extra = load_state(str(path))
    _assert_state_equal(state, _sample_state())
    assert extra == {"kind": "test"}
    # int keys stay ints, str keys stay strs (JSON would collapse both)
    assert set(state["ints"]) == {1, 3}
    assert isinstance(state["blob"], bytes)
    assert isinstance(state["mixed"][5], tuple)


def test_state_overwrite_is_atomic_and_clean(tmp_path):
    path = tmp_path / "ckpt"
    save_state(str(path), {"v": 1})
    save_state(str(path), {"v": 2})
    state, _ = load_state(str(path))
    assert state == {"v": 2}
    assert not os.path.exists(str(path) + ".tmp")
    assert not os.path.exists(str(path) + ".old")


def test_missing_checkpoint_is_typed(tmp_path):
    with pytest.raises(CheckpointError, match="unreadable"):
        load_state(str(tmp_path / "nope"))


def test_truncated_arrays_is_typed_not_a_hang(tmp_path):
    path = tmp_path / "ckpt"
    save_state(str(path), _sample_state())
    npz = path / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[: npz.stat().st_size // 2])
    with pytest.raises(CheckpointError, match="corrupt"):
        load_state(str(path))


def test_garbage_manifest_is_typed(tmp_path):
    path = tmp_path / "ckpt"
    save_state(str(path), {"v": 1})
    (path / "manifest.json").write_text("{not json")
    with pytest.raises(CheckpointError, match="unreadable"):
        load_state(str(path))


def test_wrong_format_is_typed(tmp_path):
    path = tmp_path / "ckpt"
    save_state(str(path), {"v": 1})
    (path / "manifest.json").write_text('{"format": "v999"}')
    with pytest.raises(CheckpointError, match="format"):
        load_state(str(path))


# ---------------------------------------------------------------------------
# SlotKVManager: typed errors + accounting (the bare-assert satellite)
# ---------------------------------------------------------------------------


def _kv(n_slots=2, blocks=4, block_tokens=16, max_len=64):
    return SlotKVManager(n_slots, max_len,
                         KVBudget(block_tokens=block_tokens,
                                  total_blocks=blocks))


def test_admit_duplicate_is_accounting_error():
    kv = _kv()
    kv.admit(1, 16)
    with pytest.raises(KVAccountingError, match="already admitted"):
        kv.admit(1, 16)


def test_admit_denied_is_typed_not_silent():
    kv = _kv(n_slots=1)
    kv.admit(1, 16)
    with pytest.raises(KVAdmissionError, match="denied"):
        kv.admit(2, 16)                       # no free slot
    kv2 = _kv(n_slots=4, blocks=1)
    kv2.admit(1, 16)
    with pytest.raises(KVAdmissionError):
        kv2.admit(2, 16)                      # no free blocks
    assert isinstance(KVAdmissionError("x"), KVError)  # one catchable base


def test_extend_over_budget_returns_false_and_charges_nothing():
    kv = _kv(n_slots=2, blocks=2, block_tokens=16)
    kv.admit(1, 16)                           # 1 block
    kv.admit(2, 16)                           # 1 block: budget full
    used = kv.budget.used_blocks
    assert kv.extend(1, 40) is False          # would need 3 blocks total
    assert kv.budget.used_blocks == used      # denial charged nothing
    assert kv.extend(1, 16) is True           # within the existing charge


def test_extend_and_release_unadmitted_are_accounting_errors():
    kv = _kv()
    with pytest.raises(KVAccountingError, match="unadmitted"):
        kv.extend(9, 16)
    with pytest.raises(KVAccountingError, match="unadmitted"):
        kv.release(9)


def test_release_returns_blocks_and_slot():
    kv = _kv(n_slots=2, blocks=4, block_tokens=16)
    slot = kv.admit(1, 33)                    # 3 blocks
    assert kv.budget.used_blocks == 3
    kv.extend(1, 60)                          # grows to 4 blocks
    assert kv.budget.used_blocks == 4
    kv.release(1)
    assert kv.budget.used_blocks == 0         # every charged block returned
    assert sorted(kv.free_slots) == [0, 1]
    assert slot in kv.free_slots
    assert kv.active == 0


def test_grow_shrink_is_accounting_error():
    kv = _kv(n_slots=4)
    with pytest.raises(KVAccountingError, match="shrink"):
        kv.grow(2)
    kv.grow(8)
    assert kv.n_slots == 8 and len(kv.free_slots) == 8


def test_kv_state_dict_roundtrip_and_validation():
    kv = _kv(n_slots=3, blocks=8, block_tokens=16)
    kv.admit(1, 33)
    kv.admit(2, 16)
    kv.extend(2, 20)
    state = kv.state_dict()

    fresh = _kv(n_slots=3, blocks=8, block_tokens=16)
    fresh.load_state_dict(state)
    assert fresh.slot_of == kv.slot_of
    assert fresh.budget.used_blocks == kv.budget.used_blocks
    fresh.release(1)                          # books stay workable
    assert fresh.budget.used_blocks == kv.budget.used_blocks - 3

    bad = dict(state, used_blocks=99)
    with pytest.raises(KVAccountingError, match="sum"):
        _kv(3, 8).load_state_dict(bad)
    bad = dict(state, slot_of={1: 0, 2: 0},
               blocks_of={1: 3, 2: 2}, used_blocks=5, free_slots=[1, 2])
    with pytest.raises(KVAccountingError, match="double-books"):
        _kv(3, 8).load_state_dict(bad)
    bad = dict(state, free_slots=[])
    with pytest.raises(KVAccountingError, match="partition"):
        _kv(3, 8).load_state_dict(bad)


# ---------------------------------------------------------------------------
# launcher supervision: restart-aware _wait_workers (fake processes)
# ---------------------------------------------------------------------------


class _FakeProc:
    """poll() pops scripted return codes; the last one is sticky."""

    def __init__(self, *rcs):
        self._rcs = list(rcs)
        self.returncode = None

    def poll(self):
        self.returncode = (self._rcs.pop(0) if len(self._rcs) > 1
                           else self._rcs[0])
        return self.returncode

    def wait(self, timeout=None):
        return self.returncode


def _fake_cloud(proc, tmp_path, port=5555):
    return SimpleNamespace(proc=proc, log_path=tmp_path / "cloud.log",
                           port=port)


def _supervisor(plan, cloud, tmp_path, respawn=None):
    from repro.net.launcher import _CloudSupervisor

    return _CloudSupervisor(plan, cloud, tmp_path / "ckpt",
                            respawn or (lambda port, log: None))


def test_wait_workers_still_fails_fast_without_supervisor(tmp_path):
    from repro.net.launcher import _wait_workers

    cloud = _fake_cloud(_FakeProc(None, 1), tmp_path)
    with pytest.raises(TransportError, match="cloud service exited"):
        _wait_workers([_FakeProc(None)], cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01)


def test_wait_workers_tolerates_planned_restart(tmp_path):
    """A dead cloud with the supervisor mid-restart must NOT reap the
    workers; once the successor is installed the run completes."""
    from repro.net.launcher import CloudRestartPlan, _wait_workers

    dying = _fake_cloud(_FakeProc(None, -9), tmp_path)
    sup = _supervisor(CloudRestartPlan(), dying, tmp_path)
    sup.restarting.set()                     # planned kill in flight
    worker = _FakeProc(None, None, None, None, 0)

    def _finish_restart():
        time.sleep(0.05)
        sup.current = _fake_cloud(_FakeProc(None), tmp_path)
        sup.restarting.clear()

    t = threading.Thread(target=_finish_restart)
    t.start()
    _wait_workers([worker], dying, timeout_s=5.0, wd=tmp_path,
                  poll_s=0.01, supervisor=sup)       # no raise
    t.join()


def test_wait_workers_unexpected_death_policy_fail(tmp_path):
    from repro.net.launcher import CloudRestartPlan, _wait_workers

    cloud = _fake_cloud(_FakeProc(None, 1), tmp_path)
    sup = _supervisor(CloudRestartPlan(on_unexpected_death="fail"),
                      cloud, tmp_path)
    with pytest.raises(TransportError, match="unexpectedly"):
        _wait_workers([_FakeProc(None)], cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01, supervisor=sup)


def test_wait_workers_unexpected_death_policy_restart(tmp_path):
    from repro.net.launcher import CloudRestartPlan, _wait_workers

    respawned = []

    def respawn(port, log_name):
        c = _fake_cloud(_FakeProc(None), tmp_path, port=port)
        respawned.append((port, log_name))
        return c

    cloud = _fake_cloud(_FakeProc(None, 1), tmp_path, port=7777)
    sup = _supervisor(
        CloudRestartPlan(on_unexpected_death="restart", max_restarts=1),
        cloud, tmp_path, respawn)
    worker = _FakeProc(None, None, 0)
    _wait_workers([worker], cloud, timeout_s=5.0, wd=tmp_path,
                  poll_s=0.01, supervisor=sup)       # no raise
    assert respawned == [(7777, "cloud1.log")]
    assert sup.restarts == 1
    # the budget is spent: a second death fails the run
    sup.current.proc = _FakeProc(1)
    with pytest.raises(TransportError, match="unexpectedly"):
        _wait_workers([_FakeProc(None)], cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01, supervisor=sup)


def test_wait_workers_surfaces_restart_failure(tmp_path):
    from repro.net.launcher import CloudRestartPlan, _wait_workers

    cloud = _fake_cloud(_FakeProc(None), tmp_path)
    sup = _supervisor(CloudRestartPlan(), cloud, tmp_path)
    sup.error = TransportError("no checkpoint appeared")
    with pytest.raises(TransportError, match="cloud restart failed"):
        _wait_workers([_FakeProc(None)], cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01, supervisor=sup)


def test_supervisor_waits_for_checkpoint_after_trigger(tmp_path):
    """The two-generation rule: the supervisor only kills once a manifest
    strictly newer than one already newer than the trigger exists."""
    from repro.net.launcher import CloudRestartPlan

    ckpt = tmp_path / "ckpt"
    ckpt.mkdir()
    manifest = ckpt / "manifest.json"
    manifest.write_text("{}")
    os.utime(manifest, (50.0, 50.0))         # stale: before the trigger

    sup = _supervisor(CloudRestartPlan(checkpoint_wait_s=5.0),
                      _fake_cloud(_FakeProc(None), tmp_path), tmp_path)
    sup.checkpoint = ckpt
    done = threading.Event()

    def _wait():
        sup._wait_checkpoint_after(100.0)
        done.set()

    t = threading.Thread(target=_wait)
    t.start()
    time.sleep(0.15)
    assert not done.is_set()                 # stale manifest: still waiting
    os.utime(manifest, (101.0, 101.0))       # generation 1 (after trigger)
    time.sleep(0.15)
    assert not done.is_set()                 # one generation is not enough
    os.utime(manifest, (102.0, 102.0))       # generation 2
    t.join(timeout=5.0)
    assert done.is_set()

    sup2 = _supervisor(CloudRestartPlan(checkpoint_wait_s=0.2),
                       _fake_cloud(_FakeProc(None), tmp_path), tmp_path)
    sup2.checkpoint = tmp_path / "never"
    with pytest.raises(TransportError, match="no checkpoint"):
        sup2._wait_checkpoint_after(100.0)


def test_chaos_kill_trigger_fires_once_at_thresholds():
    from repro.net.chaos import ChaosProxy, seeded_kill_after_frames

    assert seeded_kill_after_frames(7, 32) == seeded_kill_after_frames(7, 32)
    assert seeded_kill_after_frames(7, 32) == 32 * seeded_kill_after_frames(7)

    fired = []
    proxy = ChaosProxy("127.0.0.1", 1, kill_after_open_oks=2,
                       kill_after_up_frames=3,
                       on_cloud_kill=lambda: fired.append(1))
    proxy.open_oks_seen, proxy.up_frames_seen = 2, 2
    proxy._maybe_fire_kill()
    assert fired == []                       # frame threshold not met
    proxy.up_frames_seen = 3
    proxy._maybe_fire_kill()
    proxy._maybe_fire_kill()                 # idempotent: fires exactly once
    assert fired == [1]
    assert [f["kind"] for f in proxy.faults] == ["cloud_kill"]


# ---------------------------------------------------------------------------
# CloudEngine whole-pool checkpoint round trips (dense + SSM archs)
# ---------------------------------------------------------------------------


def _build_engine(arch, n_slots=2, max_len=64):
    from repro.core import split_model
    from repro.serving.engine import CloudEngine

    cfg, _, params = reduced_model(arch)
    split = split_model(cfg, params)
    return cfg, CloudEngine(split, n_slots=n_slots, max_len=max_len,
                            max_batch_tokens=128)


def _job(cfg, req_id, t, offset, kind="prefill", want_deep=True, seed=0):
    from repro.serving.engine import EngineJob

    rng = np.random.default_rng(seed * 1000 + offset)
    hidden = rng.standard_normal((t, cfg.d_model)).astype(np.float32)
    return EngineJob(req_id, hidden, offset, kind, want_deep=want_deep)


def _deep(results):
    return {r.req_id: np.asarray(r.deep) for r in results if r.deep is not None}


@pytest.mark.parametrize("arch", [ARCH, SSM_ARCH])
def test_engine_checkpoint_roundtrip_byte_identical(arch, tmp_path):
    """checkpoint -> save_state -> load_state -> restore into a FRESH
    engine, then step both engines identically: byte-identical outputs
    for a dense-KV arch and an SSM arch (recurrent state in the pool)."""
    cfg, eng = _build_engine(arch)
    eng.add_request(1, 48)
    eng.add_request(2, 48)
    eng.submit(_job(cfg, 1, 16, 0, seed=1))
    eng.submit(_job(cfg, 2, 16, 0, seed=2))
    eng.step()
    eng.submit(_job(cfg, 1, 4, 16, kind="verify", seed=3))
    eng.step()

    path = tmp_path / "engine_ckpt"
    save_state(str(path), eng.checkpoint_state())
    state, _ = load_state(str(path))
    _, fresh = _build_engine(arch)
    fresh.restore_state(state)
    assert set(fresh.kv.slot_of) == {1, 2}

    # identical continuations must produce byte-identical deep states
    for e in (eng, fresh):
        e.submit(_job(cfg, 1, 4, 20, kind="verify", seed=4))
        e.submit(_job(cfg, 2, 4, 16, kind="verify", seed=5))
    a, b = _deep(eng.step()), _deep(fresh.step())
    assert set(a) == set(b) == {1, 2}
    for rid in a:
        assert a[rid].tobytes() == b[rid].tobytes(), f"req {rid} diverged"


def test_engine_restore_validates_shapes_and_grows():
    cfg, eng = _build_engine(ARCH, n_slots=2)
    eng.add_request(1, 32)
    state = eng.checkpoint_state()

    _, bigger = _build_engine(ARCH, n_slots=4)
    with pytest.raises(CheckpointError, match="refusing to shrink"):
        bigger.restore_state(state)

    _, small = _build_engine(ARCH, n_slots=1)
    small.restore_state(state)               # grows 1 -> 2 to fit
    assert small.n_slots == 2
    assert 1 in small.kv.slot_of

    with pytest.raises(CheckpointError, match="malformed"):
        _build_engine(ARCH)[1].restore_state({"config": {}})
    wrong = dict(state)
    wrong["config"] = dict(state["config"], d_model=cfg.d_model + 1)
    with pytest.raises(CheckpointError, match="does not match"):
        _build_engine(ARCH)[1].restore_state(wrong)


def test_engine_submit_unadmitted_is_typed():
    """The bare ``assert`` in submit() is gone: unadmitted submissions
    raise the typed accounting error even under ``python -O``."""
    cfg, eng = _build_engine(ARCH)
    with pytest.raises(KVAccountingError, match="unadmitted"):
        eng.submit(_job(cfg, 999, 4, 0))


def test_corrupt_engine_checkpoint_is_typed_end_to_end(tmp_path):
    cfg, eng = _build_engine(ARCH)
    eng.add_request(1, 32)
    path = tmp_path / "ckpt"
    save_state(str(path), eng.checkpoint_state())
    npz = path / "arrays.npz"
    npz.write_bytes(npz.read_bytes()[:200])  # truncate mid-archive
    with pytest.raises(CheckpointError):
        load_state(str(path))


# ---------------------------------------------------------------------------
# the tentpole: cross-process-style restart over real sockets
# ---------------------------------------------------------------------------


def _build_service(split, *, port=0, grace_s=30.0, checkpoint=None):
    from repro.net.service import CloudService
    from repro.serving import CloudServer

    server = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server, port=port, grace_s=grace_s,
                       checkpoint_path=checkpoint)
    host, svc_port = svc.start()
    return svc, host, svc_port


def _make_client(split, transport):
    from repro.serving import DeviceClient

    return DeviceClient(split, transport, sd=None, max_len=64,
                        wire_codec="fp16", fixed_chunk=16,
                        dynamic_chunks=False)


def _loopback_tokens(split, prompt, n, req_id):
    from repro.serving import CloudServer, LoopbackTransport

    server = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    client = _make_client(split, LoopbackTransport(server))
    return list(client.generate(prompt, max_new_tokens=n, req_id=req_id))


def test_session_survives_cloud_process_restart(tmp_path):
    """Mid-generation checkpoint -> service dies -> a FRESH service
    restores on the same port under a bumped restart epoch -> the device
    resumes, replays the rolled-back uplink frames, and the full token
    stream is byte-identical to an uninterrupted loopback run."""
    from repro.core import split_model
    from repro.net.transport import SocketTransport

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    prompt = np.random.default_rng(11).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    want = _loopback_tokens(split, prompt, 6, req_id=71)
    assert len(want) == 6

    ckpt = str(tmp_path / "svc_ckpt")
    svc1, host, port = _build_service(split, checkpoint=ckpt)
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=60.0)
    client = _make_client(split, t)
    gen = client.generate(prompt, max_new_tokens=6, req_id=71)
    got = [next(gen) for _ in range(3)]
    svc1.checkpoint()                        # state at 3 tokens
    got.append(next(gen))                    # progress PAST the checkpoint
    svc1.stop()                              # the process "dies"

    svc2, _, _ = _build_service(split, port=port, checkpoint=ckpt)
    try:
        restored = svc2.restore()
        assert restored == 1
        assert svc2.restart_epoch == 1
        got.extend(gen)                      # device reconnects + resumes
    finally:
        t.shutdown()
        svc2.stop()
    assert got == want                       # byte-identical across the death
    assert t.reconnects >= 1
    assert t.cloud_restarts_seen == 1        # the bumped epoch was noticed
    assert t.replayed_frames >= 1            # the rolled-back suffix was re-sent
    assert svc2.sessions_restored == 1
    assert svc2.dup_frames_dropped >= 0      # replays are watermark-deduped


def test_session_absent_from_checkpoint_is_lost_not_hung(tmp_path):
    """A fresh cloud process with NO checkpoint for the session refuses
    the resume: the device surfaces the typed SessionLostError (with the
    partial tokens at the client layer) instead of hanging."""
    from repro.core import split_model
    from repro.net.transport import SocketTransport

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    prompt = np.random.default_rng(12).integers(
        3, cfg.vocab_size, 16).astype(np.int32)

    svc1, host, port = _build_service(split)
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=30.0)
    client = _make_client(split, t)
    gen = client.generate(prompt, max_new_tokens=6, req_id=81)
    partial = [next(gen) for _ in range(2)]
    svc1.stop()                              # dies with NO checkpoint

    svc2, _, _ = _build_service(split, port=port)   # fresh: knows nothing
    try:
        with pytest.raises(SessionLostError) as ei:
            list(gen)
        assert ei.value.req_id == 81
        assert "checkpoint" in str(ei.value) or "unknown" in str(ei.value)
        assert len(partial) == 2             # partial progress kept
    finally:
        t.shutdown()
        svc2.stop()


def test_resume_at_exact_grace_boundary_beats_the_sweep():
    """The sweep-race satellite: expiry is strictly-greater-than-grace
    and decided under one lock, so a resume landing exactly at the
    boundary deterministically wins no matter how often the sweep runs."""
    from repro.core import split_model
    from repro.net.transport import SocketTransport

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    prompt = np.random.default_rng(13).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    want = _loopback_tokens(split, prompt, 4, req_id=91)

    svc, host, port = _build_service(split, grace_s=5.0)
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=30.0)
    try:
        client = _make_client(split, t)
        gen = client.generate(prompt, max_new_tokens=4, req_id=91)
        got = [next(gen)]
        # hard-drop the connection: the service detaches the session
        t._sock.shutdown(socket.SHUT_RDWR)
        deadline = time.monotonic() + 5.0
        while time.monotonic() < deadline:
            sess = svc._sessions.get(91)
            if sess is not None and sess.detached_at is not None:
                break
            time.sleep(0.01)
        sess = svc._sessions[91]
        assert sess.detached_at is not None
        # keep re-pinning the session a hair inside the grace boundary
        # (pinning *exactly* at it would legitimately expire one clock
        # tick later) and hammer the sweep from another thread the whole
        # time the resume runs; exact-boundary determinism is asserted
        # below against _expired_locked with a pinned ``now``
        stop = threading.Event()

        def _hammer():
            while not stop.is_set():
                with svc._lock:
                    if 91 in svc._sessions:
                        s = svc._sessions[91]
                        if s.detached_at is not None:
                            s.detached_at = (time.monotonic()
                                             - svc.grace_s + 0.5)
                svc._sweep_grace()

        hammer = threading.Thread(target=_hammer)
        hammer.start()
        try:
            got.extend(gen)                  # forces recovery + resume
        finally:
            stop.set()
            hammer.join()
        assert got == want                   # resumed, not expired
        assert t.reconnects >= 1
    finally:
        t.shutdown()
        svc.stop()
    # and strictly PAST the boundary the verdict flips: the sweep wins
    now = time.monotonic()
    from repro.net.service import _NetSession

    boundary = _NetSession(req_id=1, epoch=1, conn=None,
                           detached_at=now - svc.grace_s)
    past = _NetSession(req_id=2, epoch=1, conn=None,
                       detached_at=now - svc.grace_s - 0.5)
    assert not svc._expired_locked(boundary, now)
    assert svc._expired_locked(past, now)


def test_service_checkpoint_persists_wire_state(tmp_path):
    """state_dict -> save_state -> restore carries the per-session wire
    watermarks: up_expected rolls back to the processed watermark and the
    downlink seq/buffer survive byte-for-byte."""
    from repro.core import split_model
    from repro.net.transport import SocketTransport

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    prompt = np.random.default_rng(14).integers(
        3, cfg.vocab_size, 16).astype(np.int32)

    ckpt = str(tmp_path / "ckpt")
    svc1, host, port = _build_service(split, checkpoint=ckpt)
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=30.0)
    try:
        client = _make_client(split, t)
        gen = client.generate(prompt, max_new_tokens=4, req_id=95)
        next(gen)
        sess1 = svc1._sessions[95]
        # quiesce: the pump may still be stepping an uplink frame the
        # client pipelined behind the one that produced token 1 — wait
        # until every accepted frame is processed and emitted before
        # snapshotting the reference wire state
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            with svc1._lock:
                settled = (sess1.up_processed == sess1.up_expected
                           and not svc1.server.engine.queue
                           and not svc1._pump_busy)
            if settled:
                break
            time.sleep(0.01)
        before = (sess1.up_processed, sess1.down_seq,
                  [(s, bytes(d)) for s, d in sess1.down_buffer])
        svc1.checkpoint()
        assert svc1.checkpoints_written == 1
        svc1.stop()

        svc2, _, _ = _build_service(split, port=port, checkpoint=ckpt)
        try:
            svc2.restore()
            sess2 = svc2._sessions[95]
            assert (sess2.up_processed, sess2.down_seq,
                    [(s, bytes(d)) for s, d in sess2.down_buffer]) == before
            assert sess2.up_expected == sess2.up_processed  # rolled back
            assert sess2.detached_at is not None            # fresh grace
            assert svc2.server._processed.get(95) == sess2.up_processed
            gen.close()
        finally:
            svc2.stop()
    finally:
        t.shutdown()
