"""THE serving invariant: chunked prefill + decode through the cache must
equal the full-context forward — for every arch family, any chunking."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED
from conftest import reduced_model

TOL = 3e-4


def _memory_for(cfg, model, params, key, B):
    if cfg.frontend == "vision":
        return jax.random.normal(key, (B, 8, cfg.d_model))
    if cfg.is_encoder_decoder:
        return model.encode(params, jax.random.normal(key, (B, 8, cfg.d_model)))
    return None


@pytest.mark.parametrize("arch", ASSIGNED)
def test_chunked_prefill_matches_full(arch, key):
    cfg, model, params = reduced_model(arch)
    B, T = 2, 24
    tokens = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0, cfg.vocab_size)
    memory = _memory_for(cfg, model, params, key, B)
    full, _, _ = model.apply(params, tokens, memory=memory)

    cache = model.init_cache(params, B, 64, memory=memory)
    outs, off = [], 0
    for chunk in (tokens[:, :5], tokens[:, 5:6], tokens[:, 6:17], tokens[:, 17:]):
        lg, cache, _ = model.apply(params, chunk, cache=cache, offset=off,
                                   memory=memory)
        outs.append(lg)
        off += chunk.shape[1]
    err = float(jnp.max(jnp.abs(full - jnp.concatenate(outs, 1))))
    assert err < TOL, f"{arch}: chunked/full mismatch {err}"


def test_windowed_ring_buffer_long_roll(key):
    """gemma3-style local attention: ring cache (W slots) over a sequence
    several times the window length must match the full windowed forward."""
    cfg, model, params = reduced_model("gemma3-12b")
    W = cfg.pattern[0].window            # 16 in reduced
    B, T = 1, 3 * W + 5
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full, _, _ = model.apply(params, tokens)

    cache = model.init_cache(params, B, W)       # ring allocated at W
    outs, off = [], 0
    step = 7
    while off < T:
        chunk = tokens[:, off : off + step]
        lg, cache, _ = model.apply(params, chunk, cache=cache, offset=off)
        outs.append(lg)
        off += chunk.shape[1]
    err = float(jnp.max(jnp.abs(full - jnp.concatenate(outs, 1))))
    assert err < TOL, f"ring-buffer mismatch {err}"


def test_vector_offsets_match_scalar(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    B, S = 3, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    offs = jnp.array([5, 9, 13])
    refs = []
    for b in range(B):
        c = model.init_cache(params, 1, S)
        _, c, _ = model.apply(params, toks[b : b + 1, : int(offs[b])], cache=c, offset=0)
        lg, _, _ = model.apply(
            params, toks[b : b + 1, int(offs[b]) : int(offs[b]) + 1],
            cache=c, offset=int(offs[b]),
        )
        refs.append(lg[0, -1])
    cache = model.init_cache(params, B, S)
    _, cache, _ = model.apply(params, toks[:, :13], cache=cache, offset=0)
    step_tok = jnp.stack([toks[b, offs[b]] for b in range(B)])[:, None]
    lgv, _, _ = model.apply(params, step_tok, cache=cache, offset=offs)
    err = float(jnp.max(jnp.abs(lgv[:, -1] - jnp.stack(refs))))
    assert err < TOL
