"""Perf-iteration variants must be EXACT vs the baseline implementations
(EXPERIMENTS.md §Perf): chunkwise SSM forms, shard_map MoE, microbatched
train step, cache-native attention layout."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from conftest import reduced_model


@pytest.mark.parametrize("arch", ["xlstm-350m", "zamba2-1.2b"])
def test_chunkwise_ssm_equals_scan(arch, key):
    cfg, model, params = reduced_model(arch)
    toks = jax.random.randint(key, (2, 33), 0, cfg.vocab_size)
    os.environ["REPRO_SSM_CHUNK"] = "0"
    jax.clear_caches()
    base, _, _ = model.apply(params, toks)
    try:
        os.environ["REPRO_SSM_CHUNK"] = "16"
        jax.clear_caches()
        opt, _, _ = model.apply(params, toks)
    finally:
        os.environ["REPRO_SSM_CHUNK"] = "0"
    assert float(jnp.max(jnp.abs(base - opt))) < 5e-4


def test_chunkwise_ssm_cache_continuation(key):
    """Chunked prefill with chunkwise SSM still matches the full forward."""
    cfg, model, params = reduced_model("zamba2-1.2b")
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size)
    try:
        os.environ["REPRO_SSM_CHUNK"] = "8"
        jax.clear_caches()
        full, _, _ = model.apply(params, toks)
        cache = model.init_cache(params, 1, 32)
        outs, off = [], 0
        for ch in (toks[:, :10], toks[:, 10:17], toks[:, 17:]):
            lg, cache, _ = model.apply(params, ch, cache=cache, offset=off)
            outs.append(lg)
            off += ch.shape[1]
        err = float(jnp.max(jnp.abs(full - jnp.concatenate(outs, 1))))
    finally:
        os.environ["REPRO_SSM_CHUNK"] = "0"
        jax.clear_caches()
    assert err < 5e-4


def test_shardmap_moe_equals_pjit(key):
    from repro.distributed.sharding import make_rules, use_rules

    cfg, model, params = reduced_model("dbrx-132b")
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    base, _, aux0 = model.apply(params, toks)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    try:
        os.environ["REPRO_MOE_SHARDMAP"] = "1"
        jax.clear_caches()
        with mesh, use_rules(make_rules(mesh)):
            opt, _, aux1 = model.apply(params, toks)
    finally:
        os.environ["REPRO_MOE_SHARDMAP"] = "0"
        jax.clear_caches()
    assert float(jnp.max(jnp.abs(base - opt))) < 3e-4
    assert float(jnp.abs(aux0 - aux1)) < 1e-4


def test_kv_layout_baseline_switch(key):
    """REPRO_KV_TRANSPOSE=1 (baseline transpose path) must agree with the
    optimized cache-native layout."""
    import subprocess
    import sys

    code = """
import os, jax, jax.numpy as jnp, sys
sys.path.insert(0, "src")
from repro.configs import get_config
from repro.models import Model
cfg = get_config("internlm2-1.8b").reduced()
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
cache = m.init_cache(params, 1, 16)
lg, _, _ = m.apply(params, toks, cache=cache, offset=0)
print(float(jnp.sum(jnp.abs(lg))))
"""
    outs = []
    for env_val in ("0", "1"):
        env = dict(os.environ, REPRO_KV_TRANSPOSE=env_val)
        r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                           text=True, env=env, cwd=os.path.join(os.path.dirname(__file__), ".."))
        assert r.returncode == 0, r.stderr[-500:]
        outs.append(float(r.stdout.strip().splitlines()[-1]))
    assert outs[0] == pytest.approx(outs[1], rel=1e-4)


def test_microbatch_equals_full_batch(key):
    from repro.configs.base import InputShape
    from repro.launch.steps import build_step, make_optimizer

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("internlm2-1.8b").reduced()
    small = InputShape("train_4k", seq_len=32, global_batch=4, kind="train")
    results = {}
    for mb in (None, 2):
        built = build_step(cfg, small, mesh, dtype=jnp.float32, microbatch=mb)
        fn = jax.jit(built.fn, in_shardings=built.in_shardings)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = make_optimizer(cfg)
        toks = jnp.asarray(
            np.asarray(jax.random.randint(key, (4, 32), 0, cfg.vocab_size))
        )
        with mesh:
            p2, _, loss = fn(params, opt.init(params), {"tokens": toks})
        results[mb] = (float(loss), p2)
    assert results[None][0] == pytest.approx(results[2][0], rel=1e-4)
    d = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(results[None][1]), jax.tree.leaves(results[2][1]))
    )
    assert d < 1e-4
