"""Optimizers, checkpointing, tokenizers, workloads."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import (
    CNN_DM,
    SPECBENCH,
    BPETokenizer,
    ByteTokenizer,
    markov_corpus,
    sample_workload,
    token_batches,
)
from repro.training import (
    AdamW,
    Adafactor,
    SGD,
    clip_by_global_norm,
    cosine_schedule,
    load_checkpoint,
    save_checkpoint,
    train_loop,
)
from conftest import reduced_model


def _quadratic_min(opt, steps=400):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(steps):
        grads = jax.grad(loss)(params)
        ups, state = opt.update(grads, state, params)
        params = jax.tree.map(lambda a, u: a + u, params, ups)
    return float(loss(params))


@pytest.mark.parametrize("opt", [
    AdamW(lr=0.1), Adafactor(lr=0.3), SGD(lr=0.1, momentum=0.9),
])
def test_optimizers_minimize(opt):
    assert _quadratic_min(opt) < 0.05


def test_adafactor_factored_state_is_small():
    opt = Adafactor(lr=1e-2, min_dim_size_to_factor=4)
    params = {"w": jnp.zeros((128, 256))}
    st = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(st["f"]))
    assert n_state == 128 + 256              # factored, not 128*256


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(5)) == pytest.approx(0.5)
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-3)


def test_training_reduces_loss(rng, key):
    cfg, model, params = reduced_model("phi4-mini-3.8b")
    corpus = markov_corpus(np.random.default_rng(1), cfg.vocab_size, 12_000)
    params2, res = train_loop(
        model, params, AdamW(lr=3e-3),
        token_batches(np.random.default_rng(2), corpus, 8, 32),
        max_steps=40, log_every=0,
    )
    assert res.losses[-1] < res.losses[0] - 0.5


def test_checkpoint_roundtrip(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, params, step=7)
        restored = load_checkpoint(d, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert jnp.array_equal(a, b)
        from repro.training import checkpoint_step

        assert checkpoint_step(d) == 7


def test_workload_stats_match_table3():
    rng = np.random.default_rng(0)
    for spec, mean, p90 in ((SPECBENCH, 351.2, 891.0), (CNN_DM, 1036.6, 1772.0)):
        reqs = sample_workload(spec, rng, n_requests=4000, rate_per_s=6)
        lens = np.array([r.prompt_len for r in reqs])
        assert abs(lens.mean() - mean) / mean < 0.15
        assert abs(np.percentile(lens, 90) - p90) / p90 < 0.2
        # Poisson arrivals: increasing times
        ts = [r.arrival_s for r in reqs]
        assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_markov_corpus_is_learnable_structure():
    rng = np.random.default_rng(0)
    c = markov_corpus(rng, 256, 5000)
    assert c.min() >= 3 and c.max() < 256
    # strong bigram structure: repeated bigrams far above uniform chance
    bigrams = set(zip(c[:-1], c[1:]))
    assert len(bigrams) < 0.5 * len(c)
