"""End-to-end behaviour: the full HAT system — trained teacher, distilled
adapter, device-cloud fleet with real models — produces exactly the
teacher's greedy outputs while beating the U-shape baseline's latency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import init_adapter, make_distill_step, split_model
from repro.data import RequestSpec, markov_corpus, token_batches
from repro.models import Model
from repro.serving import RealBackend, run_fleet
from repro.training import AdamW, train_loop


@pytest.fixture(scope="module")
def system():
    cfg = get_config("internlm2-1.8b").reduced()
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(rng, cfg.vocab_size, 15_000)
    params, _ = train_loop(model, params, AdamW(lr=3e-3),
                           token_batches(rng, corpus, 8, 32),
                           max_steps=60, log_every=0)
    split = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    opt = AdamW(lr=1e-3)
    step = make_distill_step(split, model, params, opt)
    ost = opt.init(adapter)
    for i, b in zip(range(80), token_batches(rng, corpus, 8, 32)):
        adapter, ost, _ = step(adapter, ost, jnp.asarray(b["tokens"][:, :32]))
    return cfg, model, params, split, adapter, corpus


def _requests(corpus, n=3, gen=16):
    out = []
    for i in range(n):
        s = 200 * i
        out.append(RequestSpec(
            req_id=i, device_id=0, arrival_s=2.0 * i, prompt_len=24,
            max_new_tokens=gen, prompt=corpus[s:s + 24].astype(np.int32),
        ))
    return out


def _greedy(model, params, prompt, n_new):
    cache = model.init_cache(params, 1, 128)
    lg, cache, _ = model.apply(params, jnp.asarray(prompt)[None], cache=cache, offset=0)
    out = [int(lg[0, -1].argmax())]
    off = len(prompt)
    while len(out) < n_new:
        lg, cache, _ = model.apply(params, jnp.asarray([[out[-1]]], jnp.int32),
                                   cache=cache, offset=off)
        off += 1
        out.append(int(lg[0, -1].argmax()))
    return out


def test_hat_system_end_to_end(system):
    cfg, model, params, split, adapter, corpus = system
    reqs = _requests(corpus)
    backend = RealBackend(split, adapter_params=adapter, max_len=256)
    m = run_fleet("hat", reqs, rng=np.random.default_rng(3),
                  hidden_bytes=cfg.d_model * 2, backend=backend, n_devices=1)
    s = m.summary()
    assert s["n"] == len(reqs)
    # LOSSLESS: every request's output equals the teacher's greedy decode
    for r in m.requests:
        ref = _greedy(model, params, r.prompt, r.max_new_tokens)
        assert r.generated == ref, f"req {r.req_id} diverged"
    # the distilled adapter actually speculates (accept > baseline 1.0)
    assert s["accept_length"] > 1.2


def test_hat_faster_than_ushape_same_tokens(system):
    cfg, model, params, split, adapter, corpus = system
    reqs = _requests(corpus, n=3, gen=16)
    hat = run_fleet(
        "hat", reqs, rng=np.random.default_rng(3),
        hidden_bytes=cfg.d_model * 2,
        backend=RealBackend(split, adapter_params=adapter, max_len=256),
        n_devices=1,
    ).summary()
    ush = run_fleet(
        "u-shape", reqs, rng=np.random.default_rng(3),
        hidden_bytes=cfg.d_model * 2,
        backend=RealBackend(split, max_len=256), n_devices=1,
    ).summary()
    assert hat["tbt_mean_ms"] < ush["tbt_mean_ms"]
