"""Config registry: exact assigned specs, param counts, reduced variants."""
import pytest

from repro.configs import ASSIGNED, CONFIGS, SHAPES, get_config, shape_applicable

EXPECTED_SPECS = {
    # arch: (layers, d_model, heads, kv, vocab)
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, 163_840),
    "qwen2-72b": (80, 8192, 64, 8, 152_064),
    "xlstm-350m": (24, 1024, 4, 4, 50_304),
    "llama-3.2-vision-90b": (100, 8192, 64, 8, 128_256),
    "internlm2-1.8b": (24, 2048, 16, 8, 92_544),
    "zamba2-1.2b": (38, 2048, 32, 32, 32_000),
    "dbrx-132b": (40, 6144, 48, 8, 100_352),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 200_064),
    "gemma3-12b": (48, 3840, 16, 8, 262_144),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 256_206),
}

PARAM_RANGES = {  # billions, generous brackets around the nameplate size
    "kimi-k2-1t-a32b": (900, 1150),
    "qwen2-72b": (70, 76),
    "xlstm-350m": (0.3, 0.5),
    "llama-3.2-vision-90b": (85, 96),
    "internlm2-1.8b": (1.6, 2.1),
    "zamba2-1.2b": (0.9, 1.4),
    "dbrx-132b": (125, 138),
    "phi4-mini-3.8b": (3.5, 4.2),
    "gemma3-12b": (11, 13),
    "seamless-m4t-large-v2": (1.6, 2.4),
}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_assigned_spec(arch):
    cfg = get_config(arch)
    L, d, h, kv, v = EXPECTED_SPECS[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.vocab_size == v
    assert len(cfg.layers) == L
    assert cfg.source, "every config must cite its source"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_param_count_matches_nameplate(arch):
    lo, hi = PARAM_RANGES[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 25 <= kimi.active_param_count() / 1e9 <= 40      # "a32b"
    dbrx = get_config("dbrx-132b")
    assert 30 <= dbrx.active_param_count() / 1e9 <= 45


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_constraints(arch):
    r = get_config(arch).reduced()
    assert r.n_layers <= 2 or (arch == "zamba2-1.2b" and r.n_layers <= 6)
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.vocab_size <= 512


def test_qwen2_bias_and_gemma_window():
    assert get_config("qwen2-72b").qkv_bias
    g = get_config("gemma3-12b")
    windows = [l.window for l in g.layers]
    assert windows.count(None) == 8 and windows.count(1024) == 40  # 5:1


def test_long_500k_applicability():
    shape = SHAPES["long_500k"]
    runs = {a for a in ASSIGNED if shape_applicable(get_config(a), shape)[0]}
    assert runs == {"xlstm-350m", "zamba2-1.2b", "gemma3-12b"}
    for a in ASSIGNED:
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]


def test_vicuna_present():
    assert CONFIGS["vicuna-7b"].n_layers == 32
    assert CONFIGS["vicuna-13b"].hat_shallow_layers == 3
