"""Anti-rot checks for the doc set.

Three contracts:

* ``test_protocol_doc_matches_code`` — every protocol constant quoted in
  docs/PROTOCOL.md (message ids, error codes, version, magic, header
  struct, size limit) matches ``repro.net.protocol``, and the doc's
  message/error tables are *complete* — a new ``MSG_*`` without a doc row
  fails here, in the same commit.
* ``test_markdown_links_resolve`` — every relative link (and ``#anchor``)
  in the repo's markdown resolves; rot in moved files or renamed
  headings fails CI, not a reader.
* ``test_public_api_docstrings`` — pydocstyle-lite over the public
  session/network API (``repro.serving.api``, ``repro.net.policy``,
  ``repro.net.chaos``): every public module/class/function/method has a
  docstring (ruff/pydocstyle are not vendored, so this is plain
  ``inspect``).
"""
from __future__ import annotations

import importlib
import inspect
import re
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
PROTOCOL_MD = REPO / "docs" / "PROTOCOL.md"


# ---------------------------------------------------------------------------
# PROTOCOL.md <-> repro.net.protocol
# ---------------------------------------------------------------------------


def test_protocol_doc_matches_code():
    from repro.net import protocol as P

    text = PROTOCOL_MD.read_text()

    # message-id table rows: "|  6 | `MSG_FRAME`   | ..."
    doc_msgs = {
        name: int(num)
        for num, name in re.findall(r"^\|\s*(\d+)\s*\|\s*`(MSG_[A-Z_]+)`",
                                    text, re.M)
    }
    code_msgs = {n: v for n, v in vars(P).items()
                 if n.startswith("MSG_") and isinstance(v, int)}
    assert doc_msgs == code_msgs, (
        "PROTOCOL.md message table out of sync with repro.net.protocol: "
        f"doc-only={sorted(set(doc_msgs) - set(code_msgs))}, "
        f"code-only={sorted(set(code_msgs) - set(doc_msgs))}, "
        f"mismatched={[k for k in set(doc_msgs) & set(code_msgs) if doc_msgs[k] != code_msgs[k]]}"
    )
    # and MSG_NAMES covers exactly the same ids
    assert set(P.MSG_NAMES) == set(code_msgs.values())

    # error-code table rows: "|    3 | `overflow` | ..."
    doc_errs = {
        name: int(num)
        for num, name in re.findall(r"^\|\s*(\d+)\s*\|\s*`([a-z]+)`",
                                    text, re.M)
    }
    code_errs = {name: code for code, name in P.ERR_NAMES.items()}
    assert doc_errs == code_errs, (
        f"PROTOCOL.md error table out of sync: doc={doc_errs}, "
        f"code={code_errs}"
    )

    # scalar constants quoted in prose
    assert f"PROTO_VERSION = {P.PROTO_VERSION}`" in text.replace("`= ", "= ") \
        or f"`PROTO_VERSION = {P.PROTO_VERSION}`" in text, \
        "PROTOCOL.md must quote the current PROTO_VERSION"
    assert P.MAGIC.decode() in text and 'b"HN"' in text
    mib = P.MAX_MESSAGE_BYTES // (1024 * 1024)
    assert f"{mib} MiB" in text, "MAX_MESSAGE_BYTES changed; update the doc"

    # every struct format used by the codec appears verbatim in the doc
    struct_fmts = {
        s.format if isinstance(s.format, str) else s.format.decode()
        for n, s in vars(P).items()
        if n.startswith("_") and hasattr(s, "format") and hasattr(s, "pack")
    }
    missing = {f for f in struct_fmts if f"`{f}`" not in text}
    assert not missing, f"struct formats undocumented in PROTOCOL.md: {missing}"

    # header size claim
    assert f"({P._HEADER.size} bytes)" in text


# ---------------------------------------------------------------------------
# markdown link rot
# ---------------------------------------------------------------------------

_LINK_RE = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
_CODE_FENCE_RE = re.compile(r"```.*?```", re.S)


def _github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation, spaces->dashes."""
    heading = re.sub(r"[`*_]", "", heading)
    heading = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", heading)  # md links
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug, flags=re.UNICODE)
    return slug.replace(" ", "-")


def _markdown_files():
    files = sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))
    assert files, "no markdown files found — wrong repo root?"
    return files


def _anchors_of(path: Path) -> set:
    text = _CODE_FENCE_RE.sub("", path.read_text())
    slugs = set()
    counts = {}
    for h in _HEADING_RE.findall(text):
        s = _github_slug(h)
        n = counts.get(s, 0)
        counts[s] = n + 1
        slugs.add(s if n == 0 else f"{s}-{n}")
    return slugs


@pytest.mark.parametrize("md", _markdown_files(), ids=lambda p: p.name)
def test_markdown_links_resolve(md):
    text = _CODE_FENCE_RE.sub("", md.read_text())
    problems = []
    for target in _LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = md if not path_part else (md.parent / path_part).resolve()
        if path_part and not dest.exists():
            problems.append(f"{target}: no such file {dest}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in _anchors_of(dest):
                problems.append(f"{target}: no heading for #{anchor} "
                                f"in {dest.name}")
    assert not problems, f"{md.name}: " + "; ".join(problems)


# ---------------------------------------------------------------------------
# public-API docstrings
# ---------------------------------------------------------------------------

DOC_MODULES = ["repro.serving.api", "repro.net.policy", "repro.net.chaos"]


def _missing_docstrings(modname: str):
    mod = importlib.import_module(modname)
    missing = []
    if not (mod.__doc__ or "").strip():
        missing.append(modname)
    for cname, obj in vars(mod).items():
        if cname.startswith("_") or getattr(obj, "__module__", None) != modname:
            continue
        if inspect.isclass(obj):
            if not (inspect.getdoc(obj) or "").strip():
                missing.append(f"{modname}.{cname}")
            for mname, member in vars(obj).items():
                if mname.startswith("_"):
                    continue
                fn = member
                if isinstance(member, (classmethod, staticmethod)):
                    fn = member.__func__
                elif isinstance(member, property):
                    fn = member.fget
                if inspect.isfunction(fn) and not (fn.__doc__ or "").strip():
                    missing.append(f"{modname}.{cname}.{mname}")
        elif inspect.isfunction(obj):
            if not (obj.__doc__ or "").strip():
                missing.append(f"{modname}.{cname}")
    return missing


@pytest.mark.parametrize("modname", DOC_MODULES)
def test_public_api_docstrings(modname):
    missing = _missing_docstrings(modname)
    assert not missing, (
        f"public API without docstrings in {modname} (state units, "
        f"blocking behavior and raised errors): {missing}"
    )
