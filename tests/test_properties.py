"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.chunking import chunk_offsets, chunk_prompt, optimal_chunk_size
from repro.core.monitor import Ewma
from repro.core.speculative import accept_greedy_rows
from repro.data import BPETokenizer, ByteTokenizer
from repro.models.layers import attend
from repro.net.protocol import MSG_NAMES

SETTINGS = dict(max_examples=40, deadline=None)


@given(plen=st.integers(1, 5000), cs=st.integers(1, 4096))
@settings(**SETTINGS)
def test_chunk_prompt_partitions(plen, cs):
    chunks = chunk_prompt(plen, cs)
    assert sum(chunks) == plen
    assert all(0 < c <= cs for c in chunks)
    assert chunk_offsets(chunks)[-1] + chunks[-1] == plen
    assert len(chunks) == -(-plen // cs)


@given(
    draft=st.lists(st.integers(0, 31), min_size=1, max_size=8),
    greedy=st.lists(st.integers(0, 31), min_size=9, max_size=9),
)
@settings(**SETTINGS)
def test_accept_greedy_rows_properties(draft, greedy):
    k = len(draft)
    rows = np.full((k + 1, 32), -1e9, np.float32)
    for i, t in enumerate(greedy[: k + 1]):
        rows[i, t] = 1.0
    n, nxt = accept_greedy_rows(np.asarray(draft), rows)
    assert 0 <= n <= k
    assert draft[:n] == greedy[:n]                   # accepted prefix matches
    if n < k:
        assert draft[n] != greedy[n]                 # first reject diverges
    assert nxt == greedy[n]                          # bonus = LLM's token


@given(
    samples=st.lists(st.floats(0.1, 1e3), min_size=1, max_size=30),
    alpha=st.floats(0.0, 1.0),
)
@settings(**SETTINGS)
def test_ewma_stays_in_range(samples, alpha):
    e = Ewma(alpha=alpha)
    for s in samples:
        e.update(s)
    assert min(samples) - 1e-6 <= e.get() <= max(samples) + 1e-6


@given(
    beta=st.floats(1e5, 1e8),
    base=st.floats(1e-3, 0.2),
    slope=st.floats(1e-6, 1e-3),
    plen=st.integers(64, 8192),
)
@settings(**SETTINGS)
def test_optimal_chunk_size_bounds(beta, base, slope, plen):
    x = optimal_chunk_size(
        prompt_len=plen, hidden_bytes_per_token=8192.0, beta_up=beta,
        g=lambda t: base + slope * t, mu=64, pipeline_len=4,
    )
    assert 8 <= x <= max(4096, plen)


@given(st.text(max_size=120))
@settings(**SETTINGS)
def test_byte_tokenizer_roundtrip(text):
    bt = ByteTokenizer()
    assert bt.decode(bt.encode(text)) == text


@given(st.text(alphabet="abcdef ", min_size=0, max_size=60))
@settings(max_examples=15, deadline=None)
def test_bpe_roundtrip(text):
    bpe = BPETokenizer(300).train(["abc abd abe fed " * 10])
    assert bpe.decode(bpe.encode(text)) == text


@given(
    t=st.integers(1, 8),
    s_extra=st.integers(0, 16),
    window=st.one_of(st.none(), st.integers(2, 12)),
    seed=st.integers(0, 2**16),
)
@settings(max_examples=20, deadline=None)
def test_attend_causality(t, s_extra, window, seed):
    """Perturbing masked (future / out-of-window / invalid) KV slots never
    changes the attention output."""
    B, nh, nkv, hd = 1, 2, 1, 8
    S = t + s_extra + 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, t, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    off = 2
    q_pos = off + jnp.arange(t)
    k_pos = jnp.arange(S)
    out = attend(q, k, v, q_pos=q_pos, k_pos=k_pos, window=window)
    # perturb strictly-future slots
    fut = k_pos > (off + t - 1)
    noise = jax.random.normal(ks[3], (B, S, nkv, hd)) * fut[None, :, None, None]
    out2 = attend(q, k + noise, v + 3 * noise, q_pos=q_pos, k_pos=k_pos, window=window)
    assert float(jnp.max(jnp.abs(out - out2))) < 1e-5


# ---------------------------------------------------------------------------
# repro.net stream framing: any message sequence survives any chunking
# ---------------------------------------------------------------------------

_NET_MSG = st.tuples(st.sampled_from(sorted(MSG_NAMES)),
                     st.binary(max_size=200))


@given(
    msgs=st.lists(_NET_MSG, max_size=12),
    cuts=st.lists(st.integers(0, 10_000), max_size=16),
)
@settings(**SETTINGS)
def test_net_stream_decoder_reassembles_any_chunking(msgs, cuts):
    from repro.net.protocol import StreamDecoder, encode_msg

    stream = b"".join(encode_msg(t, p) for t, p in msgs)
    points = sorted(c % (len(stream) + 1) for c in cuts)
    dec = StreamDecoder()
    got, prev = [], 0
    for c in points + [len(stream)]:
        got.extend(dec.feed(stream[prev:c]))
        prev = c
    assert got == msgs
    assert dec.pending_bytes == 0
    assert dec.messages_in == len(msgs)


@given(prefix=st.binary(min_size=7, max_size=40))
@settings(**SETTINGS)
def test_net_stream_decoder_rejects_desync(prefix):
    from repro.net.protocol import MAGIC, StreamDecoder
    from repro.net.errors import ProtocolError

    hypothesis.assume(prefix[:2] != MAGIC)
    with pytest.raises(ProtocolError):
        StreamDecoder().feed(prefix)


@given(length=st.integers(1, (1 << 32) - 1), cap=st.integers(8, 1 << 20))
@settings(**SETTINGS)
def test_net_stream_decoder_oversize_rejected_on_header(length, cap):
    import struct

    from repro.net.protocol import MAGIC, MSG_FRAME, StreamDecoder
    from repro.net.errors import ProtocolError

    hypothesis.assume(length > cap)
    dec = StreamDecoder(max_message_bytes=cap)
    header = struct.pack("<2sBI", MAGIC, MSG_FRAME, length)
    with pytest.raises(ProtocolError):
        dec.feed(header)              # no payload bytes ever buffered
    assert dec.pending_bytes <= len(header)


@given(
    msgs=st.lists(_NET_MSG, min_size=1, max_size=8),
    cut_frac=st.floats(0.0, 1.0, allow_nan=False),
)
@settings(**SETTINGS)
def test_net_stream_decoder_truncation_never_buffers_unbounded(msgs, cut_frac):
    """A stream torn mid-header or mid-payload parks bounded bytes (at
    most one incomplete message) and raises nothing; feeding the rest
    completes every message."""
    from repro.net.protocol import HEADER_BYTES, StreamDecoder, encode_msg

    stream = b"".join(encode_msg(t, p) for t, p in msgs)
    cut = int(cut_frac * len(stream))
    dec = StreamDecoder(max_message_bytes=1 << 20)
    got = dec.feed(stream[:cut])                     # truncated: no error
    assert dec.pending_bytes <= HEADER_BYTES + max(
        len(p) for _, p in msgs)                     # bounded buffering
    got += dec.feed(stream[cut:])
    assert got == msgs
    assert dec.pending_bytes == 0


@given(
    prev_epoch=st.integers(0, (1 << 32) - 1),
    sessions=st.lists(
        st.tuples(st.integers(0, (1 << 32) - 1),
                  st.integers(0, (1 << 32) - 1),
                  st.integers(0, (1 << 32) - 1)),
        max_size=12,
    ),
)
@settings(**SETTINGS)
def test_net_resume_codec_roundtrip_and_truncation(prev_epoch, sessions):
    """The v2 resume codecs roundtrip any watermark set, and every torn
    payload raises a typed ProtocolError (never a struct.error)."""
    from repro.net.errors import ProtocolError
    from repro.net.protocol import (
        decode_resume,
        decode_resume_ok,
        encode_resume,
        encode_resume_ok,
    )

    payload = encode_resume(prev_epoch, sessions)
    assert decode_resume(payload) == (prev_epoch, sessions)
    ok = encode_resume_ok([(r, u) for r, u, _ in sessions])
    assert decode_resume_ok(ok) == [(r, u) for r, u, _ in sessions]
    for torn in (payload[:-1], ok[:-1] if ok else b"", b"\x00"):
        if torn in (payload, ok):
            continue
        with pytest.raises(ProtocolError):
            decode_resume(torn)


@given(seq=st.integers(0, (1 << 32) - 1), frame=st.binary(max_size=256))
@settings(**SETTINGS)
def test_net_seq_frame_codec_roundtrip(seq, frame):
    from repro.net.errors import ProtocolError
    from repro.net.protocol import decode_seq_frame, encode_seq_frame

    assert decode_seq_frame(encode_seq_frame(seq, frame)) == (seq, frame)
    with pytest.raises(ProtocolError):
        decode_seq_frame(b"\x00\x01")                # shorter than the seq
