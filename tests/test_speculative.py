"""Speculative decoding: losslessness, threshold stop, rollback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DraftModel,
    accept_greedy_rows,
    draft_until_threshold,
    init_adapter,
    restore_states,
    snapshot_states,
    split_model,
)
from repro.serving import RealBackend, Request
from conftest import reduced_model


def _greedy_reference(model, params, prompt, n_new, max_len=128):
    cache = model.init_cache(params, 1, max_len)
    lg, cache, _ = model.apply(params, prompt[None], cache=cache, offset=0)
    out = [int(lg[0, -1].argmax())]
    off = prompt.shape[0]
    while len(out) < n_new:
        lg, cache, _ = model.apply(
            params, jnp.asarray([[out[-1]]], jnp.int32), cache=cache, offset=off
        )
        off += 1
        out.append(int(lg[0, -1].argmax()))
    return out


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "xlstm-350m", "zamba2-1.2b"])
def test_speculative_losslessness(arch, key):
    """HAT's U-shaped speculative pipeline must emit EXACTLY the full model's
    greedy continuation — attention archs (positional rollback) and SSM
    archs (state snapshot + re-advance) alike."""
    cfg, model, params = reduced_model(arch)
    sp = split_model(cfg, params)
    ad, _ = init_adapter(cfg, jax.random.fold_in(key, 7))
    be = RealBackend(sp, adapter_params=ad, max_len=128)
    prompt = jnp.asarray(
        jax.random.randint(key, (16,), 0, cfg.vocab_size), jnp.int32
    )
    req = Request(req_id=0, device_id=0, arrival_s=0, prompt_len=16,
                  max_new_tokens=10, prompt=np.asarray(prompt))
    out = [be.first_token(req)]
    while len(out) < 10:
        d = be.draft(req, 5)
        n, bonus = be.verify(req, d)
        out.extend(list(d[:n]) + [bonus])
    assert out[:10] == _greedy_reference(model, params, prompt, 10)


def test_accept_greedy_rows_unit():
    V = 16

    def rows(tokens):
        r = np.full((len(tokens), V), -1e9, np.float32)
        for i, t in enumerate(tokens):
            r[i, t] = 1.0
        return r

    # all accepted
    n, nxt = accept_greedy_rows(np.array([3, 5, 7]), rows([3, 5, 7, 9]))
    assert (n, nxt) == (3, 9)
    # first divergence
    n, nxt = accept_greedy_rows(np.array([3, 5, 7]), rows([3, 6, 7, 9]))
    assert (n, nxt) == (1, 6)
    # none accepted
    n, nxt = accept_greedy_rows(np.array([3]), rows([4, 9]))
    assert (n, nxt) == (0, 4)


def test_threshold_stops_drafting(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    sp = split_model(cfg, params)
    ad, _ = init_adapter(cfg, key)
    dm = DraftModel(sp, ad)
    cache = dm.init_cache(1, 64)
    prompt = jax.random.randint(key, (1, 8), 0, cfg.vocab_size)
    _, cache, _ = dm.forward(prompt, cache=cache, offset=0)
    last = jnp.argmax(dm.forward(prompt, cache=None, offset=0)[0][:, -1:], -1)
    # eta=1.01 can never be met -> exactly one draft step
    res, _, _ = draft_until_threshold(
        dm, cache, last.astype(jnp.int32), 8, eta=1.01, max_draft=6
    )
    assert res.steps == 1
    # eta=0 -> runs to max_draft
    cache2 = dm.init_cache(1, 64)
    _, cache2, _ = dm.forward(prompt, cache=cache2, offset=0)
    res2, _, _ = draft_until_threshold(
        dm, cache2, last.astype(jnp.int32), 8, eta=0.0, max_draft=6
    )
    assert res2.steps == 6
    assert res2.topk_last.shape == (4,)


def test_ssm_snapshot_restore(key):
    cfg, model, params = reduced_model("xlstm-350m")
    cache = model.init_cache(params, 1, 32)
    toks = jax.random.randint(key, (1, 6), 0, cfg.vocab_size)
    _, cache1, _ = model.apply(params, toks, cache=cache, offset=0)
    snap = snapshot_states(cache1)
    _, cache2, _ = model.apply(params, toks, cache=cache1, offset=6)
    cache3 = restore_states(cache2, snap)
    s1 = jax.tree.leaves(snapshot_states(cache1))
    s3 = jax.tree.leaves(snapshot_states(cache3))
    for a, b in zip(s1, s3):
        assert jnp.array_equal(a, b)
