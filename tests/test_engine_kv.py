"""Batched cloud engine + KV capacity manager."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import split_model
from repro.serving import CloudEngine, EngineJob, KVBudget, SlotKVManager
from conftest import reduced_model


@pytest.fixture(scope="module")
def setup():
    cfg, model, params = reduced_model("internlm2-1.8b")
    return cfg, model, params, split_model(cfg, params)


def test_kv_manager_accounting():
    kv = SlotKVManager(2, 256, KVBudget(block_tokens=64, total_blocks=7))
    assert kv.can_admit(128)
    kv.admit(0, 128)                         # 2 blocks
    assert kv.budget.used_blocks == 2
    kv.admit(1, 256)                         # 4 blocks
    assert not kv.can_admit(64)              # out of slots
    assert kv.extend(0, 192)                 # 3 blocks now
    assert kv.budget.used_blocks == 7
    assert not kv.extend(0, 256)             # would need an 8th block
    kv.release(1)
    assert kv.budget.used_blocks == 3
    assert kv.can_admit(64)


def test_engine_chunked_prefill_matches_direct(setup):
    cfg, model, params, sp = setup
    eng = CloudEngine(sp, n_slots=4, max_len=64, max_batch_tokens=32)
    rng = np.random.default_rng(0)
    for rid, plen in [(0, 20), (1, 13)]:
        assert eng.add_request(rid, plen + 16)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, plen))[None]
        shallow, _, _ = sp.input_model.apply(sp.input_params, toks, return_hidden=True)
        ref, _, _ = sp.middle_model.apply(
            sp.middle_params, None, inputs_embeds=shallow, return_hidden=True
        )
        sh = np.asarray(shallow[0], np.float32)
        outs = []
        for off in range(0, plen, 8):
            eng.submit(EngineJob(rid, sh[off:off + 8], off, "prefill"))
            for r in eng.drain():
                outs.append(r.deep)
        err = float(np.abs(np.concatenate(outs, 0) - np.asarray(ref[0])).max())
        assert err < 1e-3


def test_engine_batches_multiple_slots(setup):
    cfg, model, params, sp = setup
    eng = CloudEngine(sp, n_slots=4, max_len=64, max_batch_tokens=64)
    rng = np.random.default_rng(1)
    refs = {}
    for rid, plen in [(0, 10), (1, 6)]:
        eng.add_request(rid, 40)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, plen))[None]
        sh, _, _ = sp.input_model.apply(sp.input_params, toks, return_hidden=True)
        refs[rid], _, _ = sp.middle_model.apply(
            sp.middle_params, None, inputs_embeds=sh, return_hidden=True
        )
        eng.submit(EngineJob(rid, np.asarray(sh[0]), 0, "prefill"))
    res = eng.step()
    assert len(res) == 2 and eng.steps == 1          # ONE batched iteration
    for r in res:
        err = float(np.abs(r.deep - np.asarray(refs[r.req_id][0])).max())
        assert err < 1e-3


def test_engine_steps_do_not_corrupt_idle_slots(setup):
    """A step must only write the cache rows of slots with a job in the
    batch: interleaved requests keep exact KV state (regression — idle
    slots used to be overwritten at offset 0 with zero-input garbage)."""
    cfg, model, params, sp = setup
    eng = CloudEngine(sp, n_slots=4, max_len=64, max_batch_tokens=64)
    rng = np.random.default_rng(3)
    toks = {rid: jnp.asarray(rng.integers(0, cfg.vocab_size, 12))[None]
            for rid in (0, 1)}
    sh = {}
    for rid in (0, 1):
        eng.add_request(rid, 24)
        s, _, _ = sp.input_model.apply(sp.input_params, toks[rid],
                                       return_hidden=True)
        sh[rid] = np.asarray(s[0], np.float32)
    # interleave: prefill halves of each request in alternating steps
    for rid, lo, hi in [(0, 0, 6), (1, 0, 6), (0, 6, 12), (1, 6, 12)]:
        eng.submit(EngineJob(rid, sh[rid][lo:hi], lo, "prefill"))
        eng.drain()                                  # one-slot batches
    # a second pass at the same offsets (positional overwrite) must see the
    # identical cache prefix a single-request engine would
    for rid in (0, 1):
        ref, _, _ = sp.middle_model.apply(
            sp.middle_params, None,
            inputs_embeds=jnp.asarray(sh[rid])[None], return_hidden=True
        )
        eng.submit(EngineJob(rid, sh[rid][6:12], 6, "verify"))
        (res,) = eng.drain()
        err = float(np.abs(res.deep - np.asarray(ref[0][6:12])).max())
        assert err < 1e-3, rid


def test_engine_budget_splits_batches(setup):
    cfg, model, params, sp = setup
    eng = CloudEngine(sp, n_slots=4, max_len=64, max_batch_tokens=8)
    rng = np.random.default_rng(2)
    for rid in (0, 1):
        eng.add_request(rid, 40)
        sh = rng.normal(size=(12, cfg.d_model)).astype(np.float32)
        eng.submit(EngineJob(rid, sh, 0, "prefill"))
    eng.drain()
    assert eng.steps == 2                            # budget forced two rounds
    assert max(eng.batched_token_history) <= 12
