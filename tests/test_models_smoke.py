"""Per-arch smoke tests (deliverable f): a REDUCED variant of each assigned
family runs one forward and one train step on CPU — output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import Model
from repro.training import AdamW, make_train_step

from conftest import reduced_model


def _inputs(cfg, model, params, key, B=2, T=16):
    kw = {}
    if cfg.frontend == "vision":
        kw["memory"] = jax.random.normal(key, (B, 8, cfg.d_model))
    if cfg.is_encoder_decoder:
        frames = jax.random.normal(key, (B, 8, cfg.d_model))
        kw["memory"] = model.encode(params, frames)
    return kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_smoke(arch, key):
    cfg, model, params = reduced_model(arch)
    B, T = 2, 16
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    kw = _inputs(cfg, model, params, key, B, T)
    logits, _, aux = model.apply(params, tokens, **kw)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    if cfg.n_experts:
        assert float(aux) > 0.0            # load-balance loss is live


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_smoke(arch, key):
    cfg, model, params = reduced_model(arch)
    opt = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, opt))
    tokens = np.asarray(
        jax.random.randint(key, (2, 17), 0, cfg.vocab_size)
    )
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["memory"] = np.asarray(jax.random.normal(key, (2, 8, cfg.d_model)))
    if cfg.is_encoder_decoder:
        batch["memory"] = np.asarray(
            model.encode(params, jax.random.normal(key, (2, 8, cfg.d_model)))
        )
    p2, _, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-12b", "xlstm-350m",
                                  "zamba2-1.2b", "kimi-k2-1t-a32b",
                                  "seamless-m4t-large-v2"])
def test_decode_one_token(arch, key):
    cfg, model, params = reduced_model(arch)
    B = 2
    memory = None
    if cfg.frontend == "vision":
        memory = jax.random.normal(key, (B, 8, cfg.d_model))
    if cfg.is_encoder_decoder:
        memory = model.encode(params, jax.random.normal(key, (B, 8, cfg.d_model)))
    cache = model.init_cache(params, B, 32, memory=memory)
    prompt = jax.random.randint(key, (B, 7), 0, cfg.vocab_size)
    lg, cache, _ = model.apply(params, prompt, cache=cache, offset=0, memory=memory)
    tok = jnp.argmax(lg[:, -1:], -1).astype(jnp.int32)
    lg2, cache, _ = model.apply(params, tok, cache=cache, offset=7, memory=memory)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg2).any())


def test_abstract_params_match_real_structure():
    cfg, model, params = reduced_model("internlm2-1.8b")
    ap = model.abstract_params()
    assert jax.tree.structure(ap) == jax.tree.structure(params)
    for a, r in zip(jax.tree.leaves(ap), jax.tree.leaves(params)):
        assert a.shape == r.shape and a.dtype == r.dtype


def test_giant_config_abstract_init_fast():
    cfg = get_config("kimi-k2-1t-a32b")
    m = Model(cfg, dtype=jnp.bfloat16)
    ap = m.abstract_params()
    n = sum(x.size for x in jax.tree.leaves(ap))
    assert n > 1.0e12                     # the trillion is real
    spec = m.param_spec()
    assert jax.tree.structure(ap) == jax.tree.structure(
        spec, is_leaf=lambda x: isinstance(x, str)
    )
