"""Session API: DeviceClient / CloudServer / Transport / ServeConfig.

The load-bearing guarantees:
  * engine<->backend parity — DeviceClient+CloudServer over loopback emit
    token-for-token identical output to a monolithic Model forward, and
    identical accept lengths to the RealBackend-driven fleet at int8;
  * ServeConfig resolves the codec-vs-hidden_bytes precedence once, and the
    legacy ``run_fleet`` wrapper never clobbers a backend-supplied codec;
  * CloudEngine bounds-checks slot writes instead of scribbling silently.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_model
from repro.core import init_adapter, split_model
from repro.serving import (
    CloudEngine,
    CloudServer,
    DelayModelTransport,
    DeviceClient,
    EngineJob,
    EngineOverflowError,
    EngineRuntime,
    FleetMetrics,
    LoopbackTransport,
    RealBackend,
    Request,
    ServeConfig,
    SimulatorRuntime,
    StatisticalBackend,
    run_fleet,
)
from repro.serving.delay_models import make_fleet
from repro.wire import get_codec


@pytest.fixture(scope="module")
def setup():
    cfg, model, params = reduced_model("internlm2-1.8b")
    return cfg, model, params, split_model(cfg, params)


def _greedy(model, params, prompt, n_new, max_len=256):
    cache = model.init_cache(params, 1, max_len)
    lg, cache, _ = model.apply(params, jnp.asarray(prompt)[None], cache=cache, offset=0)
    out = [int(lg[0, -1].argmax())]
    off = len(prompt)
    while len(out) < n_new:
        lg, cache, _ = model.apply(params, jnp.asarray([[out[-1]]], jnp.int32),
                                   cache=cache, offset=off)
        off += 1
        out.append(int(lg[0, -1].argmax()))
    return out


# ---------------------------------------------------------------------------
# ServeConfig
# ---------------------------------------------------------------------------


def test_serve_config_framework_constructors():
    hat = ServeConfig.hat()
    assert (hat.sd, hat.pc, hat.pd) == ("draft", "device", True)
    ush = ServeConfig.u_shape()
    assert (ush.sd, ush.pc, ush.pd, ush.max_batch_tokens) == (None, None, False, None)
    sar = ServeConfig.u_sarathi()
    assert (sar.pc, sar.dynamic_chunks) == ("server", False)
    med = ServeConfig.u_medusa()
    assert (med.sd, med.max_batch_tokens) == ("medusa", None)
    # ablation overrides win over the framework defaults
    abl = ServeConfig.from_framework("hat", sd=None, pd=False)
    assert (abl.sd, abl.pc, abl.pd) == (None, "device", False)
    with pytest.raises(KeyError):
        ServeConfig.from_framework("nope")


def test_serve_config_codec_resolution():
    # no codec requested: fp16 byte accounting by default
    c = ServeConfig.hat(d_model=4096)
    assert c.wire_codec is None and c.hidden_bytes_per_token == 2 * 4096
    # requested codec drives the byte accounting
    c = ServeConfig.hat(wire_codec="int8", d_model=4096)
    assert c.hidden_bytes_per_token == 4096 + 4
    # explicit bytes beat the codec-derived value
    c = ServeConfig.hat(wire_codec="int8", hidden_bytes_per_token=999.0)
    assert c.hidden_bytes_per_token == 999.0


def test_serve_config_backend_codec_not_clobbered():
    """A backend configured by its caller keeps its codec unless the run
    explicitly requests one (the old run_fleet clobbered it via the fp16
    default)."""
    rng = np.random.default_rng(0)
    be = StatisticalBackend(rng, wire_penalty=0.07)
    ServeConfig.hat().configure_backend(be)            # no codec requested
    assert be.wire_penalty == 0.07
    ServeConfig.hat(wire_codec="int8").configure_backend(be)
    assert be.wire_penalty == get_codec("int8").accept_penalty


def test_run_fleet_wrapper_codec_regression():
    """Legacy-wrapper regression (the satellite fix): backend-supplied
    codecs survive run_fleet unless a codec is requested."""
    from repro.data import SPECBENCH, sample_workload

    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=10, rate_per_s=8)
    be = StatisticalBackend(np.random.default_rng(1), wire_penalty=0.07)
    run_fleet("hat", reqs, rng=np.random.default_rng(2), backend=be)
    assert be.wire_penalty == 0.07                     # untouched
    run_fleet("hat", reqs, rng=np.random.default_rng(2), backend=be,
              wire_codec="int4")
    assert be.wire_penalty == get_codec("int4").accept_penalty
    # overrides-dict route requests a codec too
    be2 = StatisticalBackend(np.random.default_rng(1))
    run_fleet("hat", reqs, rng=np.random.default_rng(2), backend=be2,
              overrides={"wire_codec": "int8"})
    assert be2.wire_penalty == get_codec("int8").accept_penalty


# ---------------------------------------------------------------------------
# loopback parity: session API == monolithic model
# ---------------------------------------------------------------------------


def test_loopback_parity_u_shape(setup):
    """DeviceClient+CloudServer (no drafting) over the loopback transport
    emit token-for-token the monolithic model's greedy continuation, at
    both the exact fp32 wire and the production fp16 wire."""
    cfg, model, params, sp = setup
    rng = np.random.default_rng(0)
    prompt = rng.integers(3, cfg.vocab_size, size=24).astype(np.int32)
    ref = _greedy(model, params, prompt, 8)
    for codec in ("fp32", "fp16"):
        server = CloudServer(sp, n_slots=2, max_len=128, max_batch_tokens=64,
                             wire_codec=codec)
        client = DeviceClient(sp, LoopbackTransport(server), wire_codec=codec,
                              max_len=128, fixed_chunk=16)
        toks = list(client.generate(prompt, max_new_tokens=8))
        assert toks == ref, codec


def test_loopback_parity_hat_drafting(setup):
    """Speculative decoding through the session API is lossless: with an
    (untrained) adapter drafting, the emitted stream still equals greedy."""
    cfg, model, params, sp = setup
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(1)
    prompt = rng.integers(3, cfg.vocab_size, size=20).astype(np.int32)
    server = CloudServer(sp, n_slots=2, max_len=128, wire_codec="fp32")
    client = DeviceClient(sp, LoopbackTransport(server),
                          adapter_params=adapter, wire_codec="fp32",
                          max_len=128, fixed_chunk=16)
    toks = list(client.generate(prompt, max_new_tokens=10))
    assert toks == _greedy(model, params, prompt, 10)
    stats = client.finished_stats[0]
    assert stats["rounds"] >= 1 and stats["accepted"] >= stats["rounds"]


def test_loopback_parity_ssm_arch():
    """SSM middles roll back through the transport's control channel
    (engine slot snapshot/restore) — losslessness must still hold."""
    cfg, model, params = reduced_model("xlstm-350m")
    sp = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(2)
    prompt = rng.integers(3, cfg.vocab_size, size=16).astype(np.int32)
    server = CloudServer(sp, n_slots=2, max_len=128, wire_codec="fp32")
    client = DeviceClient(sp, LoopbackTransport(server),
                          adapter_params=adapter, wire_codec="fp32",
                          max_len=128, fixed_chunk=16)
    toks = list(client.generate(prompt, max_new_tokens=8))
    assert toks == _greedy(model, params, prompt, 8, max_len=128)


def test_sessions_interleave_and_release(setup):
    """Multiple concurrent sessions batch through one server; closing a
    session frees its slot for the next request."""
    cfg, model, params, sp = setup
    server = CloudServer(sp, n_slots=2, max_len=64, max_batch_tokens=64)
    client = DeviceClient(sp, LoopbackTransport(server), max_len=64,
                          fixed_chunk=16)
    rng = np.random.default_rng(3)
    prompts = {rid: rng.integers(3, cfg.vocab_size, size=12).astype(np.int32)
               for rid in range(4)}                       # 4 sessions, 2 slots
    for rid, prompt in prompts.items():
        toks = list(client.generate(prompt, max_new_tokens=4, req_id=rid))
        assert toks == _greedy(model, params, prompt, 4, max_len=64)
    assert server.engine.kv.active == 0                   # all released


def test_slot_auto_grow_preserves_live_sessions(setup):
    """The engine doubles its slot pool under concurrent session pressure
    (the RealBackend configuration), carrying live KV state across the
    growth — interleaved decodes stay lossless."""
    cfg, model, params, sp = setup
    server = CloudServer(sp, n_slots=2, max_len=64, max_batch_tokens=64,
                         auto_grow=True)
    client = DeviceClient(sp, LoopbackTransport(server), max_len=64,
                          fixed_chunk=16)
    rng = np.random.default_rng(7)
    prompts = {rid: rng.integers(3, cfg.vocab_size, size=12).astype(np.int32)
               for rid in range(4)}                     # 4 live, 2 slots
    outs = {rid: [client.prefill(rid, p)] for rid, p in prompts.items()}
    assert server.engine.n_slots >= 4                   # pool grew
    for _ in range(3):                                  # interleaved decode
        for rid in prompts:
            outs[rid].extend(client.step_decode(rid))
    for rid, p in prompts.items():
        ref = _greedy(model, params, p, len(outs[rid]), max_len=64)
        assert outs[rid] == ref, rid
        client.finish(rid)


def test_generate_ends_stream_at_kv_capacity(setup):
    """A session whose prompt + generation would outgrow the slot stops
    streaming at capacity instead of overflowing the cache (with drafting
    capacity-capped near the boundary)."""
    cfg, model, params, sp = setup
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    prompt = rng.integers(3, cfg.vocab_size, size=28).astype(np.int32)
    for ad in (None, adapter):                     # u-shape and hat modes
        server = CloudServer(sp, n_slots=2, max_len=32)
        client = DeviceClient(sp, LoopbackTransport(server), max_len=32,
                              adapter_params=ad, fixed_chunk=16)
        toks = list(client.generate(prompt, max_new_tokens=10))
        assert 1 <= len(toks) <= 32 - 28 + 1       # capped by capacity
        assert toks == _greedy(model, params, prompt, len(toks), max_len=32)


def test_engine_runtime_rejects_missing_params(setup):
    cfg, model, params, sp = setup
    with pytest.raises(ValueError, match="adapter_params"):
        EngineRuntime(ServeConfig.hat(), sp)
    with pytest.raises(ValueError, match="medusa_params"):
        EngineRuntime(ServeConfig.u_medusa(), sp)


def test_accept_parity_realbackend_int8(setup):
    """Engine<->backend parity at int8: the RealBackend-driven fleet and a
    bare DeviceClient session measure identical tokens and accept lengths —
    they ARE the same path, and this pins it."""
    cfg, model, params, sp = setup
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    rng = np.random.default_rng(4)
    prompt = rng.integers(3, cfg.vocab_size, size=24).astype(np.int32)

    from repro.data import RequestSpec
    reqs = [RequestSpec(req_id=0, device_id=0, arrival_s=0.0, prompt_len=24,
                        max_new_tokens=12, prompt=prompt)]
    be = RealBackend(sp, adapter_params=adapter, max_len=256, wire_codec="int8")
    m = run_fleet("hat", reqs, rng=np.random.default_rng(5), n_devices=1,
                  wire_codec="int8", overrides={"d_model": cfg.d_model},
                  backend=be)
    (r,) = m.requests

    server = CloudServer(sp, n_slots=2, max_len=256, wire_codec="int8")
    client = DeviceClient(sp, LoopbackTransport(server),
                          adapter_params=adapter, wire_codec="int8",
                          max_len=256)
    toks = list(client.generate(prompt, max_new_tokens=12))
    stats = client.finished_stats[0]
    assert toks == r.generated
    assert stats["rounds"] == r.rounds
    assert stats["accepted"] / stats["rounds"] == pytest.approx(r.accept_length)


# ---------------------------------------------------------------------------
# engine bounds check
# ---------------------------------------------------------------------------


def test_engine_overflow_raises_and_releases(setup):
    cfg, model, params, sp = setup
    eng = CloudEngine(sp, n_slots=2, max_len=32, max_batch_tokens=64)
    assert eng.add_request(0, 32)
    sh = np.zeros((16, cfg.d_model), np.float32)
    eng.submit(EngineJob(0, sh, 0, "prefill"))            # [0, 16) fits
    with pytest.raises(EngineOverflowError):
        eng.submit(EngineJob(0, sh, 24, "prefill"))       # [24, 40) overflows
    assert 0 not in eng.kv.slot_of                        # slot released
    assert eng.queue == []                                # queued jobs dropped
    assert eng.add_request(1, 32)                         # capacity reusable


# ---------------------------------------------------------------------------
# runtimes + transports + metrics
# ---------------------------------------------------------------------------


def test_delay_model_transport_keeps_clock(setup):
    cfg, model, params, sp = setup
    dev = make_fleet(np.random.default_rng(0), 1)[0]
    server = CloudServer(sp, n_slots=2, max_len=64)
    t = DelayModelTransport(server, device=dev, start_s=1.5,
                            rng=np.random.default_rng(1))
    client = DeviceClient(sp, t, max_len=64, fixed_chunk=16, profile=dev)
    prompt = np.arange(3, 15, dtype=np.int32)
    toks = list(client.generate(prompt, max_new_tokens=3))
    assert len(toks) == 3
    assert t.clock_s > 1.5                                # time actually passed
    assert len(t.cloud_step_delays_s) >= 1
    assert t.bytes_up > 0 and t.bytes_down > 0


def test_engine_runtime_serves_fleet_metrics(setup):
    cfg, model, params, sp = setup
    from repro.data import RequestSpec

    rng = np.random.default_rng(5)
    reqs = [
        RequestSpec(req_id=i, device_id=i, arrival_s=0.5 * i, prompt_len=16,
                    max_new_tokens=4,
                    prompt=rng.integers(3, cfg.vocab_size, 16).astype(np.int32))
        for i in range(2)
    ]
    config = ServeConfig.u_shape(n_devices=2, wire_codec="fp16")
    m = EngineRuntime(config, sp, rng=np.random.default_rng(6),
                      n_slots=2, max_len=64).serve(reqs)
    s = m.summary()
    assert s["n"] == 2
    assert s["ttft_mean_ms"] > 0 and s["tbt_mean_ms"] > 0
    assert s["cloud_delay_mean_ms"] > 0
    for r in m.requests:
        assert len(r.generated) == 4
        assert r.generated == _greedy(model, params, r.prompt, 4, max_len=64)


def test_simulator_runtime_matches_run_fleet():
    """The Runtime surface and the legacy wrapper are the same engine."""
    from repro.data import SPECBENCH, sample_workload

    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=30, rate_per_s=8)
    a = run_fleet("hat", reqs, rng=np.random.default_rng(1)).summary()
    b = SimulatorRuntime(ServeConfig.hat(), rng=np.random.default_rng(1)) \
        .serve(reqs).summary()
    assert a == b


def test_summary_always_has_cloud_delay_keys():
    m = FleetMetrics()
    s = m.summary()
    assert s["cloud_delay_mean_ms"] == 0.0
    assert s["cloud_delay_std_ms"] == 0.0
