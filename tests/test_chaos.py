"""Fault tolerance: session resume, retry/deadline policies, backpressure
and the deterministic chaos harness of :mod:`repro.net`.

Layered like ``test_net.py``, cheapest first:

* pure policy units (RetryPolicy backoff determinism, Deadline
  composition) and the :class:`FaultyTransport` wrapper — no sockets;
* launcher supervision units against fake processes — no JAX;
* a real in-process :class:`~repro.net.service.CloudService` behind a
  :class:`~repro.net.chaos.ChaosProxy` injecting seeded connection drops
  mid-prefill, mid-verify (SSM arch) and on the downlink: the device must
  reconnect, resume via watermarks, and produce a token stream
  byte-identical to the fault-free loopback run — or, past the grace
  period, surface :class:`~repro.net.errors.SessionLostError` with the
  partial tokens instead of hanging.
"""
import socket
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from conftest import reduced_model
from repro.net import protocol as P
from repro.net.chaos import ChaosProxy, FaultEvent, FaultyTransport, seeded_schedule
from repro.net.protocol import StreamDecoder
from repro.net.errors import (
    ProtocolError,
    SessionLostError,
    TransportClosed,
    TransportError,
    TransportTimeout,
)
from repro.net.policy import Deadline, RetryPolicy

ARCH = "internlm2-1.8b"
SSM_ARCH = "xlstm-350m"


# ---------------------------------------------------------------------------
# policy units: deterministic backoff, deadline composition
# ---------------------------------------------------------------------------


def test_retry_policy_same_seed_same_schedule():
    p = RetryPolicy(max_attempts=5, seed=42)
    a = list(p.delays())
    assert a == list(p.delays())                     # fresh rng, same seed
    assert len(a) == p.max_attempts
    for attempt, d in enumerate(a):
        base = min(p.base_s * p.multiplier ** attempt, p.max_backoff_s)
        assert abs(d - base) <= base * p.jitter + 1e-9
    # the cap really caps: far attempts stop growing
    late = RetryPolicy(max_attempts=20, jitter=0.0).backoff_s(19)
    assert late == RetryPolicy().max_backoff_s


def test_retry_policy_zero_attempts_means_no_schedule():
    assert list(RetryPolicy(max_attempts=0).delays()) == []


def test_deadline_composition_and_expiry():
    d = Deadline(op_timeout_s=5.0, total_s=0.05)
    clock = d.start()
    assert not clock.expired()
    assert clock.total_remaining_s() <= 0.05
    time.sleep(0.08)
    assert clock.expired()
    # per-call override beats op_timeout_s; None means unbounded
    assert d.op_deadline(100.0) == 105.0
    assert d.op_deadline(100.0, timeout=1.0) == 101.0
    assert Deadline(op_timeout_s=None).op_deadline(0.0) == float("inf")


def test_transport_total_deadline_caps_recv(make_transport):
    """A session-wide total_s budget bounds a recv even when both the
    per-call timeout and op_timeout_s are far larger (the migration
    contract: transport timeouts compose with deadlines, tightest wins)."""
    from test_net import _FakeCloud

    t = make_transport(_FakeCloud(),
                       deadline=Deadline(op_timeout_s=60.0, total_s=0.4))
    t.open(5, 16)
    t0 = time.monotonic()
    with pytest.raises(TransportTimeout):
        t.recv(5, timeout=30.0)                      # returns in ~0.4s, not 30
    assert time.monotonic() - t0 < 5.0


def test_heartbeat_pings_a_silent_connection(make_transport):
    """A blocked recv on a silent (but live) connection probes it with
    MSG_PING instead of waiting blind."""
    from test_net import _FakeCloud

    t = make_transport(_FakeCloud(), heartbeat_s=0.1,
                       heartbeat_timeout_s=30.0)
    t.open(5, 16)
    with pytest.raises(TransportTimeout):
        t.recv(5, timeout=0.6)
    assert t.pings_sent >= 1


def test_liveness_ignores_our_own_stall(make_transport):
    """Minutes of device-side compute between handshake and first open (a
    cold jit compile on a loaded host) must not read as peer silence: the
    liveness window re-arms after our own absence instead of condemning a
    healthy connection.  Regression: under an 8+ device storm the stall
    crossed ``heartbeat_timeout_s``, the first ``open`` silently tore down
    the connection its request went out on, and the device polled the
    replacement until the op deadline."""
    from test_net import _FakeCloud

    t = make_transport(_FakeCloud(), heartbeat_s=0.5,
                       heartbeat_timeout_s=2.0)
    # simulate the stall without sleeping: last wire traffic *and* last
    # liveness check happened long ago (the process was busy elsewhere)
    t._last_rx -= 300.0
    t._last_liveness -= 300.0
    t.open(5, 16)                    # _FakeCloud accepts only one
    assert t.reconnects == 0         # connection: a recover would fail


class _SilentThenServingCloud:
    """First connection: acks hello, then goes silent (opens vanish into
    the void).  Later connections get full control-plane service — models
    a reply that died with a connection the device itself tore down."""

    def __init__(self, d_model=64):
        self.d_model = d_model
        self._ls = socket.create_server(("127.0.0.1", 0))
        self.port = self._ls.getsockname()[1]
        self.conns = 0
        threading.Thread(target=self._accept_loop, daemon=True).start()

    def _accept_loop(self):
        while True:
            try:
                sock, _ = self._ls.accept()
            except OSError:
                return
            idx = self.conns
            self.conns += 1
            threading.Thread(target=self._serve, args=(sock, idx),
                             daemon=True).start()

    def _serve(self, sock, idx):
        dec = StreamDecoder()
        with sock:
            while True:
                try:
                    chunk = sock.recv(1 << 16)
                except OSError:
                    return
                if not chunk:
                    return
                for mtype, payload in dec.feed(chunk):
                    if mtype == P.MSG_HELLO:
                        sock.sendall(P.encode_msg(
                            P.MSG_HELLO_ACK, P.encode_hello(self.d_model)))
                    elif mtype == P.MSG_OPEN and idx > 0:
                        rid, _ = P.decode_u32_pair(payload)
                        sock.sendall(P.encode_msg(
                            P.MSG_OPEN_OK, P.encode_u32(rid)))
                    elif mtype == P.MSG_BYE:
                        return

    def close(self):
        self._ls.close()


def test_liveness_recovery_resends_inflight_control(make_transport):
    """A liveness-triggered reconnect *inside* a control roundtrip must
    re-send the request: the reply to the original died with the old
    connection, and resume has nothing to replay for a session that was
    never established.  Regression: the roundtrip only re-sent when the
    *socket* raised, so a silent recovery left it polling the new
    connection forever."""
    cloud = _SilentThenServingCloud()
    t = make_transport(cloud, heartbeat_s=0.2, heartbeat_timeout_s=0.6,
                       recv_timeout_s=10.0,
                       retry=RetryPolicy(max_attempts=4, base_s=0.05))
    t.open(5, 16)                    # succeeds on the second connection
    assert t.reconnects == 1
    assert cloud.conns == 2


@pytest.fixture
def make_transport():
    from repro.net.transport import SocketTransport

    made = []

    def make(cloud, **kw):
        kw.setdefault("d_model", cloud.d_model)
        kw.setdefault("connect_timeout_s", 5.0)
        t = SocketTransport("127.0.0.1", cloud.port, **kw)
        made.append((t, cloud))
        return t

    yield make
    for t, cloud in made:
        t.shutdown()
        cloud.close()


# ---------------------------------------------------------------------------
# chaos primitives: seeded schedules, FaultyTransport
# ---------------------------------------------------------------------------


def test_seeded_schedule_is_deterministic():
    a = seeded_schedule(7, connections=2, drops_per_conn=2, max_hop=3)
    assert a == seeded_schedule(7, connections=2, drops_per_conn=2, max_hop=3)
    events = [ev for evs in a.values() for ev in evs]
    assert len(events) == 4                          # 2 conns x 2 drops
    assert all(ev.kind == "drop" for ev in events)
    assert all(0 <= ev.at_hop <= 3 for ev in events)
    # multi-drop schedules spread across reconnect indices: at most one
    # drop per connection index, so finite retries always converge
    assert all(len(evs) == 1 for evs in a.values())


def test_faulty_transport_injects_at_exact_call_indices():
    class _Inner:
        def __init__(self):
            self.sent = []

        def send(self, data):
            self.sent.append(data)

        def recv(self, req_id, timeout=None):
            return b"frame"

        def clock(self):
            return 0.0

    inner = _Inner()
    ft = FaultyTransport(inner, fail_sends=(1,), fail_recvs=(0,))
    ft.send(b"a")                                    # send #0 passes through
    with pytest.raises(TransportClosed):
        ft.send(b"b")                                # send #1 injected
    ft.send(b"c")
    with pytest.raises(TransportClosed):
        ft.recv(1)                                   # recv #0 injected
    assert ft.recv(1) == b"frame"
    assert inner.sent == [b"a", b"c"]
    assert [f["op"] for f in ft.faults] == ["send", "recv"]
    assert ft.clock() == 0.0                         # delegation


# ---------------------------------------------------------------------------
# launcher supervision: no orphaned workers
# ---------------------------------------------------------------------------


class _FakeProc:
    """poll() pops scripted return codes; the last one is sticky."""

    def __init__(self, *rcs):
        self._rcs = list(rcs)
        self.returncode = None

    def poll(self):
        self.returncode = (self._rcs.pop(0) if len(self._rcs) > 1
                           else self._rcs[0])
        return self.returncode


def test_wait_workers_raises_when_cloud_dies(tmp_path):
    from repro.net.launcher import _wait_workers

    cloud = SimpleNamespace(proc=_FakeProc(None, 1),
                            log_path=tmp_path / "cloud.log")
    workers = [_FakeProc(None), _FakeProc(None)]     # both still running
    with pytest.raises(TransportError, match="cloud service exited"):
        _wait_workers(workers, cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01)


def test_wait_workers_raises_on_worker_failure(tmp_path):
    from repro.net.launcher import _wait_workers

    cloud = SimpleNamespace(proc=_FakeProc(None),
                            log_path=tmp_path / "cloud.log")
    workers = [_FakeProc(0), _FakeProc(None, 3)]
    with pytest.raises(TransportError, match="worker 1 exited with 3"):
        _wait_workers(workers, cloud, timeout_s=5.0, wd=tmp_path,
                      poll_s=0.01)


def test_wait_workers_times_out(tmp_path):
    from repro.net.launcher import _wait_workers

    cloud = SimpleNamespace(proc=_FakeProc(None),
                            log_path=tmp_path / "cloud.log")
    with pytest.raises(TransportError, match="still running after"):
        _wait_workers([_FakeProc(None)], cloud, timeout_s=0.05, wd=tmp_path,
                      poll_s=0.01)


# ---------------------------------------------------------------------------
# real engine behind a chaos proxy (reduced model, in-process service)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dense_service():
    from repro.core import split_model
    from repro.net.service import CloudService
    from repro.serving import CloudServer

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    server = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server)
    host, port = svc.start()
    yield cfg, split, svc, host, port
    svc.stop()


def _make_client(split, transport, *, adapter=None, max_len=64,
                 wire_codec="fp16"):
    from repro.serving import DeviceClient

    return DeviceClient(split, transport, adapter_params=adapter,
                        sd="draft" if adapter is not None else None,
                        max_len=max_len, wire_codec=wire_codec,
                        fixed_chunk=16, dynamic_chunks=False)


def _loopback_tokens(split, prompt, n, *, req_id, adapter=None, max_len=64,
                     wire_codec="fp16", n_slots=4):
    """The fault-free reference run, entirely in-process."""
    from repro.serving import CloudServer, LoopbackTransport

    server = CloudServer(split, n_slots=n_slots, max_len=max_len,
                         max_batch_tokens=128, wire_codec=wire_codec)
    client = _make_client(split, LoopbackTransport(server), adapter=adapter,
                          max_len=max_len, wire_codec=wire_codec)
    return list(client.generate(prompt, max_new_tokens=n, req_id=req_id))


def _through_proxy(cfg, host, port, schedule, **kw):
    from repro.net.transport import SocketTransport

    proxy = ChaosProxy(host, port, schedule=schedule)
    phost, pport = proxy.start()
    kw.setdefault("retry", RetryPolicy(max_attempts=6, base_s=0.02, seed=1))
    t = SocketTransport(phost, pport, d_model=cfg.d_model,
                        recv_timeout_s=60.0, **kw)
    return proxy, t


def test_drop_during_prefill_resumes_with_token_parity(dense_service):
    """Connection dies on the 2nd prefill chunk: the device must
    reconnect, resume via watermark, replay only the unprocessed uplink,
    and the token stream must match the fault-free run exactly."""
    cfg, split, svc, host, port = dense_service
    prompt = np.random.default_rng(0).integers(
        3, cfg.vocab_size, 24).astype(np.int32)      # 2 chunks of 16 + 8
    proxy, t = _through_proxy(
        cfg, host, port, {0: [FaultEvent("drop", at_hop=1, direction="up")]})
    try:
        client = _make_client(split, t)
        got = list(client.generate(prompt, max_new_tokens=3, req_id=101))
        t.shutdown()
    finally:
        proxy.stop()
    assert t.reconnects == 1
    assert t.replayed_frames >= 1
    assert [f["kind"] for f in proxy.faults] == ["drop"]
    assert got == _loopback_tokens(split, prompt, 3, req_id=101)
    assert len(got) == 3


def test_drop_on_downlink_replays_buffered_frame(dense_service):
    """Connection dies while the deep result is in flight cloud->device:
    the resume re-sends the buffered downlink frame — no token is lost,
    none is double-counted."""
    cfg, split, svc, host, port = dense_service
    prompt = np.random.default_rng(1).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    before = svc.frames_replayed
    proxy, t = _through_proxy(
        cfg, host, port, {0: [FaultEvent("drop", at_hop=0, direction="down")]})
    try:
        client = _make_client(split, t)
        got = list(client.generate(prompt, max_new_tokens=3, req_id=102))
        t.shutdown()
    finally:
        proxy.stop()
    assert t.reconnects == 1
    assert svc.frames_replayed > before              # cloud-side replay
    assert got == _loopback_tokens(split, prompt, 3, req_id=102)


def test_dup_and_delay_are_absorbed(dense_service):
    """Duplicated frames (both directions) and a delayed frame must be
    invisible to the token stream: watermark dedupe, not a double-step."""
    cfg, split, svc, host, port = dense_service
    prompt = np.random.default_rng(2).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    dup_before = svc.dup_frames_dropped
    proxy, t = _through_proxy(cfg, host, port, {0: [
        FaultEvent("dup", at_hop=0, direction="up"),
        FaultEvent("dup", at_hop=0, direction="down"),
        FaultEvent("delay", at_hop=1, direction="up", delay_s=0.05),
    ]})
    try:
        client = _make_client(split, t)
        got = list(client.generate(prompt, max_new_tokens=3, req_id=103))
        t.shutdown()
    finally:
        proxy.stop()
    assert t.reconnects == 0                         # nothing dropped
    assert svc.dup_frames_dropped > dup_before       # uplink dup eaten
    assert t.dup_frames_dropped >= 1                 # downlink dup eaten
    assert len(proxy.faults) == 3
    assert got == _loopback_tokens(split, prompt, 3, req_id=103)


def test_drop_during_verify_strip_ssm_arch():
    """Mid-decode drop on an SSM arch with adapter drafting: the verify
    strip is replayed against the slot's surviving recurrent state (the
    SSM state never crossed the wire), so tokens stay byte-identical."""
    import jax

    from repro.core import init_adapter, split_model
    from repro.net.service import CloudService
    from repro.serving import CloudServer

    cfg, _, params = reduced_model(SSM_ARCH)
    split = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(3))
    prompt = np.random.default_rng(3).integers(
        3, cfg.vocab_size, 16).astype(np.int32)      # 1 prefill chunk

    server = CloudServer(split, n_slots=2, max_len=128, max_batch_tokens=128,
                         wire_codec="fp32")
    svc = CloudService(server)
    host, port = svc.start()
    # up hop 0 is the prefill chunk; hop 1 is the first verify strip
    proxy, t = _through_proxy(
        cfg, host, port, {0: [FaultEvent("drop", at_hop=1, direction="up")]})
    try:
        client = _make_client(split, t, adapter=adapter, max_len=128,
                              wire_codec="fp32")
        got = list(client.generate(prompt, max_new_tokens=8, req_id=104))
        t.shutdown()
    finally:
        proxy.stop()
        svc.stop()
    assert t.reconnects == 1
    assert [f["kind"] for f in proxy.faults] == ["drop"]
    assert got == _loopback_tokens(split, prompt, 8, req_id=104,
                                   adapter=adapter, max_len=128,
                                   wire_codec="fp32", n_slots=2)
    assert len(got) == 8


def test_retry_disabled_first_drop_is_fatal(dense_service):
    """max_attempts=0 restores the pre-v2 contract: the drop surfaces as
    a TransportError instead of a silent reconnect."""
    cfg, split, svc, host, port = dense_service
    prompt = np.random.default_rng(4).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    proxy, t = _through_proxy(
        cfg, host, port, {0: [FaultEvent("drop", at_hop=0, direction="up")]},
        retry=RetryPolicy(max_attempts=0))
    try:
        client = _make_client(split, t)
        with pytest.raises(TransportError):
            list(client.generate(prompt, max_new_tokens=3, req_id=105))
    finally:
        proxy.stop()
    assert t.reconnects == 0


def test_grace_expiry_surfaces_session_lost_with_partial_tokens():
    """If the device stays away past grace_s the cloud reaps the slot; the
    resume omits the session and the device gets SessionLostError carrying
    every token generated before the drop — not a hang, not a crash."""
    from repro.core import split_model
    from repro.net.service import CloudService
    from repro.serving import CloudServer

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    server = CloudServer(split, n_slots=2, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server, grace_s=0.05)
    host, port = svc.start()
    prompt = np.random.default_rng(5).integers(
        3, cfg.vocab_size, 16).astype(np.int32)
    # backoff (~0.4s) far exceeds grace_s: the session is gone on resume.
    # Up hop 0 = prefill -> 1 token out; hop 2 = 3rd round, so >= 2 tokens
    # have been emitted when the link dies.
    proxy, t = _through_proxy(
        cfg, host, port, {0: [FaultEvent("drop", at_hop=2, direction="up")]},
        retry=RetryPolicy(max_attempts=3, base_s=0.4, seed=0))
    got = []
    try:
        client = _make_client(split, t)
        with pytest.raises(SessionLostError) as ei:
            for tok in client.generate(prompt, max_new_tokens=6, req_id=106):
                got.append(tok)
        t.shutdown()
    finally:
        proxy.stop()
        svc.stop()
    assert ei.value.req_id == 106
    assert t.reconnects == 1                         # reconnect succeeded...
    assert ei.value.partial_tokens == got            # ...the session did not
    assert len(got) >= 2
    # the partial stream is a prefix of the fault-free one
    assert got == _loopback_tokens(split, prompt, 6, req_id=106)[:len(got)]


def test_fleet_metrics_reconnects_match_fault_schedule(dense_service):
    """FleetMetrics.summary must report reconnects/replayed_frames
    consistent with the injected (seeded) fault schedule."""
    from repro.serving.request import FleetMetrics, Request

    cfg, split, svc, host, port = dense_service
    prompt = np.random.default_rng(6).integers(
        3, cfg.vocab_size, 24).astype(np.int32)
    schedule = seeded_schedule(7, connections=1, drops_per_conn=2, max_hop=1)
    n_drops = sum(len(v) for v in schedule.values())
    assert n_drops == 2
    proxy, t = _through_proxy(cfg, host, port, schedule)
    try:
        client = _make_client(split, t)
        req = Request(req_id=107, device_id=0, arrival_s=t.clock(),
                      prompt_len=len(prompt), max_new_tokens=3, prompt=prompt)
        for tok in client.generate(prompt, max_new_tokens=3, req_id=107):
            req.emit_tokens([tok], t.clock())
        t.shutdown()
    finally:
        proxy.stop()
    assert len(proxy.faults) == n_drops              # every drop fired
    # a drop can strike while a recovery replays, folding two drops into
    # one recovery cycle — reconnects is within [1, n_drops], never more
    assert 1 <= t.reconnects <= n_drops
    assert t.replayed_frames >= 1

    m = FleetMetrics()
    m.add(req)
    m.record_transport(t)
    s = m.summary()
    assert s["reconnects"] == t.reconnects
    assert s["replayed_frames"] == t.replayed_frames
    assert s["requests_degraded"] == 0
    assert req.generated == _loopback_tokens(split, prompt, 3, req_id=107)


def test_backpressure_sends_busy_at_inflight_cap():
    """With a 1-frame in-flight window and a slow step, the 2nd uplink
    must trigger MSG_BUSY; both frames are still served in order."""
    from repro.core import split_model
    from repro.net.service import CloudService
    from repro.net.transport import SocketTransport
    from repro.serving import CloudServer
    from repro.wire import encode_hidden, get_codec

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    server = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server, max_inflight_frames=1)
    # slow the engine step down so the 2nd frame reliably arrives while
    # the 1st is still in flight (the window fills deterministically)
    real_step = server.engine.step

    def slow_step():
        time.sleep(0.3)
        return real_step()

    server.engine.step = slow_step
    host, port = svc.start()
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=60.0)
    try:
        codec = get_codec("fp16")
        for rid in (108, 109):
            t.open(rid, 8)
        # both frames back to back: the 2nd hits the reader while the 1st
        # is still inside the slowed step, so the window is full
        for rid in (108, 109):
            t.send(encode_hidden(
                codec, np.zeros((4, cfg.d_model), np.float32),
                req_id=rid, offset=0, kind="prefill"))
        assert t.recv(108, timeout=60.0)             # both still served
        assert t.recv(109, timeout=60.0)
        assert t.busy_signals >= 1                   # the cloud pushed back
        t.close(108)
        t.close(109)
        t.shutdown()
    finally:
        svc.stop()


def test_connection_cap_rejects_with_typed_busy():
    """Connections past max_connections get a typed ERR_BUSY + close, not
    a reader thread."""
    from repro.core import split_model
    from repro.net.service import CloudService
    from repro.net.transport import SocketTransport
    from repro.serving import CloudServer

    cfg, _, params = reduced_model(ARCH)
    split = split_model(cfg, params)
    server = CloudServer(split, n_slots=2, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server, max_connections=1)
    host, port = svc.start()
    t1 = None
    try:
        t1 = SocketTransport(host, port, d_model=cfg.d_model)
        with pytest.raises(ProtocolError, match="connection limit"):
            SocketTransport(host, port, d_model=cfg.d_model,
                            recv_timeout_s=5.0)
        assert svc.conns_rejected == 1
    finally:
        if t1 is not None:
            t1.shutdown()
        svc.stop()
