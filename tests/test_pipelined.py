"""Pipelined chunk uplink: planner, ack window, coalescing, simulator.

The tentpole guarantees:
  * the §4.2 overlap planner (``pipelined_prefill_time``) is exactly the
    serialized sum at depth 1 and monotonically no worse as the window
    widens;
  * ``LoopbackTransport`` observes real processed-frame watermarks, so
    the bounded window is enforced in-process too — and token streams
    are byte-identical at every depth (the window reorders *waiting*,
    never computation);
  * cloud-side prefill coalescing only merges what a window lets pile up
    (depth 1 coalesces nothing);
  * the discrete-event simulator models the same overlap: deeper windows
    never lose TTFT on an uplink-bound link;
  * overlapping phase spans still tile TTFT (earliest-start attribution).
"""
import numpy as np
import pytest

from conftest import reduced_model
from repro.core import split_model
from repro.core.chunking import (
    chunk_prompt,
    optimal_chunk_size_pipelined,
    pipelined_prefill_time,
    plan_chunks,
)
from repro.net.errors import TransportError
from repro.serving import (
    CloudServer,
    DeviceClient,
    LoopbackTransport,
    ServeConfig,
    SimulatorRuntime,
)

ARCH = "internlm2-1.8b"


# ---------------------------------------------------------------------------
# planner (no models needed)
# ---------------------------------------------------------------------------


def test_pipelined_time_depth1_is_serialized_sum():
    chunks = [16, 16, 16, 8]
    up = lambda x: 0.1 * x
    step = lambda x: 0.05 * x
    t1 = pipelined_prefill_time(chunks, up_time=up, step_time=step,
                                pipeline_depth=1)
    assert t1 == pytest.approx(sum(up(c) + step(c) for c in chunks))


def test_pipelined_time_monotone_in_depth():
    chunks = chunk_prompt(256, 32)
    up = lambda x: 0.002 * x
    step = lambda x: 0.001 * x + 0.01
    times = [
        pipelined_prefill_time(chunks, up_time=up, step_time=step,
                               pipeline_depth=d)
        for d in (1, 2, 4, 0)          # 0 = unbounded window
    ]
    assert times == sorted(times, reverse=True) or all(
        a >= b - 1e-12 for a, b in zip(times, times[1:])
    )
    # with >1 chunk and nonzero step time the overlap must actually win
    assert times[-1] < times[0]


def test_pipelined_solver_beats_eq3_plan_under_overlap():
    """The depth-aware solver's plan never finishes later than the plan it
    replaces, measured by the overlapped delay model itself."""
    g = lambda mu: 0.004 * mu + 0.02
    common = dict(prompt_len=512, hidden_bytes_per_token=8192.0,
                  beta_up=5e6, g=g, mu=64.0, min_chunk=8, align=8)
    up = lambda x: x * 8192.0 / 5e6
    step = lambda x: g(64.0) + g(64.0 + x)
    for depth in (1, 2, 4):
        x = optimal_chunk_size_pipelined(pipeline_depth=depth, **common)
        assert x % 8 == 0 and 8 <= x <= 512
        t = pipelined_prefill_time(chunk_prompt(512, x), up_time=up,
                                   step_time=step, pipeline_depth=depth)
        for other in (64, 128, 256, 512):
            t_other = pipelined_prefill_time(
                chunk_prompt(512, other), up_time=up, step_time=step,
                pipeline_depth=depth)
            assert t <= t_other + 1e-12


def test_plan_chunks_accepts_depth_and_covers_prompt():
    g = lambda mu: 0.004 * mu + 0.02
    for depth in (0, 1, 3):
        chunks = plan_chunks(
            200, pc="device", dynamic_chunks=True, fixed_chunk=128,
            hidden_bytes_per_token=8192.0, beta_up=5e6, g=g, mu=32.0,
            pipeline_depth=depth,
        )
        assert sum(chunks) == 200 and all(c > 0 for c in chunks)


# ---------------------------------------------------------------------------
# loopback window + parity + coalescing
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg, model, params = reduced_model(ARCH)
    return cfg, split_model(cfg, params)


def _generate(split, *, depth, coalesce=False, prompt_len=48, new_tokens=3):
    server = CloudServer(split, n_slots=4, max_len=128,
                         max_batch_tokens=256, wire_codec="fp16")
    server.engine.coalesce_prefill = coalesce
    transport = LoopbackTransport(server)
    client = DeviceClient(split, transport, sd=None, max_len=128,
                          wire_codec="fp16", fixed_chunk=16,
                          dynamic_chunks=False, pipeline_depth=depth)
    rng = np.random.default_rng(0)
    prompt = rng.integers(3, 100, prompt_len).astype(np.int32)
    toks = list(client.generate(prompt, max_new_tokens=new_tokens, req_id=1))
    return toks, server


def test_token_parity_across_depths(setup):
    _, split = setup
    base, _ = _generate(split, depth=0)
    assert len(base) == 3
    for depth in (1, 2, 4):
        toks, _ = _generate(split, depth=depth)
        assert toks == base, f"depth {depth} diverged"


def test_coalescing_gated_by_window(setup):
    _, split = setup
    # depth 1 admits one unprocessed chunk at a time: nothing to merge
    toks1, server1 = _generate(split, depth=1, coalesce=True)
    assert server1.engine.frames_coalesced == 0
    # unbounded streaming piles all prefill chunks up before the first
    # pump, so the contiguous run merges (3 chunks of 48/16 fold into 1)
    toks0, server0 = _generate(split, depth=0, coalesce=True)
    assert server0.engine.frames_coalesced >= 2
    assert toks0 == toks1


def test_loopback_acks_observable(setup):
    _, split = setup
    server = CloudServer(split, n_slots=4, max_len=128,
                         max_batch_tokens=256, wire_codec="fp16")
    transport = LoopbackTransport(server)
    transport.open(7, 16)
    assert transport.acked_count(7) == 0
    assert transport.wait_acked(7, 0) == 0          # satisfied, no pump
    with pytest.raises(TransportError, match="ack starved"):
        transport.wait_acked(7, 3)                  # nothing ever submitted


# ---------------------------------------------------------------------------
# simulator models the same overlap
# ---------------------------------------------------------------------------


def _sim_ttfts(depth):
    from repro.data import RequestSpec

    cfg = ServeConfig.hat(
        dynamic_chunks=False, fixed_chunk=128, pipeline_depth=depth,
        uplink_bps=2e6, n_devices=1,            # uplink-bound link
    )
    rt = SimulatorRuntime(cfg, rng=np.random.default_rng(0))
    # one request: with several requests sharing the device's uplink, the
    # link saturates and another request's chunks fill any ack-wait gap, so
    # TTFT ties across depths — the window only shows on an idle link
    reqs = [RequestSpec(req_id=0, device_id=0, arrival_s=0.0,
                        prompt_len=512, max_new_tokens=2)]
    m = rt.serve(reqs)
    return sorted(r.ttft_s for r in m.requests)


def test_simulator_window_gates_uplink():
    t1, t2, t0 = _sim_ttfts(1), _sim_ttfts(2), _sim_ttfts(0)
    # deeper windows never lose on an uplink-bound link, and depth 1's
    # ack-wait gap (one cloud stage per chunk) must actually cost something
    for a, b in zip(t2, t1):
        assert a <= b + 1e-9
    for a, b in zip(t0, t2):
        assert a <= b + 1e-9
    assert t2[0] < t1[0]


# ---------------------------------------------------------------------------
# overlapping spans still tile TTFT
# ---------------------------------------------------------------------------


def test_phase_breakdown_overlap_attributed_once():
    from repro.obs import Tracer

    tr = Tracer()
    tr.add_span("uplink", 0.0, 2.0, tid=1, phase="uplink")
    tr.add_span("cloud_step", 1.0, 3.0, tid=1, phase="cloud_step")  # overlaps
    tr.add_span("draft", 3.0, 3.5, tid=1, phase="draft")
    bd = tr.phase_breakdown(1, until=3.5)
    assert bd["uplink"] == pytest.approx(2.0)       # earliest start wins
    assert bd["cloud_step"] == pytest.approx(1.0)   # only the tail counts
    assert bd["draft"] == pytest.approx(0.5)
    assert sum(bd.values()) == pytest.approx(3.5)   # tiles the clock
