"""U-shaped split + adapter/distillation correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.core import (
    DraftModel,
    adapter_param_count,
    derive_configs,
    init_adapter,
    make_distill_step,
    split_model,
)
from repro.training import AdamW
from conftest import reduced_model

TOL = 2e-4


def _memory(cfg, model, params, key, B):
    if cfg.frontend == "vision":
        return jax.random.normal(key, (B, 8, cfg.d_model))
    if cfg.is_encoder_decoder:
        return model.encode(params, jax.random.normal(key, (B, 8, cfg.d_model)))
    return None


@pytest.mark.parametrize("arch", ASSIGNED)
def test_split_equals_full(arch, key):
    cfg, model, params = reduced_model(arch)
    sp = split_model(cfg, params)
    B, T = 2, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    memory = _memory(cfg, model, params, key, B)
    full, _, _ = model.apply(params, tokens, memory=memory)
    shallow, _, _ = sp.device_forward(tokens, memory=memory)
    deep, _, _ = sp.middle_forward(shallow, memory=memory)
    err = float(jnp.max(jnp.abs(full - sp.head_logits(deep))))
    assert err < TOL, f"{arch}: split path diverges by {err}"


def test_derive_configs_partition():
    from repro.configs import get_config

    cfg = get_config("gemma3-12b")
    cin, cmid = derive_configs(cfg)
    assert cin.n_layers + cmid.n_layers == cfg.n_layers
    assert cin.layers == cfg.layers[: cfg.hat_shallow_layers]
    assert cmid.layers == cfg.layers[cfg.hat_shallow_layers:]
    assert not cmid.include_embed and not cmid.include_head


def test_adapter_is_lightweight():
    from repro.configs import get_config

    for arch, medusa_ratio in (("vicuna-7b", 5), ("vicuna-13b", 5)):
        cfg = get_config(arch)
        n_adapter = adapter_param_count(cfg)
        # Table 4: HAT trains ~1 order of magnitude fewer params than Medusa
        from repro.serving import medusa_param_count

        assert n_adapter * medusa_ratio < medusa_param_count(cfg)
        assert n_adapter < 0.03 * cfg.param_count()


def test_draft_model_shapes(key):
    cfg, model, params = reduced_model("internlm2-1.8b")
    sp = split_model(cfg, params)
    ad, _ = init_adapter(cfg, key)
    dm = DraftModel(sp, ad)
    cache = dm.init_cache(1, 32)
    logits, cache, shallow = dm.forward(
        jax.random.randint(key, (1, 5), 0, cfg.vocab_size), cache=cache, offset=0
    )
    assert logits.shape == (1, 5, cfg.vocab_size)
    assert shallow.shape == (1, 5, cfg.d_model)


def test_distillation_improves_agreement(key, rng):
    from repro.data import markov_corpus, token_batches
    from repro.training import train_loop
    from repro.models import Model
    from repro.configs import get_config

    cfg = get_config("internlm2-1.8b").reduced()
    model = Model(cfg)
    params = model.init(key)
    corpus = markov_corpus(rng, cfg.vocab_size, 12_000)
    params, _ = train_loop(model, params, AdamW(lr=3e-3),
                           token_batches(rng, corpus, 8, 32),
                           max_steps=30, log_every=0)
    sp = split_model(cfg, params)
    ad, _ = init_adapter(cfg, jax.random.fold_in(key, 3))
    opt = AdamW(lr=1e-3)
    step = make_distill_step(sp, model, params, opt)
    ost = opt.init(ad)
    first = None
    for i, b in zip(range(60), token_batches(rng, corpus, 8, 32)):
        ad, ost, metrics = step(ad, ost, jnp.asarray(b["tokens"][:, :32]))
        first = first or {k: float(v) for k, v in metrics.items()}
    assert float(metrics["loss"]) < first["loss"] * 0.7
    assert float(metrics["agree"]) > first["agree"]
