import os
import sys

# NOTE: deliberately NOT forcing xla_force_host_platform_device_count here —
# tests must see the real single CPU device (the 512-device override belongs
# exclusively to repro.launch.dryrun).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="module", autouse=True)
def _release_compiled_executables():
    """Clear jax's compiled-executable caches after every test module.

    The suite jits hundreds of distinct programs (per-arch models × step
    buckets × codecs); letting them all accumulate in one process has been
    observed to segfault XLA:CPU's compiler late in the run (deep in
    ``backend_compile``).  Dropping executables between modules trades a
    little recompilation for a bounded compiler footprint.
    """
    yield
    jax.clear_caches()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


_PARAM_CACHE = {}


def reduced_model(arch: str):
    """Session-cached (cfg, model, params) for a reduced config."""
    if arch not in _PARAM_CACHE:
        from repro.configs import get_config
        from repro.models import Model

        cfg = get_config(arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        _PARAM_CACHE[arch] = (cfg, model, params)
    return _PARAM_CACHE[arch]


@pytest.fixture
def make_reduced():
    return reduced_model
