"""repro.net: stream protocol, socket transport, and the cloud service.

Three layers of coverage, cheapest first:

* pure protocol units (StreamDecoder over torn/interleaved/hostile byte
  streams, message codecs) — no sockets, no JAX;
* a stdlib *fake* cloud (threaded socketpair/TCP server speaking just the
  control protocol) for handshake, timeout, and typed-error paths without
  building a model;
* one real in-process :class:`~repro.net.service.CloudService` wrapping a
  reduced-model engine: token parity vs ``LoopbackTransport``, overflow
  propagation, snapshot/restore over the wire.

Hypothesis property tests for the framing live in ``test_properties.py``
with the repo's other hypothesis suites (skipped when hypothesis is not
installed); the deterministic edge cases here always run.
"""
import json
import socket
import struct
import threading
import time

import numpy as np
import pytest

from conftest import reduced_model
from repro.net import protocol as P
from repro.net.errors import (
    ProtocolError,
    RemoteEngineError,
    TransportClosed,
    TransportError,
    TransportTimeout,
)
from repro.net.protocol import StreamDecoder, iter_messages


# ---------------------------------------------------------------------------
# protocol units
# ---------------------------------------------------------------------------


def _sample_messages():
    return [
        (P.MSG_HELLO, P.encode_hello(256)),
        (P.MSG_OPEN, P.encode_u32_pair(7, 40)),
        (P.MSG_OPEN_OK, P.encode_u32(7)),
        (P.MSG_FRAME, b"\x00" * 313),          # opaque payload to the envelope
        (P.MSG_ERROR, P.encode_error(P.ERR_OVERFLOW, 7, "slot overflow")),
        (P.MSG_BYE, b""),
    ]


def test_roundtrip_single_feed():
    msgs = _sample_messages()
    stream = b"".join(P.encode_msg(t, p) for t, p in msgs)
    assert list(iter_messages(stream)) == msgs


def test_roundtrip_every_split_point():
    """Any torn read must reassemble: split the stream at every boundary."""
    msgs = _sample_messages()[:3]
    stream = b"".join(P.encode_msg(t, p) for t, p in msgs)
    for cut in range(len(stream) + 1):
        dec = StreamDecoder()
        got = dec.feed(stream[:cut]) + dec.feed(stream[cut:])
        assert got == msgs, f"split at {cut} broke reassembly"
        assert dec.pending_bytes == 0


def test_roundtrip_byte_at_a_time_and_coalesced():
    msgs = _sample_messages()
    stream = b"".join(P.encode_msg(t, p) for t, p in msgs)
    dec = StreamDecoder()
    got = []
    for i in range(len(stream)):
        got.extend(dec.feed(stream[i:i + 1]))
    assert got == msgs
    # and the same stream twice in one chunk: interleaved completion
    dec = StreamDecoder()
    assert dec.feed(stream + stream) == msgs + msgs


def test_random_chunking_matches(rng):
    msgs = _sample_messages() * 5
    stream = b"".join(P.encode_msg(t, p) for t, p in msgs)
    for _ in range(25):
        cuts = np.sort(rng.integers(0, len(stream) + 1, size=9))
        dec = StreamDecoder()
        got = []
        prev = 0
        for c in list(cuts) + [len(stream)]:
            got.extend(dec.feed(stream[prev:c]))
            prev = c
        assert got == msgs
        assert dec.pending_bytes == 0


def test_bad_magic_rejected():
    with pytest.raises(ProtocolError, match="magic"):
        StreamDecoder().feed(b"XX" + b"\x00" * 20)


def test_unknown_type_rejected():
    bad = struct.pack("<2sBI", P.MAGIC, 99, 0)
    with pytest.raises(ProtocolError, match="unknown message type"):
        StreamDecoder().feed(bad)


def test_oversized_length_rejected_before_buffering():
    dec = StreamDecoder(max_message_bytes=1024)
    huge = struct.pack("<2sBI", P.MAGIC, P.MSG_FRAME, 1 << 30)
    with pytest.raises(ProtocolError, match="exceeds"):
        dec.feed(huge)          # rejected on the header alone, no payload read


def test_trailing_partial_is_an_error_for_complete_streams():
    stream = P.encode_msg(P.MSG_BYE) + b"HN"
    with pytest.raises(ProtocolError, match="trailing"):
        list(iter_messages(stream))


def test_error_codec_roundtrip():
    code, rid, msg = P.decode_error(P.encode_error(P.ERR_REJECTED, 41, "no slot"))
    assert (code, rid, msg) == (P.ERR_REJECTED, 41, "no slot")
    with pytest.raises(ProtocolError):
        P.decode_error(b"\x00")
    with pytest.raises(ProtocolError):
        P.decode_hello(b"\x00\x01")


def test_socketpair_roundtrip():
    """The decoder against a real kernel byte stream, odd-sized writes."""
    a, b = socket.socketpair()
    try:
        msgs = _sample_messages()
        stream = b"".join(P.encode_msg(t, p) for t, p in msgs)
        for i in range(0, len(stream), 13):
            a.sendall(stream[i:i + 13])
        a.shutdown(socket.SHUT_WR)
        dec = StreamDecoder()
        got = []
        while True:
            chunk = b.recv(4096)
            if not chunk:
                break
            got.extend(dec.feed(chunk))
        assert got == msgs
        assert dec.pending_bytes == 0
    finally:
        a.close()
        b.close()


# ---------------------------------------------------------------------------
# fake cloud: handshake / timeout / typed errors, no JAX
# ---------------------------------------------------------------------------


class _FakeCloud:
    """Minimal control-plane server: acks hello and opens, then follows a
    script — deliver nothing (timeout tests) or inject typed errors."""

    def __init__(self, *, d_model=64, accept_hello=True, error_after_open=None):
        self.d_model = d_model
        self.accept_hello = accept_hello
        self.error_after_open = error_after_open     # (code, req_id, msg)
        self._ls = socket.create_server(("127.0.0.1", 0))
        self.port = self._ls.getsockname()[1]
        self._t = threading.Thread(target=self._serve, daemon=True)
        self._t.start()

    def _serve(self):
        sock, _ = self._ls.accept()
        dec = StreamDecoder()
        with sock:
            while True:
                try:
                    chunk = sock.recv(1 << 16)
                except OSError:
                    return
                if not chunk:
                    return
                for mtype, payload in dec.feed(chunk):
                    if mtype == P.MSG_HELLO:
                        if self.accept_hello:
                            sock.sendall(P.encode_msg(
                                P.MSG_HELLO_ACK, P.encode_hello(self.d_model)))
                        else:
                            sock.sendall(P.encode_msg(P.MSG_ERROR, P.encode_error(
                                P.ERR_VERSION, 0, "speak something else")))
                            return
                    elif mtype == P.MSG_OPEN:
                        rid, _ = P.decode_u32_pair(payload)
                        sock.sendall(P.encode_msg(P.MSG_OPEN_OK, P.encode_u32(rid)))
                        if self.error_after_open is not None:
                            code, erid, msg = self.error_after_open
                            sock.sendall(P.encode_msg(
                                P.MSG_ERROR, P.encode_error(code, erid, msg)))
                    elif mtype == P.MSG_BYE:
                        return

    def close(self):
        self._ls.close()


@pytest.fixture
def make_transport():
    from repro.net.transport import SocketTransport

    made = []

    def make(cloud, **kw):
        kw.setdefault("d_model", cloud.d_model)
        kw.setdefault("connect_timeout_s", 5.0)
        t = SocketTransport("127.0.0.1", cloud.port, **kw)
        made.append((t, cloud))
        return t

    yield make
    for t, cloud in made:
        t.shutdown()
        cloud.close()


def test_handshake_ok_and_open(make_transport):
    t = make_transport(_FakeCloud())
    t.open(5, 16)                                    # OPEN_OK consumed


def test_hello_version_mismatch_raises(make_transport):
    cloud = _FakeCloud(accept_hello=False)
    with pytest.raises(ProtocolError, match="version|speak"):
        make_transport(cloud)
    cloud.close()


def test_hello_d_model_mismatch_raises(make_transport):
    cloud = _FakeCloud(d_model=64)
    with pytest.raises(ProtocolError, match="mismatch"):
        make_transport(cloud, d_model=128)
    cloud.close()


def test_connect_retry_gives_up():
    from repro.net.transport import SocketTransport

    # a bound-but-never-accepting port is hard to fake portably; a closed
    # port exercises the same retry loop
    ls = socket.create_server(("127.0.0.1", 0))
    port = ls.getsockname()[1]
    ls.close()
    t0 = time.monotonic()
    with pytest.raises(TransportError, match="could not connect"):
        SocketTransport("127.0.0.1", port, d_model=8,
                        connect_timeout_s=0.3, retry_interval_s=0.02)
    assert time.monotonic() - t0 >= 0.25             # it really retried


def test_recv_timeout_raises_transport_timeout(make_transport):
    """Regression: a cloud that never delivers must raise, not hang."""
    t = make_transport(_FakeCloud())
    t.open(5, 16)
    t0 = time.monotonic()
    with pytest.raises(TransportTimeout) as ei:
        t.recv(5, timeout=0.4)
    assert 0.3 <= time.monotonic() - t0 < 5.0
    assert ei.value.req_id == 5
    assert isinstance(ei.value, TransportError)      # one except to rule them
    assert isinstance(ei.value, TimeoutError)        # and stdlib-idiomatic


def test_typed_error_frame_releases_waiting_recv(make_transport):
    """An ERR_OVERFLOW for our req must surface as RemoteEngineError from
    the blocking recv immediately — the session unwinds instead of timing
    out."""
    t = make_transport(_FakeCloud(
        error_after_open=(P.ERR_OVERFLOW, 9, "job past max_len; slot released")))
    t.open(9, 16)
    with pytest.raises(RemoteEngineError) as ei:
        t.recv(9, timeout=30.0)                      # returns in ms, not 30 s
    assert ei.value.code == P.ERR_OVERFLOW
    assert ei.value.req_id == 9
    assert "slot released" in ei.value.remote_message


def test_error_for_other_req_does_not_poison(make_transport):
    t = make_transport(_FakeCloud(
        error_after_open=(P.ERR_OVERFLOW, 777, "someone else")))
    t.open(5, 16)
    with pytest.raises(TransportTimeout):            # our req just times out
        t.recv(5, timeout=0.3)
    with pytest.raises(RemoteEngineError):           # theirs carries the error
        t.recv(777, timeout=0.3)


# ---------------------------------------------------------------------------
# real engine behind a real socket (reduced model, in-process service)
# ---------------------------------------------------------------------------

ARCH = "internlm2-1.8b"


@pytest.fixture(scope="module")
def live_service():
    from repro.net.service import CloudService
    from repro.serving import CloudServer

    cfg, _, params = reduced_model(ARCH)
    from repro.core import split_model

    split = split_model(cfg, params)
    server = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                         wire_codec="fp16")
    svc = CloudService(server)
    host, port = svc.start()
    yield cfg, split, svc, host, port
    svc.stop()


def _make_client(cfg, split, transport):
    from repro.serving import DeviceClient

    return DeviceClient(split, transport, sd=None, max_len=64,
                        wire_codec="fp16", fixed_chunk=16,
                        dynamic_chunks=False)


def test_socket_token_parity_with_loopback(live_service):
    from repro.net.transport import SocketTransport
    from repro.serving import CloudServer, DeviceClient, LoopbackTransport

    cfg, split, svc, host, port = live_service
    rng = np.random.default_rng(3)
    prompts = [rng.integers(3, cfg.vocab_size, n).astype(np.int32)
               for n in (11, 23)]

    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=60.0)
    client = _make_client(cfg, split, t)
    over_socket = [list(client.generate(p, max_new_tokens=3, req_id=i + 1))
                   for i, p in enumerate(prompts)]
    t.shutdown()

    server2 = CloudServer(split, n_slots=4, max_len=64, max_batch_tokens=128,
                          wire_codec="fp16")
    lt = LoopbackTransport(server2)
    client2 = _make_client(cfg, split, lt)
    over_loopback = [list(client2.generate(p, max_new_tokens=3, req_id=i + 1))
                     for i, p in enumerate(prompts)]

    assert over_socket == over_loopback
    assert all(len(toks) == 3 for toks in over_socket)


def test_engine_overflow_crosses_the_wire(live_service):
    """A frame past the slot's max_len must come back as a typed
    RemoteEngineError (ERR_OVERFLOW), and the slot must be reusable."""
    from repro.net.transport import SocketTransport
    from repro.wire import encode_hidden, get_codec

    cfg, split, svc, host, port = live_service
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=30.0)
    t.open(901, 8)
    bad = encode_hidden(get_codec("fp16"),
                        np.zeros((8, cfg.d_model), np.float32),
                        req_id=901, offset=1000, kind="prefill")  # 1000 >> 64
    t.send(bad)
    with pytest.raises(RemoteEngineError) as ei:
        t.recv(901, timeout=30.0)
    assert ei.value.code == P.ERR_OVERFLOW
    # the engine released the slot: a fresh session still opens + serves
    t.open(902, 8)
    t.close(902)
    t.shutdown()


def test_snapshot_restore_over_wire(live_service):
    from repro.net.transport import SocketTransport
    from repro.wire import encode_hidden, get_codec

    cfg, split, svc, host, port = live_service
    t = SocketTransport(host, port, d_model=cfg.d_model, recv_timeout_s=60.0)
    t.open(911, 8)
    frame = encode_hidden(get_codec("fp16"),
                          np.zeros((4, cfg.d_model), np.float32),
                          req_id=911, offset=0, kind="prefill")
    t.send(frame)
    t.recv(911, timeout=60.0)
    snap = t.snapshot(911)
    assert isinstance(snap, int)
    t.restore(911, snap)                             # RESTORE_OK or raise
    t.close(911)
    t.shutdown()


def test_loopback_starvation_is_transport_error(live_service):
    """Regression for the recv error-path satellite: the loopback transport
    now raises TransportError (still a RuntimeError) on starvation, and
    honors the new timeout parameter."""
    from repro.serving import CloudServer, LoopbackTransport

    cfg, split, _, _, _ = live_service
    server = CloudServer(split, n_slots=2, max_len=64, wire_codec="fp16")
    lt = LoopbackTransport(server)
    with pytest.raises(TransportError, match="starved"):
        lt.recv(1)
    with pytest.raises(RuntimeError):                # old except clauses hold
        lt.recv(1)
    with pytest.raises(TransportTimeout):
        lt.recv(1, timeout=0.0)                      # deadline beats the pump


# ---------------------------------------------------------------------------
# trace merging (the multi-process observability contract)
# ---------------------------------------------------------------------------


def _tiny_trace(offset=0.0):
    from repro.obs import Tracer, to_chrome_trace

    tr = Tracer()
    tr.add_span("uplink", offset + 0.0, offset + 0.1, tid=1, phase="uplink")
    tr.add_span("cloud_step", offset + 0.1, offset + 0.3, tid=1,
                phase="cloud_step")
    return to_chrome_trace(tr)


def test_merge_chrome_traces_disjoint_pids():
    from repro.obs import MERGE_PID_STRIDE, merge_chrome_traces, \
        validate_chrome_trace

    merged = merge_chrome_traces(
        [_tiny_trace(), _tiny_trace(5.0), _tiny_trace(9.0)],
        ["cloud", "device0", "device1"],
    )
    validate_chrome_trace(merged)
    pids = {ev["pid"] for ev in merged["traceEvents"]}
    blocks = {pid // MERGE_PID_STRIDE for pid in pids}
    assert blocks == {0, 1, 2}                       # one pid block per input
    names = [ev["args"]["name"] for ev in merged["traceEvents"]
             if ev.get("ph") == "M" and ev.get("name") == "process_name"]
    assert any(n.startswith("cloud:") for n in names)
    assert any(n.startswith("device1:") for n in names)


def test_merge_rejects_label_mismatch_and_pid_overflow():
    from repro.obs import MERGE_PID_STRIDE, merge_chrome_traces

    with pytest.raises(ValueError, match="labels"):
        merge_chrome_traces([_tiny_trace()], ["a", "b"])
    big = _tiny_trace()
    for ev in big["traceEvents"]:
        ev["pid"] = MERGE_PID_STRIDE + 1
    with pytest.raises(ValueError, match="stride"):
        merge_chrome_traces([_tiny_trace(), big], ["ok", "bad"])


def test_render_trace_merges_files(tmp_path):
    import subprocess
    import sys
    import os

    paths = []
    for i, obj in enumerate([_tiny_trace(), _tiny_trace(3.0)]):
        p = tmp_path / f"t{i}.json"
        p.write_text(json.dumps(obj))
        paths.append(str(p))
    out = tmp_path / "merged.json"
    env = dict(os.environ)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src") \
        + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, os.path.join(root, "scripts", "render_trace.py"),
         *paths, "--merge-out", str(out)],
        capture_output=True, text=True, env=env,
    )
    assert res.returncode == 0, res.stderr
    from repro.obs import validate_chrome_trace

    validate_chrome_trace(json.loads(out.read_text()))
    assert "phase attribution" in res.stdout
