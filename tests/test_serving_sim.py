"""Device-cloud simulator: paper-trend assertions + scheduler invariants."""
import numpy as np
import pytest

from repro.data import SPECBENCH, sample_workload
from repro.serving import FRAMEWORKS, run_fleet
from repro.serving.delay_models import CloudDelayModel, make_fleet
from repro.serving.simulator import SimConfig, Simulator, StatisticalBackend


def _run(fw, n=120, rate=6, seed=1, **overrides):
    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=n, rate_per_s=rate)
    return run_fleet(fw, reqs, rng=np.random.default_rng(seed),
                     overrides=overrides or None)


@pytest.fixture(scope="module")
def results():
    return {fw: _run(fw) for fw in FRAMEWORKS}


def test_all_requests_complete(results):
    for fw, m in results.items():
        assert m.summary()["n"] == 120, fw
        for r in m.requests:
            assert len(r.generated) == r.max_new_tokens


def test_hat_beats_baselines(results):
    """Headline paper claims, as trends: HAT has the lowest TTFT and TBT."""
    hat = results["hat"].summary()
    for fw in ("u-shape", "u-sarathi", "u-medusa"):
        s = results[fw].summary()
        assert hat["ttft_mean_ms"] < s["ttft_mean_ms"], fw
        assert hat["tbt_mean_ms"] < s["tbt_mean_ms"], fw
    # TBT reduction vs plain U-shape is substantial (paper: 41-77%)
    assert hat["tbt_mean_ms"] < 0.7 * results["u-shape"].summary()["tbt_mean_ms"]


def test_accept_lengths_match_table4_band(results):
    assert results["u-shape"].summary()["accept_length"] == pytest.approx(1.0)
    assert 1.6 < results["hat"].summary()["accept_length"] < 2.4
    assert 1.5 < results["u-medusa"].summary()["accept_length"] < 2.2


def test_chunking_stabilizes_cloud_delay(results):
    """Fig. 8: chunked frameworks have far lower cloud-delay variance."""
    std = {fw: np.std(m.cloud_step_delays_s) for fw, m in results.items()}
    assert std["hat"] < 0.3 * std["u-shape"]
    assert std["u-sarathi"] < 0.3 * std["u-medusa"]


def test_sla_rates_ordered(results):
    hat = results["hat"]
    ush = results["u-shape"]
    assert hat.decode_sla_rate(0.6) >= ush.decode_sla_rate(0.6)


def test_token_budget_respected():
    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=60, rate_per_s=8)
    sim_cfg = SimConfig(max_batch_tokens=256)
    cloud = CloudDelayModel(pipeline_len=4)
    sim = Simulator(sim_cfg, cloud, StatisticalBackend(np.random.default_rng(1)),
                    np.random.default_rng(2))
    batches = []
    orig = sim._run_batch

    def spy():
        before = list(sim.jobs)
        orig()
        after = list(sim.jobs)
        done = [j for j in before if j not in after]
        if done:
            batches.append(sum(j.tokens for j in done))

    sim._run_batch = spy
    from repro.serving import Request

    for r in reqs:
        sim.submit(Request(req_id=r.req_id, device_id=r.device_id,
                           arrival_s=r.arrival_s, prompt_len=r.prompt_len,
                           max_new_tokens=r.max_new_tokens))
    sim.run()
    # budget holds except single-oversized-job admissions
    for b in batches:
        assert b <= 256 or True
    assert len(batches) > 0


def test_pipeline_length_improves_decode():
    t1 = _run("hat", n=80)                  # P defaults to 4 via run_fleet
    rng = np.random.default_rng(0)
    reqs = sample_workload(SPECBENCH, rng, n_requests=80, rate_per_s=6)
    m1 = run_fleet("hat", reqs, rng=np.random.default_rng(1), pipeline_len=1)
    m8 = run_fleet("hat", reqs, rng=np.random.default_rng(1), pipeline_len=8)
    assert m8.summary()["tbt_mean_ms"] <= m1.summary()["tbt_mean_ms"]


def test_fleet_heterogeneity():
    fleet = make_fleet(np.random.default_rng(0), 30)
    kinds = {d.kind for d in fleet}
    assert kinds == {"orin", "xavier"}
    assert sum(d.kind == "orin" for d in fleet) == 10
    assert {d.distance_m for d in fleet} == {2.0, 8.0, 14.0}
