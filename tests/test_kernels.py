"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs the
pure-jnp oracle (ref.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import attention_ref, prefill_attention, verify_attention

SHAPES_PREFILL = [
    # B, T, S, nh, nkv, hd, window
    (1, 16, 64, 4, 4, 32, None),
    (2, 24, 96, 4, 2, 32, None),
    (1, 128, 128, 8, 1, 16, None),
    (2, 17, 80, 6, 2, 64, None),      # non-multiple-of-block sizes
    (1, 32, 64, 4, 2, 32, 16),        # sliding window
]

SHAPES_VERIFY = [
    (1, 1, 64, 4, 4, 32, None),
    (2, 8, 256, 8, 2, 64, None),
    (1, 9, 130, 4, 1, 32, None),
    (2, 4, 96, 4, 4, 16, 24),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SHAPES_PREFILL)
def test_prefill_kernel_allclose(case, dtype):
    B, T, S, nh, nkv, hd, window = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd), dtype)
    off = S - T - 3
    vlen = off + T
    out = prefill_attention(q, k, v, off, vlen, window=window,
                            bq=8, bkv=16, interpret=True)
    ref = attention_ref(q, k, v, offset=off, valid_len=vlen, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("case", SHAPES_VERIFY)
def test_verify_kernel_allclose(case, dtype):
    B, T, S, nh, nkv, hd, window = case
    ks = jax.random.split(jax.random.PRNGKey(hash(case) % 2**31), 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, nkv, hd), dtype)
    off = S // 2
    vlen = off + T
    out = verify_attention(q, k, v, off, vlen, window=window,
                           bkv=32, interpret=True)
    ref = attention_ref(q, k, v, offset=off, valid_len=vlen, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)))) < tol


def test_attention_op_threshold_dispatch():
    """VERIFY_MAX_T routes short causal strips to the decode-shaped kernel
    and long chunks to the MXU-tiled prefill kernel."""
    from repro.kernels import VERIFY_MAX_T, attention_impl_for

    assert attention_impl_for(1) == "verify"
    assert attention_impl_for(VERIFY_MAX_T) == "verify"
    assert attention_impl_for(VERIFY_MAX_T + 1) == "prefill"
    assert attention_impl_for(4, causal=False) == "prefill"   # non-causal


@pytest.mark.parametrize("T", [4, 9, 32, 33, 48])
def test_attention_op_interpret_parity(T):
    """attention_op(impl='interpret') — whichever Pallas kernel the
    VERIFY_MAX_T threshold picks — matches the jnp oracle on both sides of
    the dispatch boundary."""
    from repro.kernels import attention_impl_for, attention_op

    B, S, nh, nkv, hd = 2, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(T), 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    off = S - T - 5
    vlen = off + T
    out = attention_op(q, k, v, off, vlen, impl="interpret")
    ref = attention_op(q, k, v, off, vlen, impl="reference")
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5, attention_impl_for(T)


def test_kernels_match_model_attention(key):
    """The kernel semantics equal the model's attend() on a cache snapshot."""
    from repro.models.layers import attend

    B, T, S, nh, nkv, hd = 2, 4, 48, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, T, nh, hd))
    k = jax.random.normal(ks[1], (B, S, nkv, hd))
    v = jax.random.normal(ks[2], (B, S, nkv, hd))
    off = 20
    out_kernel = verify_attention(q, k, v, off, off + T, interpret=True)
    pos = off + jnp.arange(T)
    out_model = attend(q, k, v, q_pos=pos, k_pos=jnp.arange(S))
    assert float(jnp.max(jnp.abs(out_kernel - out_model))) < 2e-5


@pytest.mark.parametrize("case", [(2, 37, 3, 8, 8), (1, 48, 2, 16, 16)])
def test_mlstm_chunk_kernel_allclose(case):
    """Pallas chunkwise mLSTM (interpret) vs the per-token oracle."""
    import numpy as np

    from repro.kernels import mlstm_chunk_kernel
    from repro.kernels.ref import mlstm_chunkwise_ref

    B, T, nh, hd, L = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case)), 5)
    q = jax.random.normal(ks[0], (B, T, nh, hd)) / np.sqrt(hd)
    k = jax.random.normal(ks[1], (B, T, nh, hd))
    v = jax.random.normal(ks[2], (B, T, nh, hd))
    ig = jax.random.normal(ks[3], (B, T, nh)) * 2
    fg = jax.random.normal(ks[4], (B, T, nh)) + 3
    ref_h, (C0, n0, m0) = mlstm_chunkwise_ref(q, k, v, ig, fg)
    h, (C, n, m) = mlstm_chunk_kernel(
        q, k, v, ig, fg,
        jnp.zeros((B, nh, hd, hd)), jnp.zeros((B, nh, hd)),
        jnp.full((B, nh), -1e30),
        chunk=L, interpret=True,
    )
    assert float(jnp.max(jnp.abs(h - ref_h))) < 1e-3
    assert float(jnp.max(jnp.abs(C - C0))) < 1e-3
