"""Flight recorder (repro.obs): tracing, export, monitor bridge, breakdown.

Load-bearing guarantees:
  * the Tracer is a bounded ring buffer; disabling recording keeps
    observers (the StateMonitorBridge) firing;
  * Chrome-trace export is schema-stable and structurally valid;
  * the bridge drives StateMonitor to the same EWMA state as the old
    direct call sites (tracing and monitoring cannot disagree);
  * transports stamp ``t_send`` on every uplink frame (wire v2 contract);
  * on a traced concurrent EngineRuntime run every request's per-phase
    TTFT breakdown sums to its measured TTFT within 1% — the spans tile
    the session clock — and tracing does not change emitted tokens.
"""
import numpy as np
import pytest

from conftest import reduced_model
from repro.core import StateMonitor, init_adapter, split_model
from repro.data import RequestSpec
from repro.obs import (
    NULL_TRACER,
    PHASES,
    PID_HOST,
    PID_VIRTUAL,
    TID_CLOUD,
    Tracer,
    attach_monitor,
    to_chrome_trace,
    validate_chrome_trace,
)
from repro.serving import (
    DelayModelTransport,
    EngineRuntime,
    FleetMetrics,
    LoopbackTransport,
    Request,
    ServeConfig,
    SimulatorRuntime,
)
from repro.serving.delay_models import DeviceProfile, NetworkModel
from repro.wire import Frame, encode_hidden, get_codec


# ---------------------------------------------------------------- tracer core


def test_tracer_ring_buffer_and_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.add_span("s", i, i + 0.5, tid=i)
    assert len(tr.events) == 4
    assert tr.dropped == 6
    assert [ev.tid for ev in tr.events] == [6, 7, 8, 9]   # oldest evicted


def test_disabled_tracer_records_nothing_but_notifies_observers():
    tr = Tracer(enabled=False)
    seen = []
    tr.subscribe(seen.append)
    tr.add_span("uplink", 0.0, 1.0, tid=3, nbytes=10)
    tr.instant("accept", 1.0, tid=3)
    assert len(tr.events) == 0
    assert [ev.name for ev in seen] == ["uplink", "accept"]


def test_span_context_manager_attaches_result_attrs():
    tr = Tracer()
    with tr.span("jit_step", tid=TID_CLOUD) as a:
        a["tokens"] = 42
    (ev,) = list(tr.spans(name="jit_step"))
    assert ev.pid == PID_HOST and ev.attrs["tokens"] == 42
    assert ev.t1_s >= ev.t0_s


def test_phase_breakdown_sums_and_clips():
    tr = Tracer()
    tr.add_span("shallow", 0.0, 1.0, tid=1, phase="draft")
    tr.add_span("uplink", 1.0, 2.0, tid=1, phase="uplink")
    tr.add_span("cloud_wait", 2.0, 4.0, tid=1, phase="cloud_step")
    tr.add_span("other_req", 0.0, 9.0, tid=2, phase="queue")
    bd = tr.phase_breakdown(1)
    assert bd == {"draft": 1.0, "uplink": 1.0, "cloud_step": 2.0}
    clipped = tr.phase_breakdown(1, until=1.5)     # mid-uplink first token
    assert clipped == {"draft": 1.0, "uplink": 0.5}
    assert set(bd) <= set(PHASES)


def test_null_tracer_is_inert_and_rejects_observers():
    NULL_TRACER.add_span("x", 0, 1)
    NULL_TRACER.instant("x", 0)
    NULL_TRACER.counter("x", 1)
    with NULL_TRACER.span("x"):
        pass
    assert len(NULL_TRACER.events) == 0
    with pytest.raises(ValueError):
        NULL_TRACER.subscribe(lambda ev: None)


# -------------------------------------------------------------------- export


def test_chrome_trace_export_valid_and_normalized():
    tr = Tracer()
    tr.add_span("uplink", 10.0, 10.5, tid=1, phase="uplink",
                nbytes=np.int64(128))                 # numpy attr collapses
    tr.add_span("cloud_step", 10.2, 10.4, tid=TID_CLOUD, tokens=16)
    with tr.span("jit_step", tid=TID_CLOUD):
        pass
    tr.counter("batched_tokens", 16.0)
    tr.record_hist("batch_tokens", 16)
    obj = to_chrome_trace(tr)
    validate_chrome_trace(obj)
    assert obj["schemaVersion"] == 1
    xs = [e for e in obj["traceEvents"] if e["ph"] == "X"]
    # per-pid epoch normalization: earliest span in each pid starts at ts 0
    for pid in {e["pid"] for e in xs}:
        assert min(e["ts"] for e in xs if e["pid"] == pid) == 0.0
    names = {e["name"] for e in obj["traceEvents"] if e["ph"] == "M"}
    assert names == {"process_name", "thread_name"}
    assert obj["otherData"]["histograms"]["batch_tokens"]["count"] == 1
    import json
    json.dumps(obj)                                   # fully serializable


def test_validate_rejects_schema_drift():
    tr = Tracer()
    tr.add_span("s", 0, 1)
    obj = to_chrome_trace(tr)
    obj["schemaVersion"] = 999
    with pytest.raises(ValueError):
        validate_chrome_trace(obj)


# -------------------------------------------------------------------- bridge


def test_bridge_matches_direct_monitor_updates():
    direct, bridged = StateMonitor(), StateMonitor()
    tr = Tracer(enabled=False)
    attach_monitor(tr, bridged)
    attach_monitor(tr, bridged)                       # idempotent
    assert len(tr.observers) == 1

    for i in range(5):
        dur_up, dur_dn, dur_step = 0.01 + i * 1e-3, 0.02, 0.05 + i * 1e-3
        direct.record_device(3, beta_up=8192 / dur_up)
        tr.add_span("uplink", 0, dur_up, tid=1, dev_id=3,
                    nbytes=8192, dur_s=dur_up)
        direct.record_device(3, beta_down=4096 / dur_dn)
        tr.add_span("downlink", 0, dur_dn, tid=1, dev_id=3,
                    nbytes=4096, dur_s=dur_dn)
        direct.record_batch(64 + i, dur_step)
        tr.add_span("cloud_step", 0, dur_step, tid=TID_CLOUD,
                    tokens=64 + i, dur_s=dur_step)
        direct.record_device(3, gamma=0.002)
        tr.add_span("draft", 0, 0.008, tid=1, dev_id=3,
                    steps=4, dur_s=0.008)
    assert bridged.mu.get() == direct.mu.get()
    assert bridged.eta.get() == direct.eta.get()
    assert bridged.g.predict(128) == direct.g.predict(128)
    d, b = direct.device(3), bridged.device(3)
    assert b.beta_up.get() == pytest.approx(d.beta_up.get())
    assert b.beta_down.get() == pytest.approx(d.beta_down.get())
    assert b.gamma.get() == pytest.approx(d.gamma.get())


def test_bridge_ignores_zero_duration_and_unattributed_spans():
    m = StateMonitor()
    tr = Tracer(enabled=False)
    attach_monitor(tr, m)
    tr.add_span("uplink", 0, 0, tid=1, dev_id=3, nbytes=100, dur_s=0.0)
    tr.add_span("uplink", 0, 1, tid=1, nbytes=100)    # no dev_id
    tr.add_span("prefill", 0, 1, tid=1)               # annotation span
    assert m.devices == {}
    assert m.mu.value is None


# ----------------------------------------------------------- t_send stamping


class _CaptureServer:
    """Transport-facing stub: records uplink bytes, serves no downlinks."""

    def __init__(self):
        self.frames = []

    def handle_frame(self, data):
        self.frames.append(bytes(data))

    def poll(self, req_id):
        return None

    def pump(self):
        return 0


def _frame_bytes(req_id=5):
    codec = get_codec("fp16")
    return encode_hidden(codec, np.zeros((3, 8), np.float32),
                         req_id=req_id, offset=0, kind="prefill")


def _profile(dev_id=0):
    return DeviceProfile(dev_id=dev_id, kind="orin",
                         rng=np.random.default_rng(0))


def test_loopback_stamps_t_send_on_uplink():
    srv = _CaptureServer()
    t = LoopbackTransport(srv)
    data = _frame_bytes()
    assert Frame.from_bytes(data).t_send == 0.0       # unstamped at encode
    t.send(data)
    stamped = Frame.from_bytes(srv.frames[0])
    assert stamped.t_send > 0.0                       # wall clock, epoch-based
    assert t.bytes_up == len(data)


def test_delay_model_transport_stamps_send_complete_time():
    srv = _CaptureServer()
    tr = Tracer()
    net = NetworkModel(np.random.default_rng(0), up_fixed=1e6,
                       down_fixed=2e6)
    t = DelayModelTransport(srv, device=_profile(), net=net, start_s=2.0,
                            tracer=tr)
    data = _frame_bytes()
    t.send(data)
    stamped = Frame.from_bytes(srv.frames[0])
    # stamp == virtual send-complete time == start + uplink transfer
    assert stamped.t_send == pytest.approx(2.0 + len(data) / 1e6)
    assert stamped.t_send == pytest.approx(t.clock())
    (span,) = list(tr.spans(name="uplink"))
    assert span.tid == 5 and span.attrs["phase"] == "uplink"
    assert span.t1_s == pytest.approx(stamped.t_send)


def test_delay_transport_builds_private_bridge_for_monitor():
    srv = _CaptureServer()
    m = StateMonitor()
    net = NetworkModel(np.random.default_rng(0), up_fixed=1e6, down_fixed=2e6)
    t = DelayModelTransport(srv, device=_profile(4), net=net, monitor=m)
    assert not t.tracer.enabled                       # bridge-only tracer
    data = _frame_bytes()
    t.send(data)
    assert m.device(4).beta_up.get() == pytest.approx(1e6)


# ----------------------------------------------------------- SLA boundaries


def _req(req_id, ttft=None, token_dts=None, prompt_len=128, arrival=0.0):
    r = Request(req_id=req_id, device_id=0, arrival_s=arrival,
                prompt_len=prompt_len, max_new_tokens=64)
    t = arrival
    if ttft is not None:
        t += ttft
        r.first_token_s = t
        r.token_times_s.append(t)
    for dt in token_dts or []:
        t += dt
        r.token_times_s.append(t)
    return r


def test_prefill_sla_rate_boundaries():
    m = FleetMetrics()
    assert m.prefill_sla_rate(1.0) == 0.0             # empty: no crash
    m.add(_req(0, ttft=1.0, prompt_len=128))          # exactly on budget
    m.add(_req(1, ttft=1.0 + 1e-6, prompt_len=128))   # just over
    m.add(_req(2, ttft=1.5, prompt_len=256))          # 2x budget for 2x prompt
    m.add(_req(3))                                    # never emitted: skipped
    assert m.prefill_sla_rate(1.0) == pytest.approx(2 / 3)
    # short prompts clamp to the 128-token floor, not a tighter budget
    m2 = FleetMetrics()
    m2.add(_req(0, ttft=0.9, prompt_len=1))
    assert m2.prefill_sla_rate(1.0) == 1.0


def test_decode_sla_rate_boundaries():
    m = FleetMetrics()
    assert m.decode_sla_rate(1.0) == 0.0
    # exact binary dt (2^-5) so the 10-token window is float-exact
    m.add(_req(0, ttft=0.125, token_dts=[0.03125] * 9))   # 10 tokens: too few
    assert m.decode_sla_rate(1.0) == 0.0                  # skipped, not failed
    m.add(_req(1, ttft=0.125, token_dts=[0.03125] * 10))  # exactly 11 tokens
    assert m.decode_sla_rate(0.3125) == 1.0               # window == SLA: pass
    assert m.decode_sla_rate(0.3125 - 1e-9) == 0.0


# ------------------------------------------------- traced runtimes (tensors)


@pytest.fixture(scope="module")
def hat_setup():
    import jax

    cfg, model, params = reduced_model("internlm2-1.8b")
    sp = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    return cfg, sp, adapter


def _engine_specs(cfg, n=3, prompt_len=12, new=4):
    rng = np.random.default_rng(0)
    return [
        RequestSpec(
            req_id=i, device_id=i, arrival_s=0.05 * i,
            prompt_len=prompt_len, max_new_tokens=new,
            prompt=rng.integers(3, cfg.vocab_size, prompt_len).astype(np.int32),
        )
        for i in range(n)
    ]


def test_engine_runtime_traced_breakdown_tiles_ttft(hat_setup):
    cfg, sp, adapter = hat_setup
    config = ServeConfig.hat(n_devices=3, dynamic_chunks=False, fixed_chunk=8)
    mk = lambda tracer: EngineRuntime(
        config, sp, adapter_params=adapter, rng=np.random.default_rng(6),
        n_slots=3, max_len=64, concurrent=True, tracer=tracer,
    )
    tracer = Tracer()
    traced = mk(tracer).serve(_engine_specs(cfg))
    plain = mk(None).serve(_engine_specs(cfg))

    # tracing is observationally neutral: identical tokens and timings
    for a, b in zip(traced.requests, plain.requests):
        assert a.generated == b.generated
        assert a.ttft_s == b.ttft_s and a.done_s == b.done_s
        assert b.phase_ttft_s is None                 # untraced: no breakdown

    # every request's phase breakdown tiles its measured TTFT (<= 1%)
    assert tracer.dropped == 0
    for r in traced.requests:
        assert r.phase_ttft_s is not None
        total = sum(r.phase_ttft_s.values())
        assert total == pytest.approx(r.ttft_s, rel=0.01)
        assert set(r.phase_ttft_s) <= set(PHASES)
        assert r.phase_ttft_s.get("cloud_step", 0) > 0

    s = traced.summary()
    bd = s["ttft_breakdown_ms"]
    assert list(bd) == list(PHASES)
    assert sum(bd.values()) == pytest.approx(s["ttft_mean_ms"], rel=0.01)
    assert "ttft_breakdown_ms" not in plain.summary()

    # the trace itself: valid Chrome JSON with request + cloud + host rows
    obj = tracer.to_chrome_trace()
    validate_chrome_trace(obj)
    pids = {e["pid"] for e in obj["traceEvents"] if e["ph"] == "X"}
    assert {PID_VIRTUAL, PID_HOST} <= pids            # both time domains
    host = [e for e in obj["traceEvents"]
            if e["ph"] == "X" and e["pid"] == PID_HOST]
    assert {"batch_build", "jit_step", "gather"} <= {e["name"] for e in host}
    assert any(e["ph"] == "C" and e["name"] == "batched_tokens"
               for e in obj["traceEvents"])


def test_simulator_runtime_traced_run():
    tracer = Tracer()
    rt = SimulatorRuntime(ServeConfig.hat(), rng=np.random.default_rng(1),
                          tracer=tracer)
    reqs = _sim_specs()
    m = rt.serve(reqs)
    assert len(m.requests) == len(reqs)
    names = {ev.name for ev in tracer.spans()}
    assert {"uplink", "downlink", "cloud_step", "shallow"} <= names
    for r in m.requests:
        assert r.phase_ttft_s is not None
        assert r.phase_ttft_s.get("uplink", 0) > 0
    validate_chrome_trace(tracer.to_chrome_trace())


def _sim_specs(n=4):
    rng = np.random.default_rng(3)
    return [
        RequestSpec(req_id=i, device_id=i % 30, arrival_s=0.2 * i,
                    prompt_len=int(rng.integers(64, 256)),
                    max_new_tokens=24)
        for i in range(n)
    ]
