"""The unified multi-family model.

One :class:`Model` covers every assigned architecture: dense GQA, MoE,
xLSTM, Mamba2 hybrids, VLM cross-attention, and encoder-decoder.  Layers are
grouped into maximal scan groups (identical period bodies) with stacked
parameters, so HLO size and compile time are O(distinct pattern), not
O(n_layers) — a 61-layer MoE lowers as one 60-iteration ``lax.scan``.

Entry points (all pure functions over a params pytree):
  init(key)                        real parameters
  abstract_params()                ShapeDtypeStruct params (dry-run)
  param_spec()                     logical sharding names (same tree)
  apply(params, tokens, ...)       training forward  -> (logits, aux)
  init_cache(params, B, S, ...)    decode/prefill cache pytree
  cache_spec(B, S)                 logical sharding names for the cache
  prefill / decode == apply(..., cache=..., offset=...) -> (logits, cache, aux)
  encode(params, frames)           encoder memory (enc-dec archs)
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

import os

from ..configs.base import LayerDef, ModelConfig
from ..distributed.sharding import constrain, current_rules
from . import layers as layers_mod
from . import ssm
from .layers import (
    F32,
    abstract_init,
    apply_rope,
    attend,
    attn_qkv,
    dense_init,
    init_attn,
    init_mlp,
    init_moe,
    is_abstract,
    mlp_apply,
    moe_apply,
    rms_norm,
    zeros,
)

Params = Dict
PyTree = Any

# Perf-iteration switch (EXPERIMENTS.md §Perf, iteration 1): the cache-native
# "bnsh" attention layout avoids transposing the full KV cache per layer.
# REPRO_KV_TRANSPOSE=1 restores the baseline (transpose-copy) behavior so the
# before/after roofline numbers stay reproducible.
_KV_BASELINE = bool(int(os.environ.get("REPRO_KV_TRANSPOSE", "0")))


# ---------------------------------------------------------------------------
# layer grouping
# ---------------------------------------------------------------------------


def group_layers(cfg: ModelConfig) -> List[Tuple[Tuple[LayerDef, ...], int]]:
    """Split cfg.layers into (body, repeats) scan groups.

    If the layer list tiles the pattern exactly (>1 reps), scan over pattern
    repetitions with the period unrolled inside the body.  Otherwise fall
    back to maximal runs of identical layers (e.g. Kimi's 1 dense + 60 MoE,
    Zamba2's non-divisible 38 = 6x6+2).
    """
    layers = cfg.layers
    n, p = len(layers), len(cfg.pattern)
    if n % p == 0 and n // p > 1 and layers == cfg.pattern * (n // p):
        return [(cfg.pattern, n // p)]
    groups: List[Tuple[Tuple[LayerDef, ...], int]] = []
    run: List[LayerDef] = []
    for ld in layers:
        if run and ld == run[0]:
            run.append(ld)
        else:
            if run:
                groups.append(((run[0],), len(run)))
            run = [ld]
    if run:
        groups.append(((run[0],), len(run)))
    return groups


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, ld: LayerDef, key, dtype):
    ks = jax.random.split(key, 4)
    p: Params = {}
    s: Params = {}
    if ld.kind in ("attn", "cross_attn", "moe"):
        p["attn"], s["attn"] = init_attn(cfg, ks[0], dtype)
    if ld.kind == "cross_attn":
        p["xattn"], s["xattn"] = init_attn(cfg, ks[1], dtype, cross=True)
    if ld.kind == "attn" and cfg.d_ff:
        p["mlp"], s["mlp"] = init_mlp(cfg, ks[2], dtype)
    if ld.kind == "cross_attn":
        p["mlp"], s["mlp"] = init_mlp(cfg, ks[2], dtype)
    if ld.kind == "moe":
        p["moe"], s["moe"] = init_moe(cfg, ks[3], dtype)
    if ld.kind == "mamba2":
        p["m2"], s["m2"] = ssm.init_mamba2(cfg, ks[0], dtype)
    if ld.kind == "mlstm":
        p["mlstm"], s["mlstm"] = ssm.init_mlstm(cfg, ks[0], dtype)
    if ld.kind == "slstm":
        p["slstm"], s["slstm"] = ssm.init_slstm(cfg, ks[0], dtype)
    return p, s


class _Ctx:
    """Per-apply context threaded through layers."""

    __slots__ = ("offset", "memory", "shared", "training", "lengths")

    def __init__(self, offset, memory, shared, training, lengths=None):
        self.offset = offset          # scalar int32: absolute pos of chunk[0]
        self.memory = memory          # [B, M, D] frontend/encoder memory
        self.shared = shared          # zamba2 shared-attn params (or None)
        self.training = training
        self.lengths = lengths        # [B] valid rows per batch slot (or None)

    def valid_rows(self, T: int):
        """[B, T] bool mask of real (non-padded) rows, or None."""
        if self.lengths is None:
            return None
        t = jnp.arange(T, dtype=jnp.int32)[None, :]
        return t < self.lengths[:, None]


def _attn_block(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,
    cache: Optional[Params],
    ctx: _Ctx,
    window: Optional[int],
    causal: bool = True,
    rope: bool = True,
):
    """Self-attention sublayer with optional KV cache (full or ring).

    ``ctx.offset`` may be a scalar (whole batch at one position) or a [B]
    vector (continuous batching: each slot at its own position)."""
    B, T, _ = x.shape
    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    q, k, v = attn_qkv(p, h, cfg)
    vec_off = ctx.offset.ndim == 1
    if vec_off:
        pos = ctx.offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None]   # [B,T]
    else:
        pos = ctx.offset + jnp.arange(T, dtype=jnp.int32)
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = attend(q, k, v, q_pos=pos, k_pos=pos, window=window, causal=causal)
    elif vec_off:
        if window is not None and cache["k"].shape[2] == window:
            raise NotImplementedError(
                "per-slot offsets with ring-buffer windows: the batched "
                "engine targets full-cache layers (DESIGN.md)"
            )
        # per-slot offsets: scatter each row's chunk at its own position.
        # mode="drop" (not clip): a batched step right-pads slots to a
        # common width, so a slot near capacity can carry pad rows whose
        # positions fall past S-1 — clamping would scatter their garbage
        # onto the slot's REAL last row (nondeterministically, via
        # duplicate indices); dropping discards them entirely
        S = cache["k"].shape[2]
        b_idx = jnp.arange(B, dtype=jnp.int32)[:, None]
        nk = cache["k"].at[b_idx, :, pos, :].set(k, mode="drop")  # -> [B,T,nkv,hd]
        nv = cache["v"].at[b_idx, :, pos, :].set(v, mode="drop")
        k_pos = jnp.arange(S, dtype=jnp.int32)
        out = attend(
            q, nk, nv, q_pos=pos, k_pos=k_pos, window=window, causal=causal,
            kv_layout="bnsh",
        )
        new_cache = {"k": nk, "v": nv}
    elif window is not None and cache["k"].shape[2] == window:
        # ring buffer of W slots; slot s holds the latest pos ≡ s (mod W)
        W = window
        off = ctx.offset
        slots = jnp.arange(W, dtype=jnp.int32)
        # latest position < off congruent to slot s (or -1 if none yet)
        last = off - 1 - jnp.mod(off - 1 - slots, W)
        slot_pos = jnp.where((off > 0) & (last >= 0), last, -1)
        k_all = jnp.concatenate([jnp.moveaxis(cache["k"], 2, 1), k], axis=1)
        v_all = jnp.concatenate([jnp.moveaxis(cache["v"], 2, 1), v], axis=1)
        k_pos = jnp.concatenate([slot_pos, pos])
        out = attend(q, k_all, v_all, q_pos=pos, k_pos=k_pos, window=W)
        if T >= W:
            nk = jnp.moveaxis(k[:, -W:], 1, 2)        # [B, nkv, W, hd]
            nv = jnp.moveaxis(v[:, -W:], 1, 2)
            # roll so that entry at ring-slot (pos % W) is the right token
            shift = jnp.mod(off + T - W, W)
            nk = jnp.roll(nk, shift, axis=2)
            nv = jnp.roll(nv, shift, axis=2)
            new_cache = {"k": nk, "v": nv}
        else:
            wslots = jnp.mod(pos, W)                   # unique since T < W
            nk = cache["k"].at[:, :, wslots, :].set(jnp.moveaxis(k, 1, 2))
            nv = cache["v"].at[:, :, wslots, :].set(jnp.moveaxis(v, 1, 2))
            new_cache = {"k": nk, "v": nv}
    else:
        S = cache["k"].shape[2]
        nk = jax.lax.dynamic_update_slice(
            cache["k"], jnp.moveaxis(k, 1, 2), (0, 0, ctx.offset, 0)
        )
        nv = jax.lax.dynamic_update_slice(
            cache["v"], jnp.moveaxis(v, 1, 2), (0, 0, ctx.offset, 0)
        )
        nk = constrain(nk, "kv_cache")
        nv = constrain(nv, "kv_cache")
        k_pos = jnp.arange(S, dtype=jnp.int32)
        if _KV_BASELINE:
            out = attend(
                q, jnp.moveaxis(nk, 2, 1), jnp.moveaxis(nv, 2, 1),
                q_pos=pos, k_pos=k_pos, window=window, causal=causal,
            )
        else:
            out = attend(
                q, nk, nv, q_pos=pos, k_pos=k_pos, window=window,
                causal=causal, kv_layout="bnsh",
            )
        new_cache = {"k": nk, "v": nv}

    y = out.reshape(B, T, cfg.n_heads * cfg.head_dim) @ p["wo"]
    return constrain(x + y, "act_btd"), new_cache


def _cross_block(cfg: ModelConfig, p: Params, x, cache, ctx: _Ctx):
    """Cross-attention sublayer; KV from frontend/encoder memory."""
    B, T, _ = x.shape
    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ p["wq"]).reshape(B, T, nh, hd)
    if ctx.offset.ndim == 1:
        pos = ctx.offset[:, None] + jnp.arange(T, dtype=jnp.int32)[None]
    else:
        pos = ctx.offset + jnp.arange(T, dtype=jnp.int32)
    if cache is not None and "xk" in cache:
        xk, xv = cache["xk"], cache["xv"]             # [B, M, nkv, hd]
    else:
        mem = ctx.memory
        xk = (mem @ p["wk"]).reshape(B, -1, nkv, hd)
        xv = (mem @ p["wv"]).reshape(B, -1, nkv, hd)
    M = xk.shape[1]
    out = attend(
        q, xk, xv,
        q_pos=pos, k_pos=jnp.zeros((M,), jnp.int32), causal=False,
    )
    y = out.reshape(B, T, nh * hd) @ p["wo"]
    new_cache = {"xk": xk, "xv": xv} if cache is not None else None
    return x + y, new_cache


def _apply_layer(cfg: ModelConfig, ld: LayerDef, p: Params, x, cpiece, ctx: _Ctx):
    aux = jnp.zeros((), F32)
    nc: Params = {}
    cp = cpiece or {}
    if ld.kind == "attn":
        x, c = _attn_block(cfg, p["attn"], x, cp.get("sa"), ctx, ld.window)
        if c is not None:
            nc["sa"] = c
        if cfg.d_ff:
            x = mlp_apply(p["mlp"], x, cfg)
    elif ld.kind == "moe":
        x, c = _attn_block(cfg, p["attn"], x, cp.get("sa"), ctx, ld.window)
        if c is not None:
            nc["sa"] = c
        rules = current_rules()
        if rules is not None and layers_mod.moe_shardmap_enabled():
            x, aux = layers_mod.moe_apply_sharded(p["moe"], x, cfg, rules)
        else:
            x, aux = moe_apply(p["moe"], x, cfg)
    elif ld.kind == "cross_attn":
        x, c = _attn_block(cfg, p["attn"], x, cp.get("sa"), ctx, ld.window)
        if c is not None:
            nc["sa"] = c
        x, xc = _cross_block(cfg, p["xattn"], x, cp.get("xa"), ctx)
        if xc is not None:
            nc["xa"] = xc
        x = mlp_apply(p["mlp"], x, cfg)
    elif ld.kind == "mamba2":
        st = cp.get("m2") or ssm.mamba2_init_state(cfg, x.shape[0], x.dtype)
        x, st = ssm.mamba2_apply(p["m2"], x, st, cfg, valid=ctx.valid_rows(x.shape[1]))
        nc["m2"] = st
    elif ld.kind == "mlstm":
        st = cp.get("ml") or ssm.mlstm_init_state(cfg, x.shape[0], x.dtype)
        x, st = ssm.mlstm_apply(p["mlstm"], x, st, cfg, valid=ctx.valid_rows(x.shape[1]))
        nc["ml"] = st
    elif ld.kind == "slstm":
        st = cp.get("sl") or ssm.slstm_init_state(cfg, x.shape[0], x.dtype)
        x, st = ssm.slstm_apply(p["slstm"], x, st, cfg, valid=ctx.valid_rows(x.shape[1]))
        nc["sl"] = st

    if ld.shared_attn:
        sp = ctx.shared
        x, c = _attn_block(cfg, sp["attn"], x, cp.get("sh"), ctx, None)
        if c is not None:
            nc["sh"] = c
        x = mlp_apply(sp["mlp"], x, cfg)
    return x, (nc if cpiece is not None else None), aux


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------


def _layer_cache(cfg: ModelConfig, ld: LayerDef, batch: int, max_len: int,
                 dtype, memory=None, layer_params=None):
    nkv, hd = cfg.n_kv_heads, cfg.head_dim
    c: Params = {}
    if ld.kind in ("attn", "moe", "cross_attn"):
        S = min(ld.window, max_len) if ld.window else max_len
        c["sa"] = {
            "k": jnp.zeros((batch, nkv, S, hd), dtype),
            "v": jnp.zeros((batch, nkv, S, hd), dtype),
        }
    if ld.kind == "cross_attn":
        if memory is not None and layer_params is not None:
            p = layer_params["xattn"]
            xk = (memory @ p["wk"]).reshape(batch, -1, nkv, hd)
            xv = (memory @ p["wv"]).reshape(batch, -1, nkv, hd)
        else:
            M = cfg.n_frontend_tokens or 1
            xk = jnp.zeros((batch, M, nkv, hd), dtype)
            xv = jnp.zeros((batch, M, nkv, hd), dtype)
        c["xa"] = {"xk": xk, "xv": xv}
    if ld.kind == "mamba2":
        c["m2"] = ssm.mamba2_init_state(cfg, batch, dtype)
    if ld.kind == "mlstm":
        c["ml"] = ssm.mlstm_init_state(cfg, batch, dtype)
    if ld.kind == "slstm":
        c["sl"] = ssm.slstm_init_state(cfg, batch, dtype)
    if ld.shared_attn:
        c["sh"] = {
            "k": jnp.zeros((batch, nkv, max_len, hd), dtype),
            "v": jnp.zeros((batch, nkv, max_len, hd), dtype),
        }
    return c


_CACHE_SPECS = {  # leaf-key -> logical sharding name (stacked at group level)
    "k": "kv_cache", "v": "kv_cache", "xk": "kv_xmem", "xv": "kv_xmem",
    "conv": "ssm_small", "h": "ssm_state_bhps", "C": "mlstm_C",
    "n": "ssm_small", "m": "ssm_small", "c": "ssm_small", "h_prev": "ssm_small",
}


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    def __init__(self, cfg: ModelConfig, dtype=jnp.float32, remat: bool = False):
        self.cfg = cfg
        self.dtype = dtype
        self.remat = remat
        self.groups = group_layers(cfg)

    # ----------------------------------------------------------------- init
    def _init(self, key):
        cfg, dtype = self.cfg, self.dtype
        ks = jax.random.split(key, 8 + len(self.groups))
        p: Params = {}
        s: Params = {}
        if cfg.include_embed or (cfg.include_head and cfg.tie_embeddings):
            p["embed"] = dense_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype, scale=0.02)
            s["embed"] = "embed_vd"
        if cfg.include_head:
            p["final_norm"] = zeros((cfg.d_model,), dtype)
            s["final_norm"] = "norm"
            if not cfg.tie_embeddings:
                p["head"] = dense_init(ks[1], cfg.d_model, cfg.padded_vocab, dtype)
                s["head"] = "head_dv"

        p["groups"], s["groups"] = [], []
        for gi, (body, reps) in enumerate(self.groups):
            gk = ks[8 + gi]

            def body_init(k):
                bp, bs = {}, {}
                bks = jax.random.split(k, len(body))
                for li, ld in enumerate(body):
                    bp[f"l{li}"], bs[f"l{li}"] = _init_layer(cfg, ld, bks[li], dtype)
                return bp, bs

            if is_abstract():
                bp, bs = body_init(gk)
                bp = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct((reps,) + a.shape, a.dtype), bp
                )
            else:
                bp = jax.vmap(lambda k: body_init(k)[0])(jax.random.split(gk, reps))
                _, bs = body_init(gk)
            bs = jax.tree.map(
                lambda name: "*" + name, bs, is_leaf=lambda x: isinstance(x, str)
            )
            p["groups"].append(bp)
            s["groups"].append(bs)

        if any(ld.shared_attn for ld in cfg.layers):
            sa_p, sa_s = init_attn(cfg, ks[2], dtype)
            mlp_p, mlp_s = init_mlp(cfg, ks[3], dtype)
            p["shared_attn"] = {"attn": sa_p, "mlp": mlp_p}
            s["shared_attn"] = {"attn": sa_s, "mlp": mlp_s}

        if cfg.is_encoder_decoder:
            enc_def = LayerDef("attn")

            def enc_init(k):
                ep, es = {}, {}
                ep["l0"], es["l0"] = _init_layer(cfg, enc_def, k, dtype)
                return ep, es

            reps = cfg.n_encoder_layers
            ek = ks[4]
            if is_abstract():
                ep, es = enc_init(ek)
                ep = jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct((reps,) + a.shape, a.dtype), ep
                )
            else:
                ep = jax.vmap(lambda k: enc_init(k)[0])(jax.random.split(ek, reps))
                _, es = enc_init(ek)
            es = jax.tree.map(
                lambda n: "*" + n, es, is_leaf=lambda x: isinstance(x, str)
            )
            p["encoder"] = {"layers": ep, "final_norm": zeros((cfg.d_model,), dtype)}
            s["encoder"] = {"layers": es, "final_norm": "norm"}
        return p, s

    def init(self, key) -> Params:
        return self._init(key)[0]

    def abstract_params(self) -> Params:
        with abstract_init():
            return self._init(jax.random.PRNGKey(0))[0]

    def param_spec(self) -> Params:
        with abstract_init():
            return self._init(jax.random.PRNGKey(0))[1]

    # ------------------------------------------------------------- encoder
    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """Bidirectional encoder over precomputed frame embeddings."""
        cfg = self.cfg
        ctx = _Ctx(jnp.zeros((), jnp.int32), None, None, False)
        x = constrain(frames.astype(self.dtype), "memory_bmd")

        def body(h, lp):
            h, _ = _attn_block(cfg, lp["l0"]["attn"], h, None, ctx, None, causal=False)
            h = mlp_apply(lp["l0"]["mlp"], h, cfg)
            return h, None

        x, _ = jax.lax.scan(body, x, params["encoder"]["layers"])
        return rms_norm(x, params["encoder"]["final_norm"], cfg.rmsnorm_eps)

    # ---------------------------------------------------------------- apply
    def apply(
        self,
        params: Params,
        tokens: jax.Array,            # [B, T] int32
        *,
        cache: Optional[PyTree] = None,
        offset=0,
        memory: Optional[jax.Array] = None,
        frames: Optional[jax.Array] = None,
        layer_range: Optional[Tuple[int, int]] = None,
        inputs_embeds: Optional[jax.Array] = None,
        return_hidden: bool = False,
        lengths: Optional[jax.Array] = None,
    ):
        """Unified forward.

        cache=None  -> training/full-context forward over the whole sequence.
        cache given -> chunked prefill / decode / speculative verification:
                       processes the T-token chunk starting at ``offset``.
        layer_range -> run only decoder layers [lo, hi) — this implements the
                       HAT U-shaped split (device: [0, m) + head; cloud:
                       [m, n)).  Embedding applies iff lo == 0; final norm +
                       head apply iff hi == n_layers and return_hidden=False.
        lengths     -> [B] count of *real* rows per batch slot when the
                       chunk is right-padded to a common width (batched
                       engine steps).  Attention is padding-safe by
                       causality; recurrent layers use this to hold their
                       state exactly still on padded rows.
        Returns (out, new_cache, aux); new_cache is None when cache is None.
        """
        cfg = self.cfg
        lo, hi = layer_range or (0, cfg.n_layers)
        offset = jnp.asarray(offset, jnp.int32)

        if cfg.is_encoder_decoder and memory is None and frames is not None:
            memory = self.encode(params, frames)

        if inputs_embeds is not None:
            x = inputs_embeds.astype(self.dtype)
        else:
            if not cfg.include_embed:
                raise ValueError("this submodel takes inputs_embeds, not tokens")
            x = jnp.take(params["embed"], tokens, axis=0)
            x = x * math.sqrt(cfg.d_model)
        x = constrain(x, "act_btd")

        ctx = _Ctx(offset, memory, params.get("shared_attn"), cache is None,
                   None if lengths is None else jnp.asarray(lengths, jnp.int32))
        aux_total = jnp.zeros((), F32)
        new_cache_groups = [] if cache is not None else None

        layer_idx = 0
        for gi, (body, reps) in enumerate(self.groups):
            g_start, g_end = layer_idx, layer_idx + len(body) * reps
            layer_idx = g_end
            # group entirely outside [lo, hi): skip
            if g_end <= lo or g_start >= hi:
                if cache is not None:
                    new_cache_groups.append(cache["groups"][gi])
                continue
            if g_start < lo or g_end > hi:
                # partial overlap: slice the stacked params to whole repeats
                r0 = max(0, (lo - g_start)) // len(body)
                r1 = reps - max(0, g_end - hi) // len(body)
            else:
                r0, r1 = 0, reps

            gp = params["groups"][gi]
            gp = jax.tree.map(lambda a: a[r0:r1], gp) if (r0, r1) != (0, reps) else gp
            gc = None
            if cache is not None:
                gc = cache["groups"][gi]
                gc = jax.tree.map(lambda a: a[r0:r1], gc) if (r0, r1) != (0, reps) else gc

            def body_fn(h, xs):
                lp, lc = xs
                nlc: Params = {}
                aux_g = jnp.zeros((), F32)
                for li, ld in enumerate(body):
                    piece = None if lc is None else lc.get(f"l{li}")
                    h, npiece, aux = _apply_layer(cfg, ld, lp[f"l{li}"], h, piece, ctx)
                    if npiece is not None:
                        nlc[f"l{li}"] = npiece
                    aux_g = aux_g + aux
                return h, (nlc if lc is not None else None, aux_g)

            fn = jax.checkpoint(body_fn) if (self.remat and cache is None) else body_fn
            if cache is not None:
                x, (ngc, auxs) = jax.lax.scan(fn, x, (gp, gc))
                if (r0, r1) != (0, reps):
                    full = cache["groups"][gi]
                    ngc = jax.tree.map(
                        lambda old, new: jax.lax.dynamic_update_slice_in_dim(old, new, r0, 0),
                        full, ngc,
                    )
                new_cache_groups.append(ngc)
            else:
                x, (_, auxs) = jax.lax.scan(fn, x, (gp, None))
            aux_total = aux_total + auxs.sum()

        if return_hidden or hi < cfg.n_layers or not cfg.include_head:
            out = x
        else:
            x = rms_norm(x, params["final_norm"], cfg.rmsnorm_eps)
            head = params["embed"].T if cfg.tie_embeddings else params["head"]
            out = constrain((x @ head), "logits")
            if cfg.padded_vocab != cfg.vocab_size:
                # mask padded vocab columns (never sampled / zero CE mass)
                col = jnp.arange(cfg.padded_vocab)
                out = jnp.where(col < cfg.vocab_size, out, -1e30)

        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["groups"] = new_cache_groups
        return out, new_cache, aux_total

    # ---------------------------------------------------------------- cache
    def init_cache(
        self,
        params: Optional[Params],
        batch: int,
        max_len: int,
        *,
        memory: Optional[jax.Array] = None,
        dtype=None,
        layer_range: Optional[Tuple[int, int]] = None,
    ) -> PyTree:
        """Build the (zero) cache pytree.  ``memory`` (if given with params)
        precomputes cross-attention KV once — the paper's cloud caches the
        encoder/vision memory projections instead of recomputing per step."""
        cfg = self.cfg
        dtype = dtype or self.dtype
        groups = []
        layer_idx = 0
        lo, hi = layer_range or (0, cfg.n_layers)
        for body, reps in self.groups:

            def one(r):
                c = {}
                for li, ld in enumerate(body):
                    lp = None
                    if params is not None:
                        lp = jax.tree.map(lambda a: a[r], params["groups"][len(groups)])[f"l{li}"]
                    c[f"l{li}"] = _layer_cache(cfg, ld, batch, max_len, dtype, memory, lp)
                return c

            if params is not None and memory is not None:
                stacked = jax.vmap(lambda r: one(r))(jnp.arange(reps))
            else:
                proto = one(0)
                stacked = jax.tree.map(
                    lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), proto
                )
            groups.append(stacked)
            layer_idx += len(body) * reps
        return {"groups": groups}

    def cache_spec(self) -> PyTree:
        """Logical sharding names mirroring init_cache output."""

        import jax.tree_util as jtu

        proto = jax.eval_shape(lambda: self.init_cache(None, 1, 8))
        return jtu.tree_map_with_path(
            lambda path, a: "*" + _cache_key_spec(path), proto
        )


def _cache_key_spec(path) -> str:
    keys = [p.key if hasattr(p, "key") else str(p) for p in path]
    leaf = keys[-1]
    parent = keys[-2] if len(keys) > 1 else ""
    if leaf in ("k", "v"):
        return "kv_cache"
    if leaf in ("xk", "xv"):
        return "kv_xmem"
    if parent == "m2":                    # mamba2: conv tail + state
        return "ssm_state" if leaf == "h" else "ssm_small"
    if parent == "ml":                    # mlstm: C matrix + n/m stats
        return "mlstm_C" if leaf == "C" else "ssm_small"
    if parent == "sl":                    # slstm: all small [B, d] vectors
        return "ssm_small"
    return "replicated"
