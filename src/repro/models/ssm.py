"""Recurrent blocks: Mamba-2 (SSD), xLSTM mLSTM (matrix memory) and sLSTM
(scalar memory).

Each block exposes ``init_*`` → (params, spec), ``*_apply(params, x, state,
cfg)`` → (y, new_state) and ``*_init_state(cfg, batch, dtype)``.  ``apply``
processes a chunk of T tokens from a carried recurrent state — the same
entry point serves training (zero state, T = seq_len), chunked prefill, and
speculative verification (T = draft length).  HAT's rejection rollback for
SSM archs snapshots the state before verification (see core/speculative.py).

Every ``*_apply`` accepts an optional per-row ``valid`` mask ([B, T] bool):
rows marked invalid update the recurrent state as exact identities (decay 1,
input 0 — the same trick the chunkwise forms use for their own tail
padding), so a batched engine step may right-pad slots to a common width
without perturbing their state.  This is what lets the cloud engine batch
prefill chunks and verify strips of different lengths across requests in
one step (continuous batching) while staying bit-identical to the unpadded
computation.  ``valid=None`` is the untouched fast path.

Time recursion uses ``lax.scan`` over T in the paper-faithful baseline;
the EXACT chunkwise-parallel reformulations at the bottom of this module
(enabled with REPRO_SSM_CHUNK, oracle in kernels/ref.py) cut the recurrent
state's HBM traffic by the chunk length — EXPERIMENTS.md §Perf H2.
"""
from __future__ import annotations

import math
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import F32, const, dense_init, normal, rms_norm, zeros

Params = Dict

# §Perf H2 switch: chunkwise-parallel SSM forms (exact; see bottom of file).
# 0 = per-token scan (paper-faithful baseline); >0 = chunk length.
def _ssm_chunk() -> int:
    return int(os.environ.get("REPRO_SSM_CHUNK", "0"))

# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def _m2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state
    return d_in, nh, conv_ch


def init_mamba2(cfg: ModelConfig, key, dtype):
    d, s = cfg.d_model, cfg.ssm_state
    d_in, nh, conv_ch = _m2_dims(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "norm": zeros((d,), dtype),
        "w_in": dense_init(ks[0], d, 2 * d_in + 2 * s + nh, dtype),
        "conv_w": normal(ks[1], (cfg.ssm_conv, conv_ch), dtype, 0.1),
        "conv_b": zeros((conv_ch,), dtype),
        "A_log": const(lambda: jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=F32)), (nh,), F32),
        "D": const(lambda: jnp.ones((nh,), F32), (nh,), F32),
        "dt_bias": zeros((nh,), F32),
        "gnorm": zeros((d_in,), dtype),
        "w_out": dense_init(ks[2], d_in, d, dtype, scale=1.0 / math.sqrt(d_in * 2 * cfg.n_layers)),
    }
    s_ = {
        "norm": "norm", "w_in": "ssm_in", "conv_w": "replicated",
        "conv_b": "replicated", "A_log": "replicated", "D": "replicated",
        "dt_bias": "replicated", "gnorm": "replicated", "w_out": "ssm_out",
    }
    return p, s_


def mamba2_init_state(cfg: ModelConfig, batch: int, dtype):
    d_in, nh, conv_ch = _m2_dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, cfg.ssm_state), F32),
    }


def mamba2_apply(p: Params, x: jax.Array, state, cfg: ModelConfig, valid=None):
    B, T, d = x.shape
    s = cfg.ssm_state
    d_in, nh, conv_ch = _m2_dims(cfg)
    hd = cfg.ssm_head_dim

    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    zxbcdt = h @ p["w_in"]
    z, xBC, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * s], axis=-1)

    # depthwise causal conv over the chunk, seeded with the carried tail
    ext = jnp.concatenate([state["conv"].astype(xBC.dtype), xBC], axis=1)
    wc = cfg.ssm_conv
    conv = sum(ext[:, i : i + T, :] * p["conv_w"][i] for i in range(wc))
    xBC = jax.nn.silu(conv + p["conv_b"])
    if valid is None:
        new_conv = ext[:, T:, :].astype(state["conv"].dtype)
    else:
        # carried conv tail = the last wc-1 rows *ending at each slot's own
        # valid length*, not at the padded chunk end
        lens = valid.astype(jnp.int32).sum(axis=1)             # [B]
        idx = lens[:, None] + jnp.arange(wc - 1, dtype=jnp.int32)[None]
        new_conv = jnp.take_along_axis(ext, idx[:, :, None], axis=1)
        new_conv = new_conv.astype(state["conv"].dtype)

    x_in, Bm, Cm = jnp.split(xBC, [d_in, d_in + s], axis=-1)
    xh = x_in.reshape(B, T, nh, hd).astype(F32)
    dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"])       # [B,T,nh]
    dA = jnp.exp(-jnp.exp(p["A_log"]) * dt)                    # [B,T,nh]
    dBx = (dt * 1.0)[..., None] * xh                           # [B,T,nh,hd]
    if valid is not None:
        # identity state update on padded rows: decay 1, zero input
        dA = jnp.where(valid[:, :, None], dA, 1.0)
        dBx = jnp.where(valid[:, :, None, None], dBx, 0.0)
    Bm, Cm = Bm.astype(F32), Cm.astype(F32)

    chunk = _ssm_chunk()
    if chunk > 0 and T > 1:
        y, h_final = mamba2_chunkwise(dBx, Bm, Cm, dA, state["h"], chunk)
    else:
        def step(hc, inp):
            xt, Bt, Ct, dAt = inp                              # [B,nh,hd],[B,s],[B,s],[B,nh]
            hc = hc * dAt[..., None, None] + xt[..., None] * Bt[:, None, None, :]
            yt = jnp.einsum("bhps,bs->bhp", hc, Ct)
            return hc, yt

        xs = (
            jnp.moveaxis(dBx, 1, 0),
            jnp.moveaxis(Bm, 1, 0),
            jnp.moveaxis(Cm, 1, 0),
            jnp.moveaxis(dA, 1, 0),
        )
        h_final, ys = jax.lax.scan(step, state["h"], xs)
        y = jnp.moveaxis(ys, 0, 1)
    y = y + p["D"][None, None, :, None] * xh                   # [B,T,nh,hd]
    y = y.reshape(B, T, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"], cfg.rmsnorm_eps)
    return x + y @ p["w_out"], {"conv": new_conv, "h": h_final}


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory)
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    return d_in, nh, d_in // nh


def init_mlstm(cfg: ModelConfig, key, dtype):
    d = cfg.d_model
    d_in, nh, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    p = {
        "norm": zeros((d,), dtype),
        "w_up": dense_init(ks[0], d, 2 * d_in, dtype),
        "wq": dense_init(ks[1], d_in, d_in, dtype),
        "wk": dense_init(ks[2], d_in, d_in, dtype),
        "wv": dense_init(ks[3], d_in, d_in, dtype),
        "w_i": dense_init(ks[4], d_in, nh, dtype, scale=0.02),
        "b_i": zeros((nh,), F32),
        "w_f": dense_init(ks[5], d_in, nh, dtype, scale=0.02),
        "b_f": const(lambda: jnp.linspace(3.0, 6.0, nh, dtype=F32), (nh,), F32),  # forget bias
        "gnorm": zeros((d_in,), dtype),
        "w_down": dense_init(ks[6], d_in, d, dtype, scale=1.0 / math.sqrt(d_in * 2 * cfg.n_layers)),
    }
    s = {
        "norm": "norm", "w_up": "ssm_in", "wq": "replicated", "wk": "replicated",
        "wv": "replicated", "w_i": "replicated", "b_i": "replicated",
        "w_f": "replicated", "b_f": "replicated", "gnorm": "replicated",
        "w_down": "ssm_out",
    }
    return p, s


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype):
    d_in, nh, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, hd, hd), F32),
        "n": jnp.zeros((batch, nh, hd), F32),
        "m": jnp.full((batch, nh), -jnp.inf, F32),
    }


def mlstm_apply(p: Params, x: jax.Array, state, cfg: ModelConfig, valid=None):
    B, T, d = x.shape
    d_in, nh, hd = _mlstm_dims(cfg)

    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    up = h @ p["w_up"]
    x_in, z = jnp.split(up, 2, axis=-1)
    q = (x_in @ p["wq"]).reshape(B, T, nh, hd).astype(F32) / math.sqrt(hd)
    k = (x_in @ p["wk"]).reshape(B, T, nh, hd).astype(F32)
    v = (x_in @ p["wv"]).reshape(B, T, nh, hd).astype(F32)
    ig = (x_in @ p["w_i"]).astype(F32) + p["b_i"]              # [B,T,nh]
    fg = (x_in @ p["w_f"]).astype(F32) + p["b_f"]
    if valid is not None:
        # padded rows must not touch (C, n, m): input gate -inf, forget
        # gate -> sigmoid 1 — the chunkwise form's own padding convention
        ig = jnp.where(valid[:, :, None], ig, -jnp.inf)
        fg = jnp.where(valid[:, :, None], fg, 1e9)

    chunk = _ssm_chunk()
    if chunk > 0 and T > 1:
        hs, new_state = mlstm_chunkwise(
            q, k, v, ig, fg,
            {"C": state["C"], "n": state["n"], "m": state["m"]}, chunk,
        )
        C, n, m = new_state["C"], new_state["n"], new_state["m"]
        y = hs.reshape(B, T, d_in).astype(x.dtype)
    else:
        def step(carry, inp):
            C, n, m = carry
            qt, kt, vt, it, ft = inp
            log_f = -jax.nn.softplus(-ft)                      # log sigmoid(f)
            m_new = jnp.maximum(log_f + m, it)
            i_p = jnp.exp(it - m_new)[..., None]               # [B,nh,1]
            f_p = jnp.exp(log_f + m - m_new)[..., None]
            C = f_p[..., None] * C + i_p[..., None] * (vt[..., None] * kt[..., None, :])
            n = f_p * n + i_p * kt
            denom = jnp.maximum(
                jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new)
            )[..., None]
            ht = jnp.einsum("bhvd,bhd->bhv", C, qt) / denom
            return (C, n, m_new), ht

        xs = tuple(jnp.moveaxis(a, 1, 0) for a in (q, k, v, ig, fg))
        (C, n, m), ys = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gnorm"], cfg.rmsnorm_eps)
    return x + y @ p["w_down"], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# xLSTM: sLSTM (scalar memory)
# ---------------------------------------------------------------------------


def init_slstm(cfg: ModelConfig, key, dtype):
    d, nh = cfg.d_model, cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 5)
    p = {
        "norm": zeros((d,), dtype),
        "w_izfo": dense_init(ks[0], d, 4 * d, dtype),
        "b_izfo": const(
            lambda: jnp.concatenate(
                [jnp.zeros((2 * d,), F32), jnp.full((d,), 3.0, F32), jnp.zeros((d,), F32)]
            ),
            (4 * d,), F32,
        ),
        # head-block-diagonal recurrent projections (i, z, f, o)
        "r_izfo": normal(ks[1], (4, nh, hd, hd), dtype, 1.0 / math.sqrt(hd)),
        "gnorm": zeros((d,), dtype),
    }
    s = {"norm": "norm", "w_izfo": "ssm_in", "b_izfo": "replicated",
         "r_izfo": "replicated", "gnorm": "replicated"}
    return p, s


def slstm_init_state(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), F32),
        "n": jnp.full((batch, d), 1e-6, F32),
        "h": jnp.zeros((batch, d), F32),
        "m": jnp.full((batch, d), -jnp.inf, F32),
    }


def slstm_apply(p: Params, x: jax.Array, state, cfg: ModelConfig, valid=None):
    B, T, d = x.shape
    nh = cfg.n_heads
    hd = d // nh

    xin = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    pre = (xin @ p["w_izfo"]).astype(F32) + p["b_izfo"]        # [B,T,4d]

    r = p["r_izfo"].astype(F32)

    def gates(pre_t, h, m):
        hh = h.reshape(B, nh, hd)
        rec = jnp.einsum("gnij,bnj->bgni", r, hh).reshape(B, 4 * d)
        gi, gz, gf, go = jnp.split(pre_t + rec, 4, axis=-1)
        log_f = -jax.nn.softplus(-gf)
        m_new = jnp.maximum(log_f + m, gi)
        i_p = jnp.exp(gi - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        return gz, go, m_new, i_p, f_p

    def step(carry, pre_t):
        c, n, h, m = carry
        gz, go, m_new, i_p, f_p = gates(pre_t, h, m)
        c = f_p * c + i_p * jnp.tanh(gz)
        n = f_p * n + i_p
        h = jax.nn.sigmoid(go) * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    def step_masked(carry, inp):
        pre_t, v = inp                                         # v: [B, 1] bool
        c, n, h, m = carry
        gz, go, m_new, i_p, f_p = gates(pre_t, h, m)
        c_u = f_p * c + i_p * jnp.tanh(gz)
        n_u = f_p * n + i_p
        h_u = jax.nn.sigmoid(go) * c_u / jnp.maximum(n_u, 1e-6)
        # h carries state (unlike attention outputs), so padded rows must
        # hold every carry component — including h — exactly still
        c = jnp.where(v, c_u, c)
        n = jnp.where(v, n_u, n)
        h = jnp.where(v, h_u, h)
        m = jnp.where(v, m_new, m)
        return (c, n, h, m), h

    xs = jnp.moveaxis(pre, 1, 0)
    carry0 = (state["c"], state["n"], state["h"], state["m"])
    if valid is None:
        (c, n, h, m), ys = jax.lax.scan(step, carry0, xs)
    else:
        vs = jnp.moveaxis(valid, 1, 0)[:, :, None]
        (c, n, h, m), ys = jax.lax.scan(step_masked, carry0, (xs, vs))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                 # [B,T,d]
    y = rms_norm(y, p["gnorm"], cfg.rmsnorm_eps)
    return x + y, {"c": c, "n": n, "h": h, "m": m}


# ---------------------------------------------------------------------------
# Chunkwise-parallel forms (EXPERIMENTS.md §Perf H2 — beyond-paper)
#
# The per-token scans above read+write the recurrent state (mLSTM's C matrix,
# Mamba2's SSD state) every token: HBM traffic O(T · |state|).  The chunkwise
# forms below are EXACT reformulations (stabilizer-invariance of the mLSTM
# output holds; Mamba2's decays telescope) that materialize the state once
# per chunk: traffic O(T/L · |state|) plus attention-like intra-chunk terms
# that are MXU-friendly matmuls.  Enabled via ssm_chunk (env
# REPRO_SSM_CHUNK for the launchers); chunk=0 falls back to the scan.
# ---------------------------------------------------------------------------


def _pad_chunks(x, L, axis=1):
    T = x.shape[axis]
    pad = (-T) % L
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    return x, T + pad


def mlstm_chunkwise(q, k, v, ig, fg, state, chunk: int):
    """q,k,v: [B,T,nh,hd] (q pre-scaled); ig/fg: [B,T,nh] raw gates.
    Returns ([B,T,nh,hd], new_state).  Exact vs the per-token recurrence."""
    B, T, nh, hd = q.shape
    L = min(chunk, T)
    qs, Tp = _pad_chunks(q.astype(F32), L)
    ks, _ = _pad_chunks(k.astype(F32), L)
    vs, _ = _pad_chunks(v.astype(F32), L)
    igs, _ = _pad_chunks(ig.astype(F32), L)
    # padded steps must not affect state: forget=1 (lf=0), input=-inf
    pad = Tp - T
    if pad:
        igs = igs.at[:, T:].set(-jnp.inf)
        fgs = jnp.concatenate(
            [fg.astype(F32), jnp.full((B, pad, nh), 1e9, F32)], axis=1
        )
    else:
        fgs = fg.astype(F32)
    nC = Tp // L

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nC, L, *x.shape[2:]), 1, 0)

    qc, kc, vc, ic, fc = map(to_chunks, (qs, ks, vs, igs, fgs))

    mask = jnp.tril(jnp.ones((L, L), bool))

    def step(carry, xs):
        C, n, m0 = carry                       # [B,nh,hd,hd],[B,nh,hd],[B,nh]
        qt, kt, vt, it, ft = xs                # [B,L,...]
        lf = -jax.nn.softplus(-ft)             # [B,L,nh]
        b = jnp.cumsum(lf, axis=1)
        D = b[:, :, None, :] - b[:, None, :, :] + it[:, None, :, :]
        D = jnp.where(mask[None, :, :, None], D, -jnp.inf)
        m_intra = D.max(axis=2)                                  # [B,L,nh]
        m_hat = jnp.maximum(b + m0[:, None, :], m_intra)
        inter = jnp.exp(b + m0[:, None, :] - m_hat)              # [B,L,nh]
        S = jnp.exp(D - m_hat[:, :, None, :])                    # [B,L,S,nh]
        sc = jnp.einsum("blnk,bsnk->blsn", qt, kt)
        w = S * sc
        num = inter[..., None] * jnp.einsum("bnvk,blnk->blnv", C, qt) \
            + jnp.einsum("blsn,bsnv->blnv", w, vt)
        nvec = inter[..., None] * n[:, None] + jnp.einsum("blsn,bsnk->blnk", S, kt)
        dot = jnp.abs(jnp.einsum("blnk,blnk->bln", nvec, qt))
        h = num / jnp.maximum(dot, jnp.exp(-m_hat))[..., None]

        BL = b[:, -1, :]                                          # [B,nh]
        m_new = jnp.maximum(BL + m0, (BL[:, None] - b + it).max(axis=1))
        cdec = jnp.exp(BL + m0 - m_new)
        src = jnp.exp(BL[:, None] - b + it - m_new[:, None])      # [B,L,nh]
        C = cdec[..., None, None] * C + jnp.einsum("bln,blnv,blnk->bnvk", src, vt, kt)
        n = cdec[..., None] * n + jnp.einsum("bln,blnk->bnk", src, kt)
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]),
                                 (qc, kc, vc, ic, fc))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, Tp, nh, hd)[:, :T]
    return h, {"C": C, "n": n, "m": m}


def mamba2_chunkwise(xh, Bm, Cm, dA, h0, chunk: int):
    """xh: [B,T,nh,hd] (dt-scaled inputs); Bm/Cm: [B,T,state]; dA: [B,T,nh]
    per-token decay in (0,1].  Returns ([B,T,nh,hd], h_final)."""
    B, T, nh, hd = xh.shape
    st = Bm.shape[-1]
    L = min(chunk, T)
    xs_, Tp = _pad_chunks(xh, L)
    Bs, _ = _pad_chunks(Bm, L)
    Cs, _ = _pad_chunks(Cm, L)
    dAs, _ = _pad_chunks(dA, L)
    pad = Tp - T
    if pad:  # padded steps: decay 1, zero input (xh already zero-padded)
        dAs = dAs.at[:, T:].set(1.0)
    nC = Tp // L

    def to_chunks(x):
        return jnp.moveaxis(x.reshape(B, nC, L, *x.shape[2:]), 1, 0)

    xc, Bc, Cc, ac = map(to_chunks, (xs_, Bs, Cs, dAs))
    mask = jnp.tril(jnp.ones((L, L), bool))

    def step(h, xs):
        xt, Bt, Ct, at = xs
        la = jnp.log(jnp.maximum(at, 1e-38))                      # [B,L,nh]
        cum = jnp.cumsum(la, axis=1)
        G = cum[:, :, None, :] - cum[:, None, :, :]               # t,s
        G = jnp.where(mask[None, :, :, None], jnp.exp(G), 0.0)
        sc = jnp.einsum("blc,bsc->bls", Ct, Bt)                   # [B,L,S]
        y_intra = jnp.einsum("blsn,bsnv->blnv", sc[..., None] * G, xt)
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum("blc,bnvc->blnv", Ct, h)
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)              # [B,L,nh]
        h = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bln,blnv,blc->bnvc", decay_to_end, xt, Bt
        )
        return h, y_intra + y_inter

    h, ys = jax.lax.scan(step, h0, (xc, Bc, Cc, ac))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, Tp, nh, hd)[:, :T]
    return y, h
