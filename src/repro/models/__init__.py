from .model import Model, group_layers
from . import layers, ssm

__all__ = ["Model", "group_layers", "layers", "ssm"]
