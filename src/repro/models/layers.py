"""Transformer primitives: RMSNorm, RoPE, GQA attention (full / sliding
window / cross), SwiGLU MLP, and capacity-based MoE dispatch.

All functions are pure; parameters are plain dicts of jnp arrays.  Each
``init_*`` returns ``(params, spec)`` where ``spec`` mirrors ``params`` with
logical sharding names (see repro.distributed.sharding).
"""
from __future__ import annotations

import contextlib
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain

Params = Dict
F32 = jnp.float32

# ---------------------------------------------------------------------------
# abstract-init mode: build ShapeDtypeStruct params instead of real arrays.
# The multi-pod dry-run initializes trillion-parameter configs this way —
# zero allocation, exact shapes/dtypes for .lower().
# ---------------------------------------------------------------------------

_ABSTRACT = False


@contextlib.contextmanager
def abstract_init():
    global _ABSTRACT
    prev, _ABSTRACT = _ABSTRACT, True
    try:
        yield
    finally:
        _ABSTRACT = prev


def is_abstract() -> bool:
    return _ABSTRACT


def zeros(shape, dtype):
    if _ABSTRACT:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return jnp.zeros(shape, dtype)


def normal(key, shape, dtype, scale: float):
    if _ABSTRACT:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    return (jax.random.normal(key, shape, F32) * scale).astype(dtype)


def const(fn, shape, dtype):
    """Deterministic initializer (linspace, log-spaced decay rates, ...)."""
    if _ABSTRACT:
        return jax.ShapeDtypeStruct(tuple(shape), dtype)
    out = fn()
    assert out.shape == tuple(shape), (out.shape, shape)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(F32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(F32))).astype(dt)


def _rope_angles(pos: jax.Array, head_dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    # pos: [...]; returns cos/sin of shape [..., head_dim//2]
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=F32) / half)
    ang = pos.astype(F32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: [B, T, H, hd]; pos: [T] or [B, T]."""
    hd = x.shape[-1]
    cos, sin = _rope_angles(pos, hd, theta)          # [T, hd/2] or [B, T, hd/2]
    if cos.ndim == 2:                                 # [T, hd/2] -> broadcast B
        cos, sin = cos[None], sin[None]
    cos, sin = cos[:, :, None, :], sin[:, :, None, :]  # [B, T, 1, hd/2]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return normal(key, (d_in, d_out), dtype, scale)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def attend(
    q: jax.Array,            # [B, T, nh, hd]
    k: jax.Array,            # [B, S, nkv, hd]  (or [B, nkv, S, hd], kv_layout="bnsh")
    v: jax.Array,
    *,
    q_pos: jax.Array,        # [T] or [B, T]
    k_pos: jax.Array,        # [S] or [B, S]; entries < 0 are invalid slots
    window: Optional[int] = None,
    causal: bool = True,
    kv_layout: str = "bsnh",
) -> jax.Array:
    """Reference GQA attention with position-based masking.

    Works for training (T == S, no cache), chunked prefill (T = chunk,
    S = cache + chunk), decode/verification (T = k draft tokens), sliding
    windows (ring-buffer slots carry their absolute position in ``k_pos``),
    and cross-attention (``causal=False``, ``k_pos >= 0`` everywhere).
    """
    B, T, nh, hd = q.shape
    if kv_layout == "bnsh":
        # cache-native layout: avoids materializing a transposed copy of
        # the (potentially huge) KV cache — see EXPERIMENTS.md §Perf
        S, nkv = k.shape[2], k.shape[1]
        kv_eq, pv_eq = "btkgh,bksh->bkgts", "bkgts,bksh->btkgh"
    else:
        S, nkv = k.shape[1], k.shape[2]
        kv_eq, pv_eq = "btkgh,bskh->bkgts", "bkgts,bskh->btkgh"
    g = nh // nkv
    qg = q.reshape(B, T, nkv, g, hd)

    scores = jnp.einsum(kv_eq, qg, k).astype(F32)
    scores *= 1.0 / math.sqrt(hd)

    qp = q_pos if q_pos.ndim == 2 else jnp.broadcast_to(q_pos[None], (B, T))
    kp = k_pos if k_pos.ndim == 2 else jnp.broadcast_to(k_pos[None], (B, S))
    mask = kp[:, None, :] >= 0                       # [B, 1, S] valid slots
    if causal:
        mask = mask & (kp[:, None, :] <= qp[:, :, None])
    if window is not None:
        mask = mask & (kp[:, None, :] > qp[:, :, None] - window)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(pv_eq, probs.astype(v.dtype), v)
    return out.reshape(B, T, nh, hd)


def init_attn(cfg: ModelConfig, key, dtype, *, cross: bool = False):
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "norm": zeros((d,), dtype),
        "wq": dense_init(ks[0], d, nh * hd, dtype),
        "wk": dense_init(ks[1], d, nkv * hd, dtype),
        "wv": dense_init(ks[2], d, nkv * hd, dtype),
        "wo": dense_init(ks[3], nh * hd, d, dtype, scale=1.0 / math.sqrt(nh * hd * 2 * cfg.n_layers)),
    }
    s = {"norm": "norm", "wq": "attn_q", "wk": "attn_kv", "wv": "attn_kv", "wo": "attn_o"}
    if cfg.qkv_bias and not cross:
        p.update(
            bq=zeros((nh * hd,), dtype),
            bk=zeros((nkv * hd,), dtype),
            bv=zeros((nkv * hd,), dtype),
        )
        s.update(bq="attn_bias", bk="attn_bias", bv="attn_bias")
    return p, s


def attn_qkv(p: Params, x: jax.Array, cfg: ModelConfig):
    B, T, _ = x.shape
    nh, nkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = constrain(q.reshape(B, T, nh, hd), "act_bthd")
    k = k.reshape(B, T, nkv, hd)
    v = v.reshape(B, T, nkv, hd)
    return q, k, v


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, dtype, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "norm": zeros((d,), dtype),
        "wi": dense_init(ks[0], d, f, dtype),
        "wg": dense_init(ks[1], d, f, dtype),
        "wo": dense_init(ks[2], f, d, dtype, scale=1.0 / math.sqrt(f * 2 * cfg.n_layers)),
    }
    s = {"norm": "norm", "wi": "mlp_in", "wg": "mlp_in", "wo": "mlp_out"}
    return p, s


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    y = (jax.nn.silu(h @ p["wg"]) * (h @ p["wi"])) @ p["wo"]
    return x + y


# ---------------------------------------------------------------------------
# MoE (top-k routing, capacity-based dispatch)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key, dtype):
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f * 2 * cfg.n_layers)
    p = {
        "norm": zeros((d,), dtype),
        "router": dense_init(ks[0], d, e, dtype, scale=0.02),
        "wi": normal(ks[1], (e, d, f), dtype, scale_in),
        "wg": normal(ks[2], (e, d, f), dtype, scale_in),
        "wo": normal(ks[3], (e, f, d), dtype, scale_out),
    }
    s = {"norm": "norm", "router": "router", "wi": "moe_in", "wg": "moe_in", "wo": "moe_out"}
    if cfg.n_shared_experts:
        fs_ = cfg.n_shared_experts * f
        p["shared_wi"] = dense_init(ks[4], d, fs_, dtype)
        p["shared_wg"] = dense_init(jax.random.fold_in(ks[4], 1), d, fs_, dtype)
        p["shared_wo"] = dense_init(jax.random.fold_in(ks[4], 2), fs_, d, dtype, scale=scale_out)
        s.update(shared_wi="mlp_in", shared_wg="mlp_in", shared_wo="mlp_out")
    return p, s


def moe_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, *, capacity_factor: Optional[float] = None
) -> Tuple[jax.Array, jax.Array]:
    """Top-k MoE with sort-based capacity dispatch.

    Returns (output, aux_load_balance_loss).  Tokens are flattened, routed
    to ``experts_per_token`` experts each, sorted by expert id, scattered
    into per-expert capacity buffers [E, C, D] (overflow dropped — GShard
    semantics), processed with batched expert matmuls, and combined back
    with router weights.  The [E, C, D] buffers carry the "moe_buf" logical
    sharding (expert-parallel over the model axis): under pjit the
    token→expert resharding lowers to an all-to-all.
    """
    B, T, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    flat = h.reshape(B * T, d)
    n = B * T

    logits = (flat @ p["router"]).astype(F32)                 # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                          # [N, k]
    w = (w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                   # [E]
    ce = jnp.zeros((e,), F32).at[idx.reshape(-1)].add(1.0) / (n * k)
    aux = e * jnp.sum(me * ce)

    cap = int(max(k, math.ceil(n * k / e * capacity_factor)))
    cap = min(cap, n * k)

    e_flat = idx.reshape(-1)                                  # [N*k]
    order = jnp.argsort(e_flat)                               # stable
    se = e_flat[order]
    counts = jnp.zeros((e,), jnp.int32).at[e_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k, dtype=jnp.int32) - starts[se]     # slot in expert
    tok = order // k                                          # source token

    ok = pos < cap
    slot = jnp.where(ok, se * cap + pos, e * cap)             # overflow slot
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(flat[tok])
    buf = constrain(buf[: e * cap].reshape(e, cap, d), "moe_buf")

    up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"])) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"]
    )
    out_buf = constrain(jnp.einsum("ecf,efd->ecd", up, p["wo"]), "moe_buf")

    gathered = out_buf.reshape(e * cap, d)[jnp.clip(slot, 0, e * cap - 1)]
    gathered = jnp.where(ok[:, None], gathered, 0.0)          # dropped -> 0
    w_sorted = w.reshape(-1)[order]
    y = jnp.zeros((n, d), x.dtype).at[tok].add(gathered * w_sorted[:, None])

    if "shared_wi" in p:
        y = y + (jax.nn.silu(flat @ p["shared_wg"]) * (flat @ p["shared_wi"])) @ p["shared_wo"]
    return x + y.reshape(B, T, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel MoE via shard_map (EXPERIMENTS.md §Perf H1 — beyond-paper)
#
# The pjit dispatch above builds globally-sharded capacity buffers; XLA
# lowers the token→expert resharding through global sorts/scatters whose
# collective traffic dwarfs the expert FLOPs (kimi train: 26x the compute
# term).  This variant keeps ALL dispatch local: every model-axis rank holds
# E/tp experts and the full dp-shard of tokens (already replicated across
# the model axis), routes locally (local top-k, local sort, local capacity
# buffers — zero collectives), computes its experts' contributions, and the
# ONLY cross-chip exchange is one psum of the [n_local, d] partial outputs
# over the model axis per layer.  Enabled with REPRO_MOE_SHARDMAP=1.
# ---------------------------------------------------------------------------

import os as _os

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as _P


def moe_shardmap_enabled() -> bool:
    return bool(int(_os.environ.get("REPRO_MOE_SHARDMAP", "0")))


def moe_apply_sharded(p: Params, x: jax.Array, cfg: ModelConfig, rules):
    """Drop-in replacement for moe_apply under active sharding rules."""
    B, T, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    mesh = rules.mesh
    tp = mesh.shape["model"]
    e_loc = e // tp
    dp_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    n_loc = (B * T) // n_dp
    cap = int(max(k, math.ceil(n_loc * k / e * cfg.moe_capacity_factor)))

    h = rms_norm(x, p["norm"], cfg.rmsnorm_eps)
    flat = h.reshape(B * T, d)

    def body(xs, router, wi, wg, wo):
        # xs: [n_loc, d] (local dp shard; identical across model ranks)
        # wi/wg/wo: my expert shard [e_loc, d, f] / [e_loc, f, d]
        j = jax.lax.axis_index("model")
        logits = (xs @ router).astype(F32)                    # [n_loc, E]
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, k)                      # [n_loc, k]
        w = (w / jnp.clip(w.sum(-1, keepdims=True), 1e-9)).astype(xs.dtype)

        # local slots for MY experts only
        e_flat = idx.reshape(-1)                              # [n_loc*k]
        local_e = e_flat - j * e_loc
        mine = (local_e >= 0) & (local_e < e_loc)
        key = jnp.where(mine, local_e, e_loc)                 # overflow bin
        order = jnp.argsort(key)
        se = key[order]
        counts = jnp.zeros((e_loc + 1,), jnp.int32).at[key].add(1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(n_loc * k, dtype=jnp.int32) - starts[se]
        tok = order // k
        ok = (se < e_loc) & (pos < cap)
        slot = jnp.where(ok, se * cap + pos, e_loc * cap)
        buf = jnp.zeros((e_loc * cap + 1, d), xs.dtype).at[slot].set(xs[tok])
        buf = buf[: e_loc * cap].reshape(e_loc, cap, d)

        up = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum(
            "ecd,edf->ecf", buf, wi
        )
        out_buf = jnp.einsum("ecf,efd->ecd", up, wo).reshape(e_loc * cap, d)
        gathered = out_buf[jnp.clip(slot, 0, e_loc * cap - 1)]
        gathered = jnp.where(ok[:, None], gathered, 0.0)
        w_sorted = w.reshape(-1)[order]
        y = jnp.zeros((n_loc, d), xs.dtype).at[tok].add(gathered * w_sorted[:, None])
        # the ONLY collective: combine expert partials across the model axis
        y = jax.lax.psum(y, "model")

        me = probs.mean(axis=0)
        ce = jnp.zeros((e,), F32).at[e_flat].add(1.0) / (n_loc * k)
        aux = e * jnp.sum(me * ce)
        for a in dp_axes:
            aux = jax.lax.pmean(aux, a)
        return y, aux

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)
    y_flat, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            _P(dp_spec, None), _P(None, None),
            _P("model", None, None), _P("model", None, None),
            _P("model", None, None),
        ),
        out_specs=(_P(dp_spec, None), _P()),
        check_rep=False,
    )(flat, p["router"], p["wi"], p["wg"], p["wo"])

    y = y_flat
    if "shared_wi" in p:
        y = y + (jax.nn.silu(flat @ p["shared_wg"]) * (flat @ p["shared_wi"])) @ p["shared_wo"]
    return x + y.reshape(B, T, d), aux
