"""Synthetic corpora and serving workloads.

Two layers:
  * ``markov_corpus`` — a token-level Markov-chain corpus with Zipfian
    unigram structure, enough for small LMs (and the adapter distillation)
    to have learnable regularities.
  * workload generators matching the paper's Table 3 prompt-length
    statistics: SpecBench-like (mean 351.2, P90 891, long right tail across
    heterogeneous tasks) and CNN/DM-like (mean 1036.6, P90 1772) —
    log-normal length models fit to (mean, P90), truncated to [16, 4096].
    Requests arrive by a Poisson process (paper §4.2).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Iterator, List, Optional

import numpy as np


# ---------------------------------------------------------------------------
# training corpora
# ---------------------------------------------------------------------------


def markov_corpus(
    rng: np.random.Generator,
    vocab_size: int,
    n_tokens: int,
    *,
    branching: int = 4,
    zipf_a: float = 1.2,
) -> np.ndarray:
    """Order-1 Markov chain: each token has ``branching`` likely successors
    drawn from a Zipfian base distribution -> compressible structure."""
    v_eff = max(vocab_size - 3, 8)
    base_p = 1.0 / np.arange(1, v_eff + 1) ** zipf_a
    base_p /= base_p.sum()
    succ = rng.choice(v_eff, size=(v_eff, branching), p=base_p)
    toks = np.empty(n_tokens, np.int32)
    t = int(rng.integers(v_eff))
    for i in range(n_tokens):
        if rng.random() < 0.85:
            t = int(succ[t, int(rng.integers(branching))])
        else:
            t = int(rng.choice(v_eff, p=base_p))
        toks[i] = t + 3                                  # skip specials
    return toks


def token_batches(
    rng: np.random.Generator,
    corpus: np.ndarray,
    batch: int,
    seq_len: int,
) -> Iterator[dict]:
    n = len(corpus) - seq_len - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        toks = np.stack([corpus[i : i + seq_len + 1] for i in idx])
        yield {"tokens": toks.astype(np.int32)}


# ---------------------------------------------------------------------------
# serving workloads (paper Table 3)
# ---------------------------------------------------------------------------


def _lognormal_from_mean_p90(mean: float, p90: float):
    """Solve (mu, sigma) of a log-normal from mean and 90th percentile."""
    z90 = 1.2815515655446004
    # mean = exp(mu + s^2/2);  p90 = exp(mu + z90 s)
    # => log(p90) - log(mean) = z90 s - s^2/2  -> solve quadratic in s
    d = math.log(p90) - math.log(mean)
    disc = z90 * z90 - 2 * d
    s = z90 - math.sqrt(max(disc, 0.0)) if disc > 0 else z90
    mu = math.log(mean) - s * s / 2
    return mu, s


@dataclass
class WorkloadSpec:
    name: str
    mean_len: float
    p90_len: float
    max_gen: int = 128            # paper: max generation 128 tokens
    min_len: int = 16
    max_len: int = 4096


SPECBENCH = WorkloadSpec("specbench", mean_len=351.2, p90_len=891.0, max_len=2048)
CNN_DM = WorkloadSpec("cnn_dm", mean_len=1036.6, p90_len=1772.0)


@dataclass
class RequestSpec:
    req_id: int
    device_id: int
    arrival_s: float
    prompt_len: int
    max_new_tokens: int
    prompt: Optional[np.ndarray] = None    # actual token ids (small-model runs)


def sample_workload(
    spec: WorkloadSpec,
    rng: np.random.Generator,
    *,
    n_requests: int,
    rate_per_s: float,
    n_devices: int = 30,
    with_tokens: bool = False,
    vocab_size: int = 512,
) -> List[RequestSpec]:
    """Poisson arrivals across a device fleet with Table-3 prompt lengths."""
    mu, s = _lognormal_from_mean_p90(spec.mean_len, spec.p90_len)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / rate_per_s)
        plen = int(np.clip(rng.lognormal(mu, s), spec.min_len, spec.max_len))
        gen = int(rng.integers(max(spec.max_gen // 4, 1), spec.max_gen + 1))
        prompt = None
        if with_tokens:
            prompt = rng.integers(3, vocab_size, size=plen).astype(np.int32)
        out.append(
            RequestSpec(
                req_id=i,
                device_id=int(rng.integers(n_devices)),
                arrival_s=t,
                prompt_len=plen,
                max_new_tokens=gen,
                prompt=prompt,
            )
        )
    return out
