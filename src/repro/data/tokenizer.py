"""Tokenizers: byte-level (always available) and a trainable BPE.

The runnable experiments use small from-scratch models, so the tokenizer is
part of the substrate (no external vocab files).  ByteTokenizer maps UTF-8
bytes + special tokens; BPETokenizer learns merges greedily over a corpus
(classic Sennrich BPE, capped vocabulary) — enough to make the synthetic
SpecBench/CNN-DM-like workloads realistic token streams.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class ByteTokenizer:
    vocab_size = 256 + N_SPECIAL

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> List[int]:
        ids = [b + N_SPECIAL for b in text.encode("utf-8")]
        if bos:
            ids = [BOS] + ids
        if eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        bs = bytes(i - N_SPECIAL for i in ids if i >= N_SPECIAL)
        return bs.decode("utf-8", errors="replace")


class BPETokenizer:
    """Greedy byte-pair encoding trained in-memory."""

    def __init__(self, vocab_size: int = 512):
        assert vocab_size > 256 + N_SPECIAL
        self.target_vocab = vocab_size
        self.merges: List[Tuple[int, int]] = []
        self.merge_ranks: Dict[Tuple[int, int], int] = {}
        self._next_id = 256 + N_SPECIAL
        self.pair_to_id: Dict[Tuple[int, int], int] = {}

    @property
    def vocab_size(self) -> int:
        return self._next_id

    def train(self, corpus: Iterable[str], max_merges: int | None = None):
        seqs = [
            [b + N_SPECIAL for b in text.encode("utf-8")] for text in corpus
        ]
        n_merges = (max_merges if max_merges is not None
                    else self.target_vocab - (256 + N_SPECIAL))
        for _ in range(n_merges):
            counts: Counter = Counter()
            for s in seqs:
                counts.update(zip(s, s[1:]))
            if not counts:
                break
            pair, freq = counts.most_common(1)[0]
            if freq < 2:
                break
            new_id = self._next_id
            self._next_id += 1
            self.merges.append(pair)
            self.merge_ranks[pair] = len(self.merges) - 1
            self.pair_to_id[pair] = new_id
            seqs = [self._merge(s, pair, new_id) for s in seqs]
        return self

    @staticmethod
    def _merge(s: List[int], pair: Tuple[int, int], new_id: int) -> List[int]:
        out, i = [], 0
        while i < len(s):
            if i + 1 < len(s) and (s[i], s[i + 1]) == pair:
                out.append(new_id)
                i += 2
            else:
                out.append(s[i])
                i += 1
        return out

    def encode(self, text: str, bos: bool = True, eos: bool = False) -> List[int]:
        s = [b + N_SPECIAL for b in text.encode("utf-8")]
        while len(s) >= 2:
            pairs = set(zip(s, s[1:]))
            ranked = [(self.merge_ranks[p], p) for p in pairs if p in self.merge_ranks]
            if not ranked:
                break
            _, best = min(ranked)
            s = self._merge(s, best, self.pair_to_id[best])
        if bos:
            s = [BOS] + s
        if eos:
            s = s + [EOS]
        return s

    def decode(self, ids: Sequence[int]) -> str:
        def expand(i: int) -> bytes:
            if i < N_SPECIAL:
                return b""
            if i < 256 + N_SPECIAL:
                return bytes([i - N_SPECIAL])
            pair = self.merges[i - 256 - N_SPECIAL]
            return expand(pair[0]) + expand(pair[1])

        return b"".join(expand(i) for i in ids).decode("utf-8", errors="replace")
