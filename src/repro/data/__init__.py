from .synthetic import (
    CNN_DM,
    SPECBENCH,
    RequestSpec,
    WorkloadSpec,
    markov_corpus,
    sample_workload,
    token_batches,
)
from .tokenizer import BOS, EOS, PAD, BPETokenizer, ByteTokenizer

__all__ = [
    "BOS", "EOS", "PAD", "BPETokenizer", "ByteTokenizer",
    "CNN_DM", "SPECBENCH", "RequestSpec", "WorkloadSpec",
    "markov_corpus", "sample_workload", "token_batches",
]
