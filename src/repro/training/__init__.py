from .checkpoint import checkpoint_step, load_checkpoint, save_checkpoint
from .optim import (
    AdamW,
    Adafactor,
    SGD,
    clip_by_global_norm,
    constant_schedule,
    cosine_schedule,
    global_norm,
)
from .trainer import TrainLoopResult, lm_loss, make_train_step, train_loop

__all__ = [
    "AdamW", "Adafactor", "SGD", "clip_by_global_norm", "constant_schedule",
    "cosine_schedule", "global_norm", "lm_loss", "make_train_step",
    "train_loop", "TrainLoopResult", "save_checkpoint", "load_checkpoint",
    "checkpoint_step",
]
