"""Training substrate: LM loss, train step factory, adapter-distillation
driver, and a minimal training loop used by tests/examples.

The same ``make_train_step`` builds both the smoke-test step (single CPU
device, f32) and the dry-run production step (bf16, pjit over the 16x16 or
2x16x16 mesh with Adafactor) — the launcher only changes shardings/dtypes.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model
from .optim import Optimizer

F32 = jnp.float32
PyTree = Any


def lm_loss(
    model: Model,
    params: PyTree,
    tokens: jax.Array,              # [B, T]: loss over next-token prediction
    *,
    memory: Optional[jax.Array] = None,
    aux_coef: Optional[float] = None,
) -> Tuple[jax.Array, Dict]:
    cfg = model.cfg
    logits, _, aux = model.apply(params, tokens[:, :-1], memory=memory)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    coef = cfg.router_aux_coef if aux_coef is None else aux_coef
    total = loss + coef * aux
    return total, {"loss": loss, "aux": aux, "ppl": jnp.exp(loss)}


def make_train_step(model: Model, optimizer: Optimizer,
                    memory_fn: Optional[Callable] = None):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state, metrics)``.

    ``batch`` is {"tokens": [B, T]} plus optional {"memory": [B, M, D]}.
    Jit/pjit is applied by the caller (launcher decides shardings)."""

    def step(params, opt_state, batch):
        memory = batch.get("memory")

        def loss_fn(p):
            return lm_loss(model, p, batch["tokens"], memory=memory)

        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, metrics

    return step


@dataclass
class TrainLoopResult:
    losses: list
    metrics: Dict
    steps: int
    wall_s: float


def train_loop(
    model: Model,
    params: PyTree,
    optimizer: Optimizer,
    batches: Iterable[Dict],
    *,
    max_steps: int = 100,
    log_every: int = 20,
    log_fn: Callable = print,
) -> Tuple[PyTree, TrainLoopResult]:
    step_fn = jax.jit(make_train_step(model, optimizer))
    opt_state = optimizer.init(params)
    losses = []
    t0 = time.time()
    last_metrics: Dict = {}
    for i, batch in enumerate(batches):
        if i >= max_steps:
            break
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        last_metrics = {k: float(v) for k, v in metrics.items()}
        if log_every and i % log_every == 0:
            log_fn(f"step {i:5d} loss {losses[-1]:.4f} ppl {last_metrics['ppl']:.2f}")
    return params, TrainLoopResult(losses, last_metrics, len(losses), time.time() - t0)
