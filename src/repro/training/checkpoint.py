"""Checkpointing: pytree <-> .npz + JSON manifest (no orbax dependency).

Flattens any params/opt-state pytree with ``jax.tree_util`` key-paths as
stable names, saves arrays into a single compressed ``.npz`` and the tree
structure into ``manifest.json``.  Restores onto host then (optionally)
device_put with a target sharding tree.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[name] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: PyTree, step: int = 0, extra: Optional[Dict] = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez_compressed(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree.structure(tree)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (names must match)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    out = []
    for path_, leaf in leaves_with_path:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_)
        arr = data[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
