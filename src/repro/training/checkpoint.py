"""Checkpointing: pytree <-> .npz + JSON manifest (no orbax dependency).

Two families:

* ``save_checkpoint``/``load_checkpoint`` — training params/opt-state.
  Flattens any pytree with ``jax.tree_util`` key-paths as stable names,
  saves arrays into a single compressed ``.npz`` and the tree structure
  into ``manifest.json``; restore requires a ``like`` template.
* ``save_state``/``load_state`` — *structure-preserving* state snapshots
  (used by the serving stack for whole-pool engine/service checkpoints).
  The manifest encodes the container structure itself — dicts with str or
  int keys, lists, tuples, scalar leaves, ``bytes``, arrays — so a state
  dict restores without a template.  Writes are atomic (tmp dir +
  ``os.replace``) and all read failures (missing, truncated zip, garbage
  JSON, unknown format) surface as the typed :class:`CheckpointError`.
"""
from __future__ import annotations

import json
import os
import shutil
import zipfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

STATE_FORMAT = "repro-state-v1"


class CheckpointError(RuntimeError):
    """A checkpoint is missing, truncated, corrupt, or structurally
    incompatible with what the caller expects."""


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        name = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[name] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: PyTree, step: int = 0, extra: Optional[Dict] = None):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez_compressed(os.path.join(path, "arrays.npz"), **flat)
    treedef = jax.tree.structure(tree)
    manifest = {
        "step": step,
        "keys": sorted(flat.keys()),
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (names must match)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_with_path = jax.tree_util.tree_flatten_with_path(like)[0]
    treedef = jax.tree.structure(like)
    out = []
    for path_, leaf in leaves_with_path:
        name = "/".join(str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_)
        arr = data[name]
        assert arr.shape == tuple(leaf.shape), (name, arr.shape, leaf.shape)
        out.append(jnp.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(treedef, out)


def checkpoint_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]


# ---------------------------------------------------------------------------
# structure-preserving state snapshots
# ---------------------------------------------------------------------------


def _encode_state(tree: Any, arrays: Dict[str, np.ndarray]) -> Any:
    """Recursively encode ``tree`` into a JSON-able node, collecting array
    and bytes leaves into ``arrays`` (npz members)."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, bool):
        return {"t": "bool", "v": bool(tree)}
    if isinstance(tree, (int, np.integer)):
        return {"t": "int", "v": int(tree)}
    if isinstance(tree, (float, np.floating)):
        return {"t": "float", "v": float(tree)}
    if isinstance(tree, str):
        return {"t": "str", "v": tree}
    if isinstance(tree, (bytes, bytearray)):
        key = f"leaf{len(arrays)}"
        arrays[key] = np.frombuffer(bytes(tree), dtype=np.uint8)
        return {"t": "bytes", "k": key}
    if isinstance(tree, dict):
        items = []
        for k, v in tree.items():
            if isinstance(k, bool) or not isinstance(k, (int, np.integer, str)):
                raise TypeError(f"unsupported state-dict key {k!r}")
            tk = ["i", int(k)] if not isinstance(k, str) else ["s", k]
            items.append([tk, _encode_state(v, arrays)])
        return {"t": "dict", "i": items}
    if isinstance(tree, (list, tuple)):
        return {"t": "list" if isinstance(tree, list) else "tuple",
                "i": [_encode_state(v, arrays) for v in tree]}
    arr = np.asarray(tree)
    key = f"leaf{len(arrays)}"
    arrays[key] = arr
    return {"t": "array", "k": key}


def _decode_state(node: Any, data) -> Any:
    t = node["t"]
    if t == "none":
        return None
    if t in ("bool", "int", "float", "str"):
        return node["v"]
    if t == "bytes":
        return bytes(data[node["k"]].tobytes())
    if t == "dict":
        out = {}
        for (kind, key), enc in node["i"]:
            out[int(key) if kind == "i" else key] = _decode_state(enc, data)
        return out
    if t in ("list", "tuple"):
        seq = [_decode_state(v, data) for v in node["i"]]
        return seq if t == "list" else tuple(seq)
    if t == "array":
        return np.asarray(data[node["k"]])
    raise CheckpointError(f"unknown state node type {t!r}")


def save_state(path: str, state: Any, extra: Optional[Dict] = None) -> str:
    """Write a structure-preserving snapshot of ``state`` to directory
    ``path`` atomically (readers see either the old or the new snapshot,
    never a half-written one).  Returns ``path``."""
    arrays: Dict[str, np.ndarray] = {}
    structure = _encode_state(state, arrays)
    tmp = str(path) + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez_compressed(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {"format": STATE_FORMAT, "structure": structure,
                "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    old = str(path) + ".old"
    if os.path.isdir(old):
        shutil.rmtree(old)
    if os.path.exists(path):
        os.replace(path, old)
    os.replace(tmp, path)
    if os.path.isdir(old):
        shutil.rmtree(old)
    return str(path)


def load_state(path: str) -> Tuple[Any, Dict]:
    """Load a :func:`save_state` snapshot; returns ``(state, extra)``.

    Any failure mode — missing directory, truncated ``arrays.npz``, garbage
    or mismatched manifest — raises :class:`CheckpointError` (never hangs,
    never returns partial state).
    """
    mpath = os.path.join(path, "manifest.json")
    apath = os.path.join(path, "arrays.npz")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointError(f"unreadable checkpoint manifest at {path}: {e}") from e
    if manifest.get("format") != STATE_FORMAT:
        raise CheckpointError(
            f"checkpoint at {path} has format {manifest.get('format')!r}, "
            f"expected {STATE_FORMAT!r}")
    try:
        data = np.load(apath)
        state = _decode_state(manifest["structure"], data)
    except (OSError, KeyError, ValueError, TypeError,
            zipfile.BadZipFile, zlib.error, EOFError) as e:
        raise CheckpointError(f"corrupt checkpoint at {path}: {e}") from e
    return state, manifest.get("extra", {})
