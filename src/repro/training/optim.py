"""Pure-JAX optimizers: AdamW, Adafactor, SGD + schedules + grad clipping.

No optax in this environment — these are self-contained, pjit-friendly
(states are pytrees mirroring params, so param shardings transfer), and
deliberately match the reference semantics:

  AdamW      — Loshchilov & Hutter; fp32 moments.
  Adafactor  — Shazeer & Stern; factored second moment, no first moment by
               default.  The dry-run uses it for the ≥100B configs: ~2 extra
               bytes/param instead of AdamW's 8 (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
F32 = jnp.float32


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, F32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.asarray(base_lr, F32)


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(F32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------
# Optimizer interface
# ---------------------------------------------------------------------------


class Optimizer:
    """init(params) -> state;  update(grads, state, params) -> (updates, state).
    ``updates`` are *deltas* to add to params."""

    def init(self, params: PyTree) -> PyTree:
        raise NotImplementedError

    def update(self, grads: PyTree, state: PyTree, params: PyTree):
        raise NotImplementedError


@dataclass
class AdamW(Optimizer):
    lr: Callable = constant_schedule(1e-3)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0

    def __post_init__(self):
        if not callable(self.lr):
            self.lr = constant_schedule(self.lr)

    def init(self, params):
        z = lambda p: jnp.zeros(p.shape, F32)
        return {
            "mu": jax.tree.map(z, params),
            "nu": jax.tree.map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        if self.clip_norm:
            grads, _ = clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(F32), state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(F32)), state["nu"], grads
        )
        bc1 = 1 - b1 ** step.astype(F32)
        bc2 = 1 - b2 ** step.astype(F32)

        def upd(p, m, v):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u - lr * self.weight_decay * p.astype(F32)
            return u.astype(p.dtype)

        updates = jax.tree.map(upd, params, mu, nu)
        return updates, {"mu": mu, "nu": nu, "step": step}


@dataclass
class Adafactor(Optimizer):
    """Factored second-moment optimizer for giant models."""

    lr: Callable = constant_schedule(1e-2)
    decay: float = 0.8                # step-dependent: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    min_dim_size_to_factor: int = 128

    def __post_init__(self):
        if not callable(self.lr):
            self.lr = constant_schedule(self.lr)

    def _factored(self, shape) -> bool:
        return (
            len(shape) >= 2
            and shape[-1] >= self.min_dim_size_to_factor
            and shape[-2] >= self.min_dim_size_to_factor
        )

    def init(self, params):
        def st(p):
            if self._factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], F32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], F32),
                }
            return {"v": jnp.zeros(p.shape, F32)}

        return {"f": jax.tree.map(st, params), "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - jnp.power(step.astype(F32), -self.decay)
        lr = self.lr(step)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_p = tdef.flatten_up_to(params)
        flat_s = tdef.flatten_up_to(state["f"])
        new_s, ups = [], []
        for g, p, s in zip(flat_g, flat_p, flat_s):
            g = g.astype(F32)
            g2 = jnp.square(g) + self.eps
            if self._factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(-2)
                rms = (
                    vr[..., :, None]
                    / jnp.maximum(vr.mean(-1, keepdims=True), self.eps)[..., :, None]
                ) * vc[..., None, :]
                u = g * jax.lax.rsqrt(rms + self.eps)
                new_s.append({"vr": vr, "vc": vc})
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + self.eps)
                new_s.append({"v": v})
            # update clipping (RMS of update <= clip_threshold)
            urms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, urms / self.clip_threshold)
            u = -lr * u
            if self.weight_decay:
                u = u - lr * self.weight_decay * p.astype(F32)
            ups.append(u.astype(p.dtype))
        return (
            tdef.unflatten(ups),
            {"f": tdef.unflatten(new_s), "step": step},
        )


@dataclass
class SGD(Optimizer):
    lr: Callable = constant_schedule(1e-2)
    momentum: float = 0.0

    def __post_init__(self):
        if not callable(self.lr):
            self.lr = constant_schedule(self.lr)

    def init(self, params):
        if self.momentum:
            return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params),
                    "step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        lr = self.lr(step)
        if self.momentum:
            m = jax.tree.map(
                lambda m, g: self.momentum * m + g.astype(F32), state["m"], grads
            )
            ups = jax.tree.map(lambda p, m_: (-lr * m_).astype(p.dtype), params, m)
            return ups, {"m": m, "step": step}
        ups = jax.tree.map(lambda p, g: (-lr * g.astype(F32)).astype(p.dtype), params, grads)
        return ups, {"step": step}
