"""Wire codecs for device-cloud hidden-state transport (HAT §2.3).

HAT ships hidden states — not tokens — across the device-cloud link, so the
wire constant ``A = bytes per token`` is the single largest term in both
TTFT (chunk uploads) and TBT (draft uploads + deep-state downloads): the
paper's anchor is 8 KiB/token on Vicuna-7B (d_model=4096, fp16), i.e. 3.2 s
of transfer for a 2k prompt at 5 MB/s.  A lossy codec shrinks A and lets
Eq. 3 pick larger chunks on the same link.

Every codec quantizes **per token** (one scale per hidden-state row): the
row is the unit that crosses the wire, rows of one chunk can be encoded /
decoded independently, and absmax-per-row keeps the dequantization error
proportional to that token's own magnitude.

Codecs are numpy-level (the transport runs on the host side of the NIC);
the accelerator hot path is the Pallas quantize/pack kernels in
``repro.kernels.wire_quant`` — ``tests/test_wire.py`` pins byte-level
parity between the two.

Registry::

    fp16        2·d B/tok   lossless wire (status quo, codec id 0)
    bf16-trunc  2·d B/tok   fp32 truncated to bf16 (id 1)
    int8        d+4 B/tok   per-token absmax, 255 levels (id 2)
    int4        d/2+4 B/tok per-token absmax, 15 levels, nibble-packed (id 3)

``accept_penalty`` is the calibrated multiplicative hit on the speculative
accept probability used by the ``StatisticalBackend``: quantization noise on
the uploaded draft hidden states perturbs the cloud's verification logits,
flipping a fraction of near-tie greedy decisions.  Per-token absmax int8
keeps ~34 dB SNR on the hidden rows (measured on the reduced models in
``tests/test_wire.py``), which flips ≈3% of accepts; int4 at ~14 dB flips
≈12%; bf16 truncation (8-bit mantissa) is nearly free at ≈1%.  The
``RealBackend`` does not use the penalty — it round-trips actual hidden
states through the codec, so the measured accept lengths already carry the
true quantization error.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


def _absmax_quantize(x: np.ndarray, qmax: float) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric absmax quantization.  x: [T, D] f32.

    Matches ``repro.kernels.ref.quantize_ref`` bit-for-bit: f32 scale,
    round-half-to-even, clip to ±qmax."""
    absmax = np.max(np.abs(x), axis=-1, keepdims=True)
    scale = np.where(absmax == 0.0, np.float32(1.0), absmax / np.float32(qmax))
    scale = scale.astype(np.float32)
    q = np.clip(np.round(x / scale), -qmax, qmax).astype(np.int32)
    return q, scale


def _pack_nibbles(q: np.ndarray) -> np.ndarray:
    """Half-split nibble packing: packed[:, j] = (q[:, D/2+j] << 4) | (q[:, j] & 0xF).

    Splitting at D/2 (rather than interleaving adjacent pairs) keeps the
    pack a pure lane-slice on TPU — see kernels/wire_quant.py."""
    h = q.shape[-1] // 2
    return ((q[..., h:] << 4) | (q[..., :h] & 0xF)).astype(np.int8)


def _unpack_nibbles(p: np.ndarray) -> np.ndarray:
    p = p.astype(np.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = p >> 4
    return np.concatenate([lo, hi], axis=-1)


@dataclass(frozen=True)
class WireCodec:
    """Base codec: per-token encode/decode with exact byte accounting."""

    name: str
    codec_id: int
    lossy: bool
    accept_penalty: float

    def bytes_per_token(self, d_model: int) -> float:
        raise NotImplementedError

    def encode(self, hidden: np.ndarray) -> bytes:
        """[T, D] float -> wire payload."""
        raise NotImplementedError

    def decode(self, payload: bytes, n_tokens: int, d_model: int) -> np.ndarray:
        """wire payload -> [T, D] f32."""
        raise NotImplementedError

    # ------------------------------------------------------------ helpers
    def roundtrip(self, hidden: np.ndarray) -> np.ndarray:
        """encode∘decode on any [..., D] array (simulates one wire crossing)."""
        x = np.asarray(hidden, np.float32)
        flat = x.reshape(-1, x.shape[-1])
        out = self.decode(self.encode(flat), flat.shape[0], flat.shape[1])
        return out.reshape(x.shape)


@dataclass(frozen=True)
class Fp16Codec(WireCodec):
    """The paper's wire: raw fp16 rows, A = 2·d_model (8 KiB/tok on Vicuna).

    Marked lossless: the physical testbed already computes/ships fp16, so
    this codec is the identity wire the fp16 baselines are calibrated to."""

    def bytes_per_token(self, d_model: int) -> float:
        return 2.0 * d_model

    def encode(self, hidden: np.ndarray) -> bytes:
        return np.asarray(hidden, np.float32).astype("<f2").tobytes()

    def decode(self, payload: bytes, n_tokens: int, d_model: int) -> np.ndarray:
        x = np.frombuffer(payload, dtype="<f2", count=n_tokens * d_model)
        return x.reshape(n_tokens, d_model).astype(np.float32)


@dataclass(frozen=True)
class Fp32Codec(WireCodec):
    """Raw little-endian f32 rows: the bit-exact wire (A = 4·d_model).

    Twice the fp16 payload — never the right choice for a real link, but
    the only codec whose encode∘decode is the identity on f32 inputs.  The
    session API uses it when a caller asks for an *exact* wire (e.g. the
    losslessness tests pin speculative output == teacher greedy output,
    which only holds if the wire adds zero noise)."""

    def bytes_per_token(self, d_model: int) -> float:
        return 4.0 * d_model

    def encode(self, hidden: np.ndarray) -> bytes:
        return np.asarray(hidden, np.float32).astype("<f4").tobytes()

    def decode(self, payload: bytes, n_tokens: int, d_model: int) -> np.ndarray:
        x = np.frombuffer(payload, dtype="<f4", count=n_tokens * d_model)
        return x.reshape(n_tokens, d_model).astype(np.float32)


@dataclass(frozen=True)
class Bf16TruncCodec(WireCodec):
    """fp32 with the low 16 mantissa bits dropped (truncate-to-bf16)."""

    def bytes_per_token(self, d_model: int) -> float:
        return 2.0 * d_model

    def encode(self, hidden: np.ndarray) -> bytes:
        u = np.asarray(hidden, np.float32).view(np.uint32) >> 16
        return u.astype("<u2").tobytes()

    def decode(self, payload: bytes, n_tokens: int, d_model: int) -> np.ndarray:
        u = np.frombuffer(payload, dtype="<u2", count=n_tokens * d_model)
        x = (u.astype(np.uint32) << 16).view(np.float32)
        return x.reshape(n_tokens, d_model).copy()


@dataclass(frozen=True)
class IntCodec(WireCodec):
    """Per-token absmax integer codec; payload = f32 scales ++ packed rows.

    int8: A = d + 4;  int4 (nibble-packed pairs): A = d/2 + 4."""

    bits: int = 8

    @property
    def qmax(self) -> float:
        return 127.0 if self.bits == 8 else 7.0

    def bytes_per_token(self, d_model: int) -> float:
        vals = d_model if self.bits == 8 else d_model / 2.0
        return vals + 4.0                      # + one f32 scale per token

    def encode(self, hidden: np.ndarray) -> bytes:
        x = np.asarray(hidden, np.float32)
        if self.bits == 4 and x.shape[-1] % 2:
            raise ValueError("int4 codec requires an even d_model")
        q, scale = _absmax_quantize(x, self.qmax)
        packed = _pack_nibbles(q) if self.bits == 4 else q.astype(np.int8)
        return scale.astype("<f4").tobytes() + packed.tobytes()

    def decode(self, payload: bytes, n_tokens: int, d_model: int) -> np.ndarray:
        scale = np.frombuffer(payload, dtype="<f4", count=n_tokens)
        vals = d_model if self.bits == 8 else d_model // 2
        packed = np.frombuffer(
            payload, dtype=np.int8, count=n_tokens * vals, offset=4 * n_tokens
        ).reshape(n_tokens, vals)
        q = _unpack_nibbles(packed) if self.bits == 4 else packed.astype(np.int32)
        return q.astype(np.float32) * scale[:, None]


CODECS: Dict[str, WireCodec] = {}
_BY_ID: Dict[int, WireCodec] = {}


def register_codec(codec: WireCodec) -> WireCodec:
    if codec.name in CODECS:
        raise ValueError(f"duplicate codec name {codec.name!r}")
    if codec.codec_id in _BY_ID:
        raise ValueError(f"duplicate codec id {codec.codec_id}")
    CODECS[codec.name] = codec
    _BY_ID[codec.codec_id] = codec
    return codec


register_codec(Fp16Codec("fp16", 0, lossy=False, accept_penalty=0.0))
register_codec(Bf16TruncCodec("bf16-trunc", 1, lossy=True, accept_penalty=0.01))
register_codec(IntCodec("int8", 2, lossy=True, accept_penalty=0.03, bits=8))
register_codec(IntCodec("int4", 3, lossy=True, accept_penalty=0.12, bits=4))
register_codec(Fp32Codec("fp32", 4, lossy=False, accept_penalty=0.0))


def get_codec(name: str) -> WireCodec:
    try:
        return CODECS[name]
    except KeyError:
        raise KeyError(
            f"unknown wire codec {name!r}; registered: {sorted(CODECS)}"
        ) from None


def codec_by_id(codec_id: int) -> WireCodec:
    try:
        return _BY_ID[codec_id]
    except KeyError:
        raise KeyError(f"unknown wire codec id {codec_id}") from None
