from .codec import (
    CODECS,
    Bf16TruncCodec,
    Fp16Codec,
    Fp32Codec,
    IntCodec,
    WireCodec,
    codec_by_id,
    get_codec,
    register_codec,
)
from .framing import (
    FLAG_WANT_DEEP,
    FRAME_VERSION,
    HEADER_BYTES,
    KIND_DEEP,
    KIND_IDS,
    KIND_NAMES,
    KIND_PREFILL,
    KIND_VERIFY,
    Frame,
    decode_hidden,
    encode_hidden,
    frame_req_id,
    frame_t_send,
    iter_frames,
    stamp_t_send,
)

__all__ = [
    "CODECS", "Bf16TruncCodec", "Fp16Codec", "Fp32Codec", "IntCodec", "WireCodec",
    "codec_by_id", "get_codec", "register_codec",
    "FLAG_WANT_DEEP", "FRAME_VERSION", "HEADER_BYTES", "KIND_DEEP",
    "KIND_IDS", "KIND_NAMES", "KIND_PREFILL", "KIND_VERIFY", "Frame",
    "decode_hidden", "encode_hidden", "frame_req_id", "frame_t_send",
    "iter_frames", "stamp_t_send",
]
