"""Chunk frames: the serialized messages that cross the device-cloud wire.

The engine and the serve example exchange real byte strings instead of bare
arrays: a frame carries enough routing metadata (request, cache offset,
job kind, codec) for the receiver to decode the payload and place it at the
right KV position without side-channel state.

Layout (little-endian, 28-byte header)::

    magic    2s   b"HW"
    version  B    FRAME_VERSION
    codec_id B    repro.wire.codec registry id
    kind     B    0 prefill | 1 verify | 2 deep (cloud -> device)
    flags    B    bit 0: want_deep (device asks for deep states back)
    req_id   I
    offset   I    cache position of payload row 0
    n_tokens H
    length   I    payload byte length
    t_send   d    event timestamp (seconds, sender clock; 0 = unstamped)
    payload  length bytes (codec-encoded [n_tokens, d_model] rows)

``t_send`` is the frame *event timestamp*: transports that keep a virtual
clock (``DelayModelTransport``) stamp each uplink frame with its
send-complete time, so the cloud scheduler knows when a queued job became
available — the concurrent ``EngineRuntime`` derives batch start times from
it.  Stamping is done in place on the serialized bytes (``stamp_t_send``)
so the encode path stays codec-pure.

Frames are self-delimiting, so a TCP-style byte stream of concatenated
frames is parsed with ``iter_frames``.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Iterator

import numpy as np

from .codec import WireCodec, codec_by_id

MAGIC = b"HW"
FRAME_VERSION = 2

KIND_PREFILL = 0
KIND_VERIFY = 1
KIND_DEEP = 2
KIND_NAMES = {KIND_PREFILL: "prefill", KIND_VERIFY: "verify", KIND_DEEP: "deep"}
KIND_IDS = {v: k for k, v in KIND_NAMES.items()}

FLAG_WANT_DEEP = 1

_HEADER = struct.Struct("<2sBBBBIIHId")
HEADER_BYTES = _HEADER.size
_T_SEND_OFFSET = HEADER_BYTES - 8          # f64 tail of the header
_REQ_ID_OFFSET = 6                         # after magic/version/codec/kind/flags
_REQ_ID = struct.Struct("<I")


@dataclass(frozen=True)
class Frame:
    req_id: int
    offset: int
    kind: int                  # KIND_PREFILL | KIND_VERIFY | KIND_DEEP
    codec_id: int
    n_tokens: int
    payload: bytes
    flags: int = 0
    t_send: float = 0.0        # event timestamp (sender clock, seconds)

    @property
    def want_deep(self) -> bool:
        return bool(self.flags & FLAG_WANT_DEEP)

    @property
    def kind_name(self) -> str:
        return KIND_NAMES[self.kind]

    @property
    def codec(self) -> WireCodec:
        return codec_by_id(self.codec_id)

    def to_bytes(self) -> bytes:
        return _HEADER.pack(
            MAGIC, FRAME_VERSION, self.codec_id, self.kind, self.flags,
            self.req_id, self.offset, self.n_tokens, len(self.payload),
            self.t_send,
        ) + self.payload

    def nbytes(self) -> int:
        return HEADER_BYTES + len(self.payload)

    @classmethod
    def from_bytes(cls, data: bytes) -> "Frame":
        frame, consumed = cls.parse(data)
        if consumed != len(data):
            raise ValueError(
                f"trailing bytes after frame ({len(data) - consumed}); "
                "use iter_frames for concatenated streams"
            )
        return frame

    @classmethod
    def parse(cls, data: bytes, pos: int = 0) -> tuple["Frame", int]:
        """Parse one frame at ``data[pos:]`` -> (frame, end position)."""
        if len(data) - pos < HEADER_BYTES:
            raise ValueError("truncated frame header")
        magic, ver, codec_id, kind, flags, req_id, offset, n_tok, plen, t_send = (
            _HEADER.unpack_from(data, pos)
        )
        if magic != MAGIC:
            raise ValueError(f"bad frame magic {magic!r}")
        if ver != FRAME_VERSION:
            raise ValueError(f"unsupported frame version {ver}")
        if kind not in KIND_NAMES:
            raise ValueError(f"unknown frame kind {kind}")
        end = pos + HEADER_BYTES + plen
        if len(data) < end:
            raise ValueError("truncated frame payload")
        return cls(req_id, offset, kind, codec_id, n_tok,
                   bytes(data[pos + HEADER_BYTES:end]), flags, t_send), end


def stamp_t_send(data: bytes, t_send: float) -> bytes:
    """Rewrite a serialized frame's event timestamp in place.

    Transports own the clock, not codecs: the client encodes the frame
    once, and the transport stamps the send-complete time into the header
    tail just before handing the bytes to the receiver."""
    if len(data) < HEADER_BYTES or data[:2] != MAGIC:
        raise ValueError("not a frame")
    buf = bytearray(data)
    struct.pack_into("<d", buf, _T_SEND_OFFSET, float(t_send))
    return bytes(buf)


def frame_req_id(data: bytes) -> int:
    """Peek a serialized frame's ``req_id`` without a full parse.

    Transports use this to tag trace spans with the owning request while
    staying payload-agnostic (no decode, no copy)."""
    if len(data) < HEADER_BYTES or data[:2] != MAGIC:
        raise ValueError("not a frame")
    return _REQ_ID.unpack_from(data, _REQ_ID_OFFSET)[0]


def frame_t_send(data: bytes) -> float:
    """Peek a serialized frame's ``t_send`` stamp without a full parse.

    The socket transport reads the sender's send-complete stamp off
    arriving downlink frames to draw real wall-clock downlink spans
    (sender and receiver share the unix-epoch clock on one host)."""
    if len(data) < HEADER_BYTES or data[:2] != MAGIC:
        raise ValueError("not a frame")
    return struct.unpack_from("<d", data, _T_SEND_OFFSET)[0]


def iter_frames(stream: bytes) -> Iterator[Frame]:
    """Yield every frame in a concatenated byte stream (linear scan: only
    each frame's own payload is copied out)."""
    pos = 0
    while pos < len(stream):
        frame, pos = Frame.parse(stream, pos)
        yield frame


def encode_hidden(
    codec: WireCodec,
    hidden: np.ndarray,          # [T, D]
    *,
    req_id: int,
    offset: int,
    kind: str,
    want_deep: bool = True,
    t_send: float = 0.0,
) -> bytes:
    """Encode one chunk of hidden states as a wire frame."""
    hidden = np.asarray(hidden, np.float32)
    flags = FLAG_WANT_DEEP if want_deep else 0
    return Frame(
        req_id=req_id, offset=offset, kind=KIND_IDS[kind],
        codec_id=codec.codec_id, n_tokens=hidden.shape[0],
        payload=codec.encode(hidden), flags=flags, t_send=t_send,
    ).to_bytes()


def decode_hidden(frame: Frame, d_model: int) -> np.ndarray:
    """Decode a frame's payload back to [n_tokens, d_model] f32 rows."""
    expected = int(frame.n_tokens * frame.codec.bytes_per_token(d_model))
    if len(frame.payload) != expected:
        raise ValueError(
            f"frame payload is {len(frame.payload)} B but {frame.codec.name} "
            f"x {frame.n_tokens} tokens at d_model={d_model} needs {expected} B "
            "(sender/receiver d_model mismatch?)"
        )
    return frame.codec.decode(frame.payload, frame.n_tokens, d_model)
