"""Knowledge distillation of the adapter Λ (HAT §3.4, Eq. 4).

    Loss = SmoothL1(f^L, f^S) + w_ce · CE( H_L(f^L), H_L(f^S) )

f^L: teacher pre-head hidden states (full model, all n layers),
f^S: student pre-head hidden states (shallow m layers + adapter Λ).
Only Λ's parameters receive gradients — the shallow layers and the head are
frozen copies of the LLM's own weights (exactly the paper's setup; that is
why HAT needs to train just 67M/105M parameters vs Medusa's 591M/760M).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.layers import F32, rms_norm
from ..models.model import Model
from .adapter import adapter_forward
from .split import SplitModels

Params = Dict


def smooth_l1(x: jax.Array, y: jax.Array, beta: float = 1.0) -> jax.Array:
    d = (x - y).astype(F32)
    a = jnp.abs(d)
    return jnp.mean(jnp.where(a < beta, 0.5 * d * d / beta, a - 0.5 * beta))


def _head_logits(split: SplitModels, hidden: jax.Array) -> jax.Array:
    return split.head_logits(hidden)


def distill_loss(
    adapter_params: Params,
    split: SplitModels,
    teacher_model: Model,
    teacher_params: Params,
    tokens: jax.Array,                  # [B, T]
    *,
    w_ce: float = 0.1,
    memory=None,
) -> Tuple[jax.Array, Dict]:
    cfg = split.cfg
    # teacher pre-head hidden states f^L (stop-grad: frozen LLM)
    f_L, _, _ = teacher_model.apply(
        teacher_params, tokens, memory=memory, return_hidden=True
    )
    f_L = jax.lax.stop_gradient(f_L)

    # student: frozen shallow layers + trainable adapter
    shallow, _, _ = split.input_model.apply(
        split.input_params, tokens, memory=memory, return_hidden=True
    )
    shallow = jax.lax.stop_gradient(shallow)
    f_S, _ = adapter_forward(cfg, adapter_params, shallow)

    l_sl = smooth_l1(f_L, f_S)
    t_logits = _head_logits(split, f_L)
    s_logits = _head_logits(split, f_S)
    t_prob = jax.nn.softmax(t_logits.astype(F32), axis=-1)
    l_ce = -jnp.mean(
        jnp.sum(t_prob * jax.nn.log_softmax(s_logits.astype(F32), axis=-1), axis=-1)
    )
    loss = l_sl + w_ce * l_ce
    # top-1 agreement: the quantity that drives speculative accept length
    agree = jnp.mean(
        (jnp.argmax(t_logits, -1) == jnp.argmax(s_logits, -1)).astype(F32)
    )
    return loss, {"loss": loss, "smooth_l1": l_sl, "ce": l_ce, "agree": agree}


def make_distill_step(split: SplitModels, teacher_model: Model, teacher_params,
                      optimizer, w_ce: float = 0.1):
    """Returns a jitted ``step(adapter_params, opt_state, tokens) ->
    (adapter_params, opt_state, metrics)`` closure."""

    def step(adapter_params, opt_state, tokens):
        (loss, metrics), grads = jax.value_and_grad(distill_loss, has_aux=True)(
            adapter_params, split, teacher_model, teacher_params, tokens, w_ce=w_ce
        )
        updates, opt_state = optimizer.update(grads, opt_state, adapter_params)
        adapter_params = jax.tree.map(lambda p, u: p + u, adapter_params, updates)
        return adapter_params, opt_state, metrics

    return jax.jit(step)
