"""Prompt chunking and the optimal-chunk-size solver (HAT §3.3, Eq. 3).

Eq. (3) balances per-chunk upload time against in-cloud compute time so the
pipeline has no bubbles:

    X_i · A / β_up  =  ( g(μ) + g(μ + X_i) ) / P

LHS: time to upload one chunk's hidden states (X_i tokens × A bytes each).
RHS: waiting delay (≈ one average batch, g(μ)) plus the chunk's own
computation delay g(μ + X_i), both divided by the cloud's parallel speedup
P (the paper's pipeline length; on the TPU mesh, the throughput scaling of
the sharded middle model — DESIGN.md §3).

LHS is strictly increasing and unbounded in X; RHS is increasing but
near-affine with a small slope, so there is a unique crossing — found by
integer bisection and clamped to [min_chunk, prompt_len].
"""
from __future__ import annotations

from typing import Callable, List

from .monitor import DelayPredictor


def optimal_chunk_size(
    *,
    prompt_len: int,
    hidden_bytes_per_token: float,     # A in Eq. (3)
    beta_up: float,                    # bytes/s
    g: Callable[[float], float],       # delay predictor (seconds)
    mu: float,                         # current EWMA batched token size
    pipeline_len: int = 1,             # P
    min_chunk: int = 32,
    max_chunk: int = 4096,
    align: int = 8,
    cold_start_chunk: int = 128,
) -> int:
    """Solve Eq. (3) for X_i."""
    A, P = hidden_bytes_per_token, max(pipeline_len, 1)
    if g(1) <= 0.0:
        # no workload observations yet: fall back to a fixed default until
        # the state monitor warms up (first few batches)
        return min(cold_start_chunk, max(prompt_len, min_chunk))

    def lhs(x: float) -> float:
        return x * A / max(beta_up, 1e-9)

    def rhs(x: float) -> float:
        return (g(mu) + g(mu + x)) / P

    lo, hi = min_chunk, min(max_chunk, max(prompt_len, min_chunk))
    if lhs(lo) >= rhs(lo):          # upload already dominates at min size
        x = lo
    elif lhs(hi) <= rhs(hi):        # compute dominates even at max size
        x = hi
    else:
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if lhs(mid) < rhs(mid):
                lo = mid
            else:
                hi = mid
        x = hi
    x = max(min_chunk, min(x, prompt_len))
    return max(align, (x // align) * align)


def pipelined_prefill_time(
    chunks: List[int],
    *,
    up_time: Callable[[int], float],
    step_time: Callable[[int], float],
    pipeline_depth: int = 0,
) -> float:
    """Completion time (seconds) of a chunk plan under uplink/compute
    overlap — the §4.2 delay model *with* transmission/processing
    parallelism instead of the bubble-free fixed point.

    Chunk ``i`` uploads as soon as the link is free (sends serialize) and,
    with ``pipeline_depth`` > 0, no earlier than chunk ``i-depth``'s
    processing finishes (the sender's bounded window); the cloud processes
    chunks in order, each starting at ``max(upload done, previous chunk
    done)``.  ``pipeline_depth=0`` models the unbounded streaming window.
    Returns the last chunk's processing-finish time; downlink + head are
    plan-independent constants and excluded."""
    finish = 0.0                      # cloud finish time of the previous chunk
    finishes: List[float] = []
    link_free = 0.0
    for i, c in enumerate(chunks):
        send_at = link_free
        if pipeline_depth > 0 and i >= pipeline_depth:
            send_at = max(send_at, finishes[i - pipeline_depth])
        uploaded = send_at + up_time(c)
        finish = max(uploaded, finish) + step_time(c)
        finishes.append(finish)
        link_free = uploaded
    return finish


def optimal_chunk_size_pipelined(
    *,
    prompt_len: int,
    hidden_bytes_per_token: float,
    beta_up: float,
    g: Callable[[float], float],
    mu: float,
    pipeline_len: int = 1,
    pipeline_depth: int = 1,
    min_chunk: int = 32,
    max_chunk: int = 4096,
    align: int = 8,
    cold_start_chunk: int = 128,
) -> int:
    """Pick the chunk size minimizing :func:`pipelined_prefill_time`.

    Eq. (3)'s fixed point balances *one* chunk's upload against its
    compute; with a bounded in-flight window the right objective is the
    whole plan's overlapped completion time, which this minimizes by
    direct search over aligned candidate sizes (the candidate set is tiny
    — O(max_chunk / align) — and each evaluation is O(n_chunks)).  Ties
    prefer the larger size: fewer frames, same finish time."""
    if g(1) <= 0.0:
        return min(cold_start_chunk, max(prompt_len, min_chunk))
    A, P = hidden_bytes_per_token, max(pipeline_len, 1)

    def up(x: int) -> float:
        return x * A / max(beta_up, 1e-9)

    def step(x: int) -> float:
        return (g(mu) + g(mu + x)) / P

    hi = min(max_chunk, max(prompt_len, min_chunk))
    lo = max(align, (min_chunk // align) * align)
    best_x, best_t = hi, float("inf")
    for x in range(lo, hi + 1, align):
        t = pipelined_prefill_time(
            chunk_prompt(prompt_len, x),
            up_time=up, step_time=step, pipeline_depth=pipeline_depth,
        )
        if t < best_t - 1e-12 or (abs(t - best_t) <= 1e-12 and x > best_x):
            best_x, best_t = x, t
    return max(align, min(best_x, max(prompt_len, align)))


def plan_chunks(
    prompt_len: int,
    *,
    pc: "str | None",                  # None | "device" | "server"
    dynamic_chunks: bool = True,
    fixed_chunk: int = 128,
    hidden_bytes_per_token: float = 0.0,
    beta_up: float = 7.5e6,
    g: "Callable[[float], float] | None" = None,
    mu: float = 64.0,
    pipeline_len: int = 1,
    pipeline_depth: int = 0,
) -> List[int]:
    """Framework-aware chunk plan for one prompt (shared by the simulator
    and the session-API DeviceClient so both speak the same Eq. 3).

    * ``pc="device"`` + ``dynamic_chunks``: HAT — solve Eq. (3) with the
      monitored link/workload state (falls back to ``fixed_chunk`` before
      any workload observations exist, i.e. ``g`` is None or cold).  With
      ``pipeline_depth`` > 0 the solver switches to the windowed-overlap
      objective (:func:`optimal_chunk_size_pipelined`).
    * ``pc="device"`` or ``pc="server"`` without dynamics: Sarathi-style
      fixed chunks.
    * ``pc=None``: one bulk chunk (plain U-shape).
    """
    if pc is None:
        return [prompt_len]
    if pc == "device" and dynamic_chunks and g is not None:
        if pipeline_depth > 0:
            x = optimal_chunk_size_pipelined(
                prompt_len=prompt_len,
                hidden_bytes_per_token=hidden_bytes_per_token,
                beta_up=beta_up, g=g, mu=mu, pipeline_len=pipeline_len,
                pipeline_depth=pipeline_depth,
                cold_start_chunk=fixed_chunk,
            )
        else:
            x = optimal_chunk_size(
                prompt_len=prompt_len,
                hidden_bytes_per_token=hidden_bytes_per_token,
                beta_up=beta_up, g=g, mu=mu, pipeline_len=pipeline_len,
                cold_start_chunk=fixed_chunk,
            )
    else:
        x = fixed_chunk
    return chunk_prompt(prompt_len, x)


def chunk_prompt(prompt_len: int, chunk_size: int) -> List[int]:
    """Split ``prompt_len`` into chunk lengths (last chunk may be short)."""
    assert prompt_len > 0 and chunk_size > 0
    full, rem = divmod(prompt_len, chunk_size)
    out = [chunk_size] * full
    if rem:
        out.append(rem)
    return out


def chunk_offsets(chunks: List[int]) -> List[int]:
    off, out = 0, []
    for c in chunks:
        out.append(off)
        off += c
    return out
