"""Prompt chunking and the optimal-chunk-size solver (HAT §3.3, Eq. 3).

Eq. (3) balances per-chunk upload time against in-cloud compute time so the
pipeline has no bubbles:

    X_i · A / β_up  =  ( g(μ) + g(μ + X_i) ) / P

LHS: time to upload one chunk's hidden states (X_i tokens × A bytes each).
RHS: waiting delay (≈ one average batch, g(μ)) plus the chunk's own
computation delay g(μ + X_i), both divided by the cloud's parallel speedup
P (the paper's pipeline length; on the TPU mesh, the throughput scaling of
the sharded middle model — DESIGN.md §3).

LHS is strictly increasing and unbounded in X; RHS is increasing but
near-affine with a small slope, so there is a unique crossing — found by
integer bisection and clamped to [min_chunk, prompt_len].
"""
from __future__ import annotations

from typing import Callable, List

from .monitor import DelayPredictor


def optimal_chunk_size(
    *,
    prompt_len: int,
    hidden_bytes_per_token: float,     # A in Eq. (3)
    beta_up: float,                    # bytes/s
    g: Callable[[float], float],       # delay predictor (seconds)
    mu: float,                         # current EWMA batched token size
    pipeline_len: int = 1,             # P
    min_chunk: int = 32,
    max_chunk: int = 4096,
    align: int = 8,
    cold_start_chunk: int = 128,
) -> int:
    """Solve Eq. (3) for X_i."""
    A, P = hidden_bytes_per_token, max(pipeline_len, 1)
    if g(1) <= 0.0:
        # no workload observations yet: fall back to a fixed default until
        # the state monitor warms up (first few batches)
        return min(cold_start_chunk, max(prompt_len, min_chunk))

    def lhs(x: float) -> float:
        return x * A / max(beta_up, 1e-9)

    def rhs(x: float) -> float:
        return (g(mu) + g(mu + x)) / P

    lo, hi = min_chunk, min(max_chunk, max(prompt_len, min_chunk))
    if lhs(lo) >= rhs(lo):          # upload already dominates at min size
        x = lo
    elif lhs(hi) <= rhs(hi):        # compute dominates even at max size
        x = hi
    else:
        while hi - lo > 1:
            mid = (lo + hi) // 2
            if lhs(mid) < rhs(mid):
                lo = mid
            else:
                hi = mid
        x = hi
    x = max(min_chunk, min(x, prompt_len))
    return max(align, (x // align) * align)


def plan_chunks(
    prompt_len: int,
    *,
    pc: "str | None",                  # None | "device" | "server"
    dynamic_chunks: bool = True,
    fixed_chunk: int = 128,
    hidden_bytes_per_token: float = 0.0,
    beta_up: float = 7.5e6,
    g: "Callable[[float], float] | None" = None,
    mu: float = 64.0,
    pipeline_len: int = 1,
) -> List[int]:
    """Framework-aware chunk plan for one prompt (shared by the simulator
    and the session-API DeviceClient so both speak the same Eq. 3).

    * ``pc="device"`` + ``dynamic_chunks``: HAT — solve Eq. (3) with the
      monitored link/workload state (falls back to ``fixed_chunk`` before
      any workload observations exist, i.e. ``g`` is None or cold).
    * ``pc="device"`` or ``pc="server"`` without dynamics: Sarathi-style
      fixed chunks.
    * ``pc=None``: one bulk chunk (plain U-shape).
    """
    if pc is None:
        return [prompt_len]
    if pc == "device" and dynamic_chunks and g is not None:
        x = optimal_chunk_size(
            prompt_len=prompt_len,
            hidden_bytes_per_token=hidden_bytes_per_token,
            beta_up=beta_up, g=g, mu=mu, pipeline_len=pipeline_len,
            cold_start_chunk=fixed_chunk,
        )
    else:
        x = fixed_chunk
    return chunk_prompt(prompt_len, x)


def chunk_prompt(prompt_len: int, chunk_size: int) -> List[int]:
    """Split ``prompt_len`` into chunk lengths (last chunk may be short)."""
    assert prompt_len > 0 and chunk_size > 0
    full, rem = divmod(prompt_len, chunk_size)
    out = [chunk_size] * full
    if rem:
        out.append(rem)
    return out


def chunk_offsets(chunks: List[int]) -> List[int]:
    off, out = 0, []
    for c in chunks:
        out.append(off)
        off += c
    return out
