"""U-shaped model partition (HAT §2.2, §3.4).

``split_model(cfg, params)`` partitions an LLM into three submodels:

  input submodel   — embedding + first ``m = cfg.hat_shallow_layers`` decoder
                     layers (on-device, "shallow" hidden states leave here),
  middle submodel  — layers ``m..n`` (in the cloud; the heavy part),
  output submodel  — final norm + LM head (on-device: raw output tokens
                     never leave the device).

Each submodel is a real :class:`repro.models.Model` over a derived config
with an explicit layer pattern, so every arch family splits the same way
(the pattern prefix/suffix keeps windows, MoE, SSM kinds, shared-attn flags).
Parameters are re-grouped from the full model's stacked scan groups; the
same code paths work on real arrays and on ShapeDtypeStructs (dry-run).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import Model, group_layers

Params = Dict
PyTree = Any


def _is_sds(a) -> bool:
    return isinstance(a, jax.ShapeDtypeStruct)


def _take(a, r: int):
    if _is_sds(a):
        return jax.ShapeDtypeStruct(a.shape[1:], a.dtype)
    return a[r]


def _stack(leaves: List):
    if _is_sds(leaves[0]):
        return jax.ShapeDtypeStruct((len(leaves),) + leaves[0].shape, leaves[0].dtype)
    return jnp.stack(leaves)


def unstack_layers(model: Model, params: Params) -> List[Params]:
    """Full params -> ordered list of per-layer param dicts."""
    out: List[Params] = []
    for gi, (body, reps) in enumerate(model.groups):
        gp = params["groups"][gi]
        for r in range(reps):
            for li in range(len(body)):
                out.append(jax.tree.map(lambda a: _take(a, r), gp[f"l{li}"]))
    return out


def stack_layers(model: Model, layer_params: List[Params]) -> List[Params]:
    """Ordered per-layer params -> stacked scan-group params for ``model``."""
    groups = []
    idx = 0
    for body, reps in model.groups:
        # gather [reps][len(body)] layer dicts
        per_pos: Dict[str, List[Params]] = {f"l{li}": [] for li in range(len(body))}
        for _ in range(reps):
            for li in range(len(body)):
                per_pos[f"l{li}"].append(layer_params[idx])
                idx += 1
        gp = {
            k: jax.tree.map(lambda *xs: _stack(list(xs)), *v)
            for k, v in per_pos.items()
        }
        groups.append(gp)
    assert idx == len(layer_params)
    return groups


def derive_configs(cfg: ModelConfig):
    """Derived (input, middle) submodel configs; output submodel is the head."""
    m = cfg.hat_shallow_layers
    layers = cfg.layers
    assert 0 < m < cfg.n_layers
    cfg_in = dataclasses.replace(
        cfg,
        name=cfg.name + "-hat-input",
        n_layers=m,
        pattern=layers[:m],
        include_embed=True,
        include_head=False,
        # encoder memory is produced cloud-side; device layers only consume it
        is_encoder_decoder=False,
        n_encoder_layers=0,
    )
    cfg_mid = dataclasses.replace(
        cfg,
        name=cfg.name + "-hat-middle",
        n_layers=cfg.n_layers - m,
        pattern=layers[m:],
        include_embed=False,
        include_head=False,
    )
    return cfg_in, cfg_mid


@dataclass
class SplitModels:
    cfg: ModelConfig
    m: int
    input_model: Model
    middle_model: Model
    input_params: Params
    middle_params: Params
    output_params: Params          # {"final_norm", "head"?, "embed"? (tied)}

    # ------------------------------------------------------------- helpers
    def device_forward(self, tokens, cache=None, offset=0, memory=None):
        """Input submodel: tokens -> shallow hidden states (uploaded)."""
        return self.input_model.apply(
            self.input_params, tokens, cache=cache, offset=offset,
            memory=memory, return_hidden=True,
        )

    def middle_forward(self, hidden, cache=None, offset=0, memory=None):
        """Middle submodel (cloud): shallow -> deep hidden states."""
        return self.middle_model.apply(
            self.middle_params, None, inputs_embeds=hidden, cache=cache,
            offset=offset, memory=memory, return_hidden=True,
        )

    def head_logits(self, hidden: jax.Array) -> jax.Array:
        """Output submodel: deep hidden states -> logits (on-device)."""
        from ..models.layers import rms_norm

        p = self.output_params
        x = rms_norm(hidden, p["final_norm"], self.cfg.rmsnorm_eps)
        head = p["embed"].T if self.cfg.tie_embeddings else p["head"]
        return x @ head

    def bytes_per_token_hidden(self, dtype_bytes: int = 2) -> int:
        """A in Eq. (3): size of one token's hidden state on the wire."""
        return self.cfg.d_model * dtype_bytes


def split_model(cfg: ModelConfig, params: Optional[Params], dtype=jnp.float32) -> SplitModels:
    """Partition ``params`` of the full model into the three submodels.

    ``params`` may be real arrays or ShapeDtypeStructs (abstract split for
    the dry-run).  If ``params`` is None, submodels get freshly-initialized
    parameters (useful for tests).
    """
    cfg_in, cfg_mid = derive_configs(cfg)
    m = cfg.hat_shallow_layers
    full_model = Model(cfg, dtype=dtype)
    input_model = Model(cfg_in, dtype=dtype)
    middle_model = Model(cfg_mid, dtype=dtype)

    if params is None:
        params = full_model.init(jax.random.PRNGKey(0))

    layers = unstack_layers(full_model, params)
    in_p: Params = {"groups": stack_layers(input_model, layers[:m])}
    mid_p: Params = {"groups": stack_layers(middle_model, layers[m:])}

    in_p["embed"] = params["embed"]
    out_p: Params = {"final_norm": params["final_norm"]}
    if cfg.tie_embeddings:
        out_p["embed"] = params["embed"]
    else:
        out_p["head"] = params["head"]

    if "shared_attn" in params:
        # zamba2: the shared block params go wherever its layers live
        if any(ld.shared_attn for ld in cfg_in.layers):
            in_p["shared_attn"] = params["shared_attn"]
        if any(ld.shared_attn for ld in cfg_mid.layers):
            mid_p["shared_attn"] = params["shared_attn"]
    if cfg.is_encoder_decoder:
        mid_p["encoder"] = params["encoder"]

    return SplitModels(
        cfg=cfg, m=m,
        input_model=input_model, middle_model=middle_model,
        input_params=in_p, middle_params=mid_p, output_params=out_p,
    )
