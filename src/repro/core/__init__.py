"""HAT's core contribution: U-shaped split + adapter speculative decoding +
prompt chunking + parallel drafting + state monitoring."""
from .adapter import (
    DraftModel,
    adapter_forward,
    adapter_param_count,
    init_adapter,
    init_adapter_cache,
)
from .chunking import chunk_offsets, chunk_prompt, optimal_chunk_size, plan_chunks
from .distill import distill_loss, make_distill_step, smooth_l1
from .monitor import DelayPredictor, DeviceState, Ewma, StateMonitor
from .parallel_draft import (
    CandidateDrafts,
    parallel_draft_steps,
    predraft_candidates,
)
from .speculative import (
    DraftResult,
    accept_greedy_rows,
    draft_until_threshold,
    has_ssm_state,
    restore_states,
    snapshot_states,
)
from .split import SplitModels, derive_configs, split_model, stack_layers, unstack_layers

__all__ = [
    "DraftModel", "adapter_forward", "adapter_param_count", "init_adapter",
    "init_adapter_cache", "chunk_offsets", "chunk_prompt",
    "optimal_chunk_size", "plan_chunks", "distill_loss", "make_distill_step",
    "smooth_l1",
    "DelayPredictor", "DeviceState", "Ewma", "StateMonitor",
    "CandidateDrafts", "parallel_draft_steps", "predraft_candidates",
    "DraftResult", "accept_greedy_rows", "draft_until_threshold",
    "has_ssm_state", "restore_states", "snapshot_states",
    "SplitModels", "derive_configs", "split_model", "stack_layers",
    "unstack_layers",
]
