"""Speculative decoding: threshold-adaptive drafting (Eq. 5), U-shaped
verification, greedy acceptance, and SSM-state rollback.

Protocol per round (HAT §3.4):
  1. drafting: the on-device draft model w_S generates tokens
     autoregressively until ``softmax prob < η`` (Eq. 5) or ``max_draft``.
  2. verification: the draft tokens pass through the device's shallow
     layers; the *shallow hidden states* (not tokens!) go to the cloud; the
     middle submodel produces deep hidden states, which return to the device
     where the head emits logits.
  3. acceptance: longest prefix of draft tokens matching the LLM's greedy
     choice is accepted; the LLM's token at the first divergence (or after
     the last accepted draft) is the bonus token of the next round.

KV-cache rollback is positional: caches are always written at
``offset = accepted_len``, so rejected entries are simply overwritten in
the next round (full-attention caches mask beyond the current position).
SSM/hybrid archs carry state, not positions — ``snapshot_states`` /
``restore_states`` + ``advance`` implement rollback by re-running the
accepted prefix from the pre-verification snapshot (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig

Params = Dict


# ---------------------------------------------------------------------------
# drafting (device side)
# ---------------------------------------------------------------------------


@dataclass
class DraftResult:
    tokens: np.ndarray            # [k] drafted token ids
    probs: np.ndarray             # [k] their softmax probabilities
    topk_last: np.ndarray         # [topk] candidates at the last draft step
    steps: int                    # drafting steps executed


def draft_until_threshold(
    draft_model,
    cache,
    last_token: jax.Array,          # [B=1, 1]
    offset: int,
    *,
    eta: float = 0.6,
    max_draft: int = 8,
    topk: int = 4,
    memory=None,
) -> Tuple[DraftResult, Params, int]:
    """Autoregressive drafting with the Eq. 5 stop rule (batch of one device;
    the fleet dimension is the simulator's, not the array's).

    Returns (result, updated draft cache, new offset).  The cache contains
    the *draft model's own* KV entries for the drafted tokens; they are
    positionally rolled back by the next round's offset if rejected.
    """
    toks: List[int] = []
    probs: List[float] = []
    tok = last_token
    off = offset
    topk_last = None
    for step in range(max_draft):
        logits, cache, _ = draft_model.forward(tok, cache=cache, offset=off, memory=memory)
        off += tok.shape[1]
        p = jax.nn.softmax(logits[0, -1].astype(jnp.float32))
        nxt = int(jnp.argmax(p))
        pmax = float(p[nxt])
        tk = jax.lax.top_k(p, topk)[1]
        toks.append(nxt)
        probs.append(pmax)
        topk_last = np.asarray(tk)
        tok = jnp.array([[nxt]], dtype=jnp.int32)
        if pmax < eta:                      # Eq. (5): stop drafting
            break
    return (
        DraftResult(
            tokens=np.asarray(toks, np.int32),
            probs=np.asarray(probs, np.float32),
            topk_last=topk_last,
            steps=len(toks),
        ),
        cache,
        off,
    )


# ---------------------------------------------------------------------------
# acceptance (device side, after verification logits arrive)
# ---------------------------------------------------------------------------


def accept_greedy_rows(
    draft_tokens: np.ndarray,        # [k]
    target_logits: np.ndarray,       # [k+1, V]; row i predicts draft[i],
                                     # row k predicts the token after draft[k-1]
) -> Tuple[int, int]:
    """Longest-prefix greedy acceptance (HAT verifies by exact match).

    The verification step feeds [bonus_token, draft_0..draft_{k-1}] through
    the full U-shaped path, yielding k+1 logit rows; row i is the LLM's
    distribution for the position draft_i occupies.  Returns
    (n_accepted, next_token) where next_token is the LLM's greedy token at
    the first divergence — the "bonus" token seeding the next round.
    """
    greedy = np.asarray(target_logits).argmax(-1)
    k = len(draft_tokens)
    n = 0
    while n < k and int(draft_tokens[n]) == int(greedy[n]):
        n += 1
    return n, int(greedy[n])


# ---------------------------------------------------------------------------
# SSM / hybrid rollback
# ---------------------------------------------------------------------------

SSM_STATE_KEYS = ("m2", "ml", "sl")
_SSM_KEYS = SSM_STATE_KEYS


def snapshot_states(cache) -> Dict:
    """Copy the recurrent-state pieces of a cache pytree (cheap: states are
    O(B·d·state), not O(S))."""

    def pick(piece):
        return {k: v for k, v in piece.items() if k in _SSM_KEYS}

    snap = []
    for g in cache["groups"]:
        snap.append({k: pick(v) for k, v in g.items()})
    return jax.tree.map(lambda a: a, {"groups": snap})     # shallow copy


def restore_states(cache, snap) -> Dict:
    """Overwrite the recurrent-state pieces of ``cache`` from ``snap``."""
    new_groups = []
    for g, sg in zip(cache["groups"], snap["groups"]):
        ng = {}
        for lk, piece in g.items():
            np_ = dict(piece)
            for k in _SSM_KEYS:
                if k in sg.get(lk, {}):
                    np_[k] = sg[lk][k]
            ng[lk] = np_
        new_groups.append(ng)
    return {"groups": new_groups}


def has_ssm_state(cfg: ModelConfig) -> bool:
    return any(ld.kind in ("mamba2", "mlstm", "slstm") for ld in cfg.layers)
