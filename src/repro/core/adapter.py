"""The lightweight adapter network Λ and the on-device draft model (HAT §3.4).

Λ has the structure of a decoder layer's *self-attention module* (the paper
picks attention over the FFN because it has fewer parameters and lower
compute delay).  The draft model is

    w_S = H_L ∘ Λ ∘ w_L^m

head ∘ adapter ∘ shallow-layers.  Λ is trained by knowledge distillation to
mimic the cloud's middle submodel (core/distill.py); at serve time the
device drafts autoregressively with w_S (core/speculative.py).

Λ is an attention block for every arch family — it consumes d_model hidden
states regardless of what the middle submodel is built from (MoE, SSM, ...),
which is exactly the paper's construction.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.layers import init_attn, init_mlp, rms_norm
from ..models.model import Model, _attn_block, _Ctx
from .split import SplitModels

Params = Dict


def init_adapter(cfg: ModelConfig, key, dtype=jnp.float32) -> Tuple[Params, Params]:
    """Adapter Λ: ``cfg.adapter_layers`` self-attention blocks."""
    ks = jax.random.split(key, max(cfg.adapter_layers, 1))
    p, s = {}, {}
    for i in range(cfg.adapter_layers):
        p[f"a{i}"], s[f"a{i}"] = init_attn(cfg, ks[i], dtype)
    return p, s


def adapter_param_count(cfg: ModelConfig) -> int:
    d, nh, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    per = d + d * nh * hd + 2 * d * nkv * hd + nh * hd * d
    if cfg.qkv_bias:
        per += nh * hd + 2 * nkv * hd
    return cfg.adapter_layers * per


def init_adapter_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    nkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        f"a{i}": {
            "k": jnp.zeros((batch, nkv, max_len, hd), dtype),
            "v": jnp.zeros((batch, nkv, max_len, hd), dtype),
        }
        for i in range(cfg.adapter_layers)
    }


def adapter_forward(
    cfg: ModelConfig,
    adapter_params: Params,
    hidden: jax.Array,                 # [B, T, D] shallow hidden states
    cache: Optional[Params] = None,
    offset=0,
) -> Tuple[jax.Array, Optional[Params]]:
    ctx = _Ctx(jnp.asarray(offset, jnp.int32), None, None, cache is None)
    new_cache = {} if cache is not None else None
    x = hidden
    for i in range(cfg.adapter_layers):
        x, c = _attn_block(
            cfg, adapter_params[f"a{i}"], x,
            None if cache is None else cache[f"a{i}"], ctx, None,
        )
        if new_cache is not None:
            new_cache[f"a{i}"] = c
    return x, new_cache


class DraftModel:
    """w_S = head ∘ Λ ∘ shallow-layers: the on-device SLM."""

    def __init__(self, split: SplitModels, adapter_params: Params):
        self.split = split
        self.cfg = split.cfg
        self.adapter_params = adapter_params

    def init_cache(self, batch: int, max_len: int, memory=None, dtype=None):
        dtype = dtype or self.split.input_model.dtype
        return {
            "input": self.split.input_model.init_cache(
                self.split.input_params, batch, max_len, memory=memory, dtype=dtype
            ),
            "adapter": init_adapter_cache(self.cfg, batch, max_len, dtype),
        }

    def forward(
        self, tokens: jax.Array, cache=None, offset=0, memory=None,
    ):
        """tokens [B, T] -> (logits [B, T, V], new_cache, shallow_hidden)."""
        shallow, in_cache, _ = self.split.input_model.apply(
            self.split.input_params, tokens,
            cache=None if cache is None else cache["input"],
            offset=offset, memory=memory, return_hidden=True,
        )
        deep_hat, ad_cache = adapter_forward(
            self.cfg, self.adapter_params, shallow,
            None if cache is None else cache["adapter"], offset,
        )
        logits = self.split.head_logits(deep_hat)
        new_cache = None
        if cache is not None:
            new_cache = {"input": in_cache, "adapter": ad_cache}
        return logits, new_cache, shallow

    def hidden_forward(self, tokens, cache=None, offset=0, memory=None):
        """Like forward but returns the adapter's pre-head hidden states
        (f^S in Eq. 4) — used by distillation."""
        shallow, in_cache, _ = self.split.input_model.apply(
            self.split.input_params, tokens,
            cache=None if cache is None else cache["input"],
            offset=offset, memory=memory, return_hidden=True,
        )
        deep_hat, ad_cache = adapter_forward(
            self.cfg, self.adapter_params, shallow,
            None if cache is None else cache["adapter"], offset,
        )
        return deep_hat
