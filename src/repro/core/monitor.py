"""State monitoring module (HAT §3.2, Eqs. 1–2).

The cloud tracks its own workload — batched token size μ^t and per-batch
computation delay η^t — with EWMA smoothing (α = 0.8), and maintains a
predictive function g^t(·) mapping batched-token-size → in-cloud computation
delay.  g is a binned piecewise-linear regressor updated online with the
same EWMA rule (Eq. 2).  Devices track their drafting delay γ_i and up/down
bandwidths β_i with the same smoothing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np


class Ewma:
    """x^t = α·x^{t-1} + (1-α)·x̂^t   (Eq. 1)."""

    def __init__(self, alpha: float = 0.8, init: Optional[float] = None):
        self.alpha = alpha
        self.value: Optional[float] = init

    def update(self, sample: float) -> float:
        if self.value is None:
            self.value = float(sample)
        else:
            self.value = self.alpha * self.value + (1 - self.alpha) * float(sample)
        return self.value

    def get(self, default: float = 0.0) -> float:
        return self.value if self.value is not None else default


class DelayPredictor:
    """g^t(·): batched token size -> in-cloud computation delay (seconds).

    Log2-spaced bins over token size; each bin holds an EWMA of observed
    delays (Eq. 2); prediction linearly interpolates between the nearest
    populated bins and extrapolates linearly beyond them (in-cloud delay is
    near-affine in batched tokens once compute saturates — Fig. 1(c))."""

    def __init__(self, alpha: float = 0.8, max_tokens: int = 1 << 20):
        self.alpha = alpha
        self.edges = [0] + [2 ** i for i in range(0, int(math.log2(max_tokens)) + 1)]
        self.bins: Dict[int, Ewma] = {}

    def _bin(self, tokens: float) -> int:
        t = max(tokens, 1.0)
        return min(int(math.log2(t)) + 1, len(self.edges) - 1)

    def update(self, tokens: float, delay: float) -> None:
        b = self._bin(tokens)
        self.bins.setdefault(b, Ewma(self.alpha)).update(delay)

    def predict(self, tokens: float) -> float:
        if not self.bins:
            return 0.0
        pts = sorted((self.edges[b], e.get()) for b, e in self.bins.items())
        xs = np.array([p[0] for p in pts], dtype=np.float64)
        ys = np.array([p[1] for p in pts], dtype=np.float64)
        t = max(tokens, 1.0)
        if len(xs) == 1:
            # single observation: scale ∝ tokens beyond the observed point
            return float(ys[0] * max(1.0, t / max(xs[0], 1.0)))
        if t >= xs[-1]:
            # delays are non-negative: noisy bins can give the tail a
            # negative slope, and unclamped linear extrapolation would then
            # predict negative delays far past the last bin (which breaks
            # the Eq. 3 chunk solver's cost comparison)
            slope = (ys[-1] - ys[-2]) / max(xs[-1] - xs[-2], 1e-9)
            return float(max(ys[-1] + slope * (t - xs[-1]), 0.0))
        return float(max(np.interp(t, xs, ys), 0.0))


@dataclass
class DeviceState:
    """Per-device EWMAs: γ_i (s per draft step), β_up/β_down (bytes/s)."""

    gamma: Ewma = field(default_factory=lambda: Ewma(0.8))
    beta_up: Ewma = field(default_factory=lambda: Ewma(0.8))
    beta_down: Ewma = field(default_factory=lambda: Ewma(0.8))


class StateMonitor:
    """Cloud-side aggregation of workload + device states (HAT §3.2)."""

    def __init__(self, alpha: float = 0.8):
        self.alpha = alpha
        self.mu = Ewma(alpha)                  # batched token size μ^t
        self.eta = Ewma(alpha)                 # batch computation delay η^t
        self.g = DelayPredictor(alpha)
        self.devices: Dict[int, DeviceState] = {}

    # --- cloud-side updates (each batch step) ------------------------------
    def record_batch(self, batched_tokens: int, compute_delay: float) -> None:
        self.mu.update(batched_tokens)
        self.eta.update(compute_delay)
        self.g.update(batched_tokens, compute_delay)

    # --- device-side reports (piggybacked on verification messages) --------
    def device(self, dev_id: int) -> DeviceState:
        return self.devices.setdefault(dev_id, DeviceState())

    def record_device(self, dev_id: int, *, gamma: Optional[float] = None,
                      beta_up: Optional[float] = None,
                      beta_down: Optional[float] = None) -> None:
        d = self.device(dev_id)
        if gamma is not None:
            d.gamma.update(gamma)
        if beta_up is not None:
            d.beta_up.update(beta_up)
        if beta_down is not None:
            d.beta_down.update(beta_down)

    # --- predictions --------------------------------------------------------
    def predict_delay(self, extra_tokens: int = 0) -> float:
        return self.g.predict(self.mu.get() + extra_tokens)
