"""Parallel drafting module (HAT §3.5, Eq. 6).

While a verification round-trips through the cloud, the device is idle.
HAT pre-drafts the *next* round: the top-k candidates of the last draft
step each seed a candidate continuation; when the verification result
arrives, if the corrected token matches one of the candidates, its
pre-drafted sequence is reused — the next drafting stage costs ~0.

λ_i (Eq. 6) bounds how many pre-draft steps fit inside the verification
round trip:

    λ_i = ⌊ ( μ_i·A/β_up + g(μ) + μ_i·A/β_down ) / γ_i ⌋

μ_i: draft length this round, A: hidden-state bytes/token, γ_i: per-step
drafting delay.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np


def parallel_draft_steps(
    *,
    draft_len: int,                   # μ_i
    hidden_bytes_per_token: float,    # A
    beta_up: float,
    beta_down: float,
    g_mu: float,                      # g^t(μ^t)
    gamma: float,                     # per-step drafting delay γ_i
    max_steps: int = 16,
) -> int:
    """Eq. (6)."""
    if gamma <= 0:
        return max_steps
    rt = (
        draft_len * hidden_bytes_per_token / max(beta_up, 1e-9)
        + g_mu
        + draft_len * hidden_bytes_per_token / max(beta_down, 1e-9)
    )
    return max(0, min(int(rt / gamma), max_steps))


@dataclass
class CandidateDrafts:
    """Pre-drafted continuations keyed by their seed token."""

    seeds: np.ndarray                      # [k] candidate seed tokens
    sequences: Dict[int, np.ndarray]       # seed -> pre-drafted tokens
    probs: Dict[int, np.ndarray]           # seed -> per-token max probs

    def lookup(self, token: int) -> Optional[np.ndarray]:
        return self.sequences.get(int(token))


def predraft_candidates(
    draft_step: Callable,          # (token:int, steps:int) -> (tokens, probs)
    topk_tokens: np.ndarray,       # [k] top-k tokens of the last draft step
    steps: int,
) -> CandidateDrafts:
    """Generate candidate continuations for each top-k seed.

    ``draft_step`` is a device-local closure that drafts ``steps`` tokens
    from a given seed using a *copy-on-write fork* of the draft cache (the
    simulator charges its wall-time to the verification window).  With k
    seeds and λ steps each, the device performs k·λ draft-model steps —
    Eq. (6) guarantees they fit inside the round trip.
    """
    sequences: Dict[int, np.ndarray] = {}
    probs: Dict[int, np.ndarray] = {}
    if steps <= 0:
        return CandidateDrafts(topk_tokens, sequences, probs)
    for seed in np.asarray(topk_tokens).tolist():
        toks, ps = draft_step(int(seed), steps)
        sequences[int(seed)] = np.asarray(toks, np.int32)
        probs[int(seed)] = np.asarray(ps, np.float32)
    return CandidateDrafts(topk_tokens, sequences, probs)
