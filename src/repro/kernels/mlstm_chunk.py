"""Chunkwise-parallel mLSTM — Pallas TPU kernel (beyond-paper, §Perf H2).

The mLSTM matrix memory C ∈ [hd, hd] makes the naive per-token recurrence
HBM-bound: C is read+written every token.  This kernel walks the sequence
chunk-by-chunk with C/n/m resident in VMEM scratch for the ENTIRE sweep —
the state touches HBM exactly twice (initial load, final store) per
(batch, head), and the intra-chunk math is three MXU matmuls
([L,hd]x[hd,hd], [L,hd]x[hd,L], [L,L]x[L,hd]).

Grid = (B, nh, T/L), chunk axis innermost (sequential on a core).  With
L = 64, hd = 512 the VMEM working set is C (1 MB f32) + chunk blocks
(~0.5 MB) — far under the ~16 MB v5e budget.

Exactness: the chunkwise algebra equals the per-token recurrence (the
stabilizer-invariance argument in models/ssm.py); validated in interpret
mode against kernels.ref.mlstm_chunkwise_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG = -1e30


def _mlstm_kernel(
    q_ref, k_ref, v_ref,      # [1, 1, L, hd]
    ig_ref, fg_ref,           # [1, 1, L, 1]
    c0_ref, n0_ref, m0_ref,   # [1, 1, hd, hd] / [1, 1, hd, 1] / [1, 1, 1, 1]
    h_ref,                    # out: [1, 1, L, hd]
    cN_ref, nN_ref, mN_ref,   # out: final state
    C_acc, n_acc, m_acc,      # VMEM scratch
    *,
    n_chunks: int,
    L: int,
):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        C_acc[...] = c0_ref[0, 0].astype(F32)
        n_acc[...] = n0_ref[0, 0].astype(F32)
        m_acc[...] = m0_ref[0, 0].astype(F32)

    q = q_ref[0, 0].astype(F32)                   # [L, hd] (pre-scaled)
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    ig = ig_ref[0, 0, :, 0].astype(F32)           # [L]
    fg = fg_ref[0, 0, :, 0].astype(F32)

    m0 = m_acc[0, 0]
    lf = -jax.nn.softplus(-fg)
    b = jnp.cumsum(lf)                             # [L]
    D = b[:, None] - b[None, :] + ig[None, :]      # [L, L] (t, s)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    D = jnp.where(tri, D, NEG)
    m_intra = D.max(axis=1)
    m_hat = jnp.maximum(b + m0, m_intra)           # [L]
    inter = jnp.exp(b + m0 - m_hat)                # [L]
    S = jnp.exp(D - m_hat[:, None])                # [L, L]

    C = C_acc[...]                                 # [hd(v), hd(k)]
    n = n_acc[...]                                 # [hd, 1]
    sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=F32)        # [L, L]
    w = S * sc
    num = inter[:, None] * jax.lax.dot_general(
        q, C, (((1,), (1,)), ((), ())), preferred_element_type=F32
    ) + jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)          # [L, hd]
    nvec = inter[:, None] * n[:, 0][None, :] + jax.lax.dot_general(
        S, k, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )                                                            # [L, hd]
    dot = jnp.abs(jnp.sum(nvec * q, axis=1))
    h = num / jnp.maximum(dot, jnp.exp(-m_hat))[:, None]
    h_ref[0, 0, :, :] = h.astype(h_ref.dtype)

    # ---- state update (stays in VMEM) -------------------------------------
    BL = b[L - 1]
    m_new = jnp.maximum(BL + m0, (BL - b + ig).max())
    cdec = jnp.exp(BL + m0 - m_new)
    src = jnp.exp(BL - b + ig - m_new)                          # [L]
    C_acc[...] = cdec * C + jax.lax.dot_general(
        v * src[:, None], k, (((0,), (0,)), ((), ())),
        preferred_element_type=F32,
    )                                                            # [hd_v, hd_k]
    n_acc[...] = cdec * n + jax.lax.dot_general(
        k, src[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=F32,
    )
    m_acc[0, 0] = m_new

    @pl.when(t == n_chunks - 1)
    def _finish():
        cN_ref[0, 0, :, :] = C_acc[...].astype(cN_ref.dtype)
        nN_ref[0, 0, :, :] = n_acc[...].astype(nN_ref.dtype)
        mN_ref[0, 0, :, :] = m_acc[...].astype(mN_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunk_kernel(
    q, k, v,                   # [B, T, nh, hd]  (q pre-scaled by 1/sqrt(hd))
    ig, fg,                    # [B, T, nh]
    C0, n0, m0,                # [B, nh, hd, hd] / [B, nh, hd] / [B, nh]
    *,
    chunk: int = 64,
    interpret: bool = False,
):
    B, T, nh, hd = q.shape
    L = min(chunk, T)
    pad = (-T) % L
    qt = jnp.moveaxis(q, 1, 2)                    # [B, nh, T, hd]
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    igt = jnp.moveaxis(ig, 1, 2)[..., None]       # [B, nh, T, 1]
    fgt = jnp.moveaxis(fg, 1, 2)[..., None]
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        # padded steps: forget=1 (lf=0 ⇐ fg=+inf), input=-inf ⇒ state frozen
        igt = jnp.pad(igt, ((0, 0), (0, 0), (0, pad), (0, 0)),
                      constant_values=NEG)
        fgt = jnp.pad(fgt, ((0, 0), (0, 0), (0, pad), (0, 0)),
                      constant_values=1e9)
    Tp = T + pad
    n_chunks = Tp // L
    # m is carried as [B, nh, 1, 1]; n as [B, nh, hd, 1]
    m4 = m0[..., None, None]
    n4 = n0[..., None]

    grid = (B, nh, n_chunks)
    bspec = lambda shape: pl.BlockSpec(shape, lambda b, h, t: (b, h, t, 0))
    state_spec = lambda s2, s3: pl.BlockSpec(
        (1, 1, s2, s3), lambda b, h, t: (b, h, 0, 0)
    )
    h, cN, nN, mN = pl.pallas_call(
        functools.partial(_mlstm_kernel, n_chunks=n_chunks, L=L),
        grid=grid,
        in_specs=[
            bspec((1, 1, L, hd)), bspec((1, 1, L, hd)), bspec((1, 1, L, hd)),
            bspec((1, 1, L, 1)), bspec((1, 1, L, 1)),
            state_spec(hd, hd), state_spec(hd, 1), state_spec(1, 1),
        ],
        out_specs=[
            bspec((1, 1, L, hd)),
            state_spec(hd, hd), state_spec(hd, 1), state_spec(1, 1),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nh, Tp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, nh, hd, hd), F32),
            jax.ShapeDtypeStruct((B, nh, hd, 1), F32),
            jax.ShapeDtypeStruct((B, nh, 1, 1), F32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hd, hd), F32),
            pltpu.VMEM((hd, 1), F32),
            pltpu.VMEM((1, 1), F32),
        ],
        interpret=interpret,
    )(qt, kt, vt, igt, fgt, C0, n4, m4)
    h = jnp.moveaxis(h[:, :, :T, :], 2, 1)        # [B, T, nh, hd]
    return h, (cN, nN[..., 0], mN[..., 0, 0])
