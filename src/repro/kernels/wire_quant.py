"""Wire quantize/pack and dequantize/unpack — Pallas TPU kernels.

HAT's transport codec (repro.wire) quantizes hidden-state rows on their way
to the NIC: per-token absmax scales fused with the cast, and — for int4 —
nibble packing of value pairs into int8 lanes.  At fleet scale this runs on
every uploaded chunk and every downloaded deep state, so it must stream at
HBM bandwidth rather than bounce through host numpy.

Kernel shape: the work is purely elementwise along lanes with one per-row
reduction (absmax), so the grid tiles tokens only — grid = (T/bt,) with the
full d_model kept resident per tile.  A [bt, D] f32 tile plus its int8
output is ~5·bt·D bytes, comfortably inside VMEM for bt=256 and D=8192.

int4 packing splits the row at D/2 instead of interleaving adjacent pairs:
``packed[:, j] = (q[:, D/2 + j] << 4) | (q[:, j] & 0xF)``.  Both halves are
contiguous lane slices, so the pack is two shifted loads and an OR on the
VPU — no cross-lane shuffles.  The numpy codec (repro.wire.codec) and the
jnp oracle (ref.quantize_ref) implement the same layout; tests pin all
three byte-identical.

Validated on CPU with ``interpret=True`` against ref.quantize_ref /
ref.dequantize_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
DEFAULT_BT = 256


def _quantize_kernel(x_ref, p_ref, s_ref, *, bits: int):
    x = x_ref[...].astype(F32)                        # [bt, D]
    qmax = 127.0 if bits == 8 else 7.0
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 4:
        h = q.shape[1] // 2
        q = (q[:, h:] << 4) | (q[:, :h] & 0xF)        # lane-slice halves
    p_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(F32)


def _dequantize_kernel(p_ref, s_ref, o_ref, *, bits: int):
    p = p_ref[...].astype(jnp.int32)                  # [bt, Dp]
    if bits == 4:
        lo = ((p & 0xF) ^ 8) - 8                      # sign-extend low nibble
        hi = p >> 4                                   # arithmetic shift
        p = jnp.concatenate([lo, hi], axis=1)
    o_ref[...] = p.astype(F32) * s_ref[...]


@functools.partial(jax.jit, static_argnames=("bits", "bt", "interpret"))
def quantize_pack(
    x: jax.Array,              # [T, D] float hidden-state rows
    *,
    bits: int = 8,
    bt: int = DEFAULT_BT,
    interpret: bool = False,
):
    """Per-token absmax quantize (+ int4 nibble pack).

    Returns (packed int8 [T, D] or [T, D/2], scales f32 [T, 1])."""
    assert bits in (4, 8), bits
    T, D = x.shape
    if bits == 4 and D % 2:
        raise ValueError("int4 packing requires an even d_model")
    Dp = D if bits == 8 else D // 2

    bt = min(bt, max(8, T))
    t_pad = (-T) % bt
    if t_pad:
        x = jnp.pad(x, ((0, t_pad), (0, 0)))
    n_tiles = (T + t_pad) // bt

    packed, scales = pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((bt, D), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, Dp), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T + t_pad, Dp), jnp.int8),
            jax.ShapeDtypeStruct((T + t_pad, 1), F32),
        ],
        interpret=interpret,
    )(x)
    return packed[:T], scales[:T]


@functools.partial(jax.jit, static_argnames=("bits", "bt", "interpret"))
def dequantize_unpack(
    packed: jax.Array,         # int8 [T, D] (int8) or [T, D/2] (int4)
    scales: jax.Array,         # f32 [T, 1]
    *,
    bits: int = 8,
    bt: int = DEFAULT_BT,
    interpret: bool = False,
) -> jax.Array:
    """Invert quantize_pack -> f32 [T, D]."""
    assert bits in (4, 8), bits
    T, Dp = packed.shape
    D = Dp if bits == 8 else 2 * Dp

    bt = min(bt, max(8, T))
    t_pad = (-T) % bt
    if t_pad:
        packed = jnp.pad(packed, ((0, t_pad), (0, 0)))
        scales = jnp.pad(scales, ((0, t_pad), (0, 0)))
    n_tiles = (T + t_pad) // bt

    out = pl.pallas_call(
        functools.partial(_dequantize_kernel, bits=bits),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((bt, Dp), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T + t_pad, D), F32),
        interpret=interpret,
    )(packed, scales)
    return out[:T]
