"""Chunked-prefill flash attention — Pallas TPU kernel.

The HAT cloud's hot loop (§3.3): a prompt *chunk* of T queries attends to
the KV cache of everything processed so far (S slots; positions ≥ valid_len
hold garbage and are masked).  GQA and sliding windows supported.

TPU mapping (HARDWARE ADAPTATION — re-derived for the TPU memory hierarchy,
not a FlashAttention/CUDA port): grid = (B, nh, T/bq, S/bkv) with the
KV-tile axis innermost, so each step keeps one (bq × hd) query tile resident
in VMEM and streams KV tiles HBM→VMEM while carrying online-softmax
statistics in VMEM scratch.  Default 128×128 blocks put the q·kᵀ and p·v
contractions on MXU-aligned tiles; hd rides along unblocked (pad to a
multiple of 128 for peak MXU utilization on real hardware).  VMEM working
set per step ≈ (bq + 2·bkv)·hd + bq·bkv floats ≈ 0.2–0.5 MB at defaults —
far under the ~16 MB v5e VMEM, leaving room for double buffering.

Validated on CPU with ``interpret=True`` against ref.attention_ref.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BKV = 128


def _prefill_kernel(
    off_ref,                  # (1,) int32: absolute position of q[0]
    vlen_ref,                 # (1,) int32: number of valid cache slots
    q_ref,                    # [1, 1, bq, hd]
    k_ref,                    # [1, 1, bkv, hd]
    v_ref,                    # [1, 1, bkv, hd]
    o_ref,                    # [1, 1, bq, hd]
    acc_ref,                  # VMEM scratch [bq, hd] f32
    m_ref,                    # VMEM scratch [bq, 1] f32
    l_ref,                    # VMEM scratch [bq, 1] f32
    *,
    bq: int,
    bkv: int,
    n_kv_tiles: int,
    window: Optional[int],
    causal: bool,
):
    qt = pl.program_id(2)
    st = pl.program_id(3)

    @pl.when(st == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(F32)                       # [bq, hd]
    k = k_ref[0, 0].astype(F32)                       # [bkv, hd]
    v = v_ref[0, 0].astype(F32)
    hd = q.shape[-1]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32
    ) * (1.0 / math.sqrt(hd))                          # [bq, bkv]

    off = off_ref[0]
    q_pos = off + qt * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = st * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = k_pos < vlen_ref[0]
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                               # [bq]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)                    # rescale old stats
    p = jnp.exp(s - m_cur[:, None])
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[:, 0] = m_cur

    @pl.when(st == n_kv_tiles - 1)
    def _finish():
        # fully-masked rows (q tiles beyond valid data) have l == 0 -> emit 0
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "causal", "bq", "bkv", "interpret")
)
def prefill_attention(
    q: jax.Array,              # [B, T, nh, hd]
    k: jax.Array,              # [B, S, nkv, hd]
    v: jax.Array,
    offset,                    # scalar int32: absolute position of q[0]
    valid_len,                 # scalar int32: valid cache slots (rest masked)
    *,
    window: Optional[int] = None,
    causal: bool = True,
    bq: int = DEFAULT_BQ,
    bkv: int = DEFAULT_BKV,
    interpret: bool = False,
) -> jax.Array:
    B, T, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv

    bq = max(8, min(bq, T))
    bkv = min(bkv, S)
    t_pad = (-T) % bq
    s_pad = (-S) % bkv
    qt = jnp.moveaxis(q, 1, 2)                          # [B, nh, T, hd]
    kt = jnp.moveaxis(k, 1, 2)                          # [B, nkv, S, hd]
    vt = jnp.moveaxis(v, 1, 2)
    if t_pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, t_pad), (0, 0)))
    if s_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    Tp, Sp = T + t_pad, S + s_pad
    n_kv_tiles = Sp // bkv

    out = pl.pallas_call(
        functools.partial(
            _prefill_kernel,
            bq=bq, bkv=bkv, n_kv_tiles=n_kv_tiles,
            window=window, causal=causal,
        ),
        grid=(B, nh, Tp // bq, n_kv_tiles),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, i, j: (0,)),
            pl.BlockSpec((1,), lambda b, h, i, j: (0,)),
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, Tp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), F32),
            pltpu.VMEM((bq, 1), F32),
            pltpu.VMEM((bq, 1), F32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(offset, jnp.int32).reshape(1),
        jnp.asarray(valid_len, jnp.int32).reshape(1),
        qt, kt, vt,
    )
    return jnp.moveaxis(out[:, :, :T, :], 2, 1)
