"""Speculative-verification attention — Pallas TPU kernel (decode regime).

HAT's verification step (§3.4): k+1 draft-token queries (k ≤ ~16) attend to
a long KV cache (S up to 512k).  The compute is memory-bound: arithmetic
intensity ≈ 2·T flops/byte with T tiny, so the kernel is shaped around
streaming the cache, not around the MXU:

  grid = (B, nh, S/bkv); the whole (T × hd) query block stays pinned in
  VMEM for the entire sweep; KV tiles stream with large blocks (default
  bkv = 512) to maximize HBM burst efficiency; online-softmax stats live in
  VMEM scratch.  The last tile writes the normalized output.

The q tile is padded to 8 sublanes; with T=8, hd=128, bkv=512 the VMEM
working set is ≈ 0.6 MB.  This kernel is also the ``long_500k`` decode
path for the sub-quadratic archs' global layers.

Validated on CPU with ``interpret=True`` against ref.attention_ref
(causal masking over absolute positions, garbage slots masked).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30
DEFAULT_BKV = 512


def _verify_kernel(
    off_ref, vlen_ref,
    q_ref,                    # [1, 1, Tp, hd]
    k_ref,                    # [1, 1, bkv, hd]
    v_ref,                    # [1, 1, bkv, hd]
    o_ref,                    # [1, 1, Tp, hd]
    acc_ref, m_ref, l_ref,    # VMEM scratch
    *,
    bkv: int,
    n_kv_tiles: int,
    window: Optional[int],
):
    st = pl.program_id(2)

    @pl.when(st == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(F32)                        # [Tp, hd]
    k = k_ref[0, 0].astype(F32)
    v = v_ref[0, 0].astype(F32)
    Tp, hd = q.shape

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=F32
    ) * (1.0 / math.sqrt(hd))                           # [Tp, bkv]

    q_pos = off_ref[0] + jax.lax.broadcasted_iota(jnp.int32, (Tp, bkv), 0)
    k_pos = st * bkv + jax.lax.broadcasted_iota(jnp.int32, (Tp, bkv), 1)
    mask = (k_pos < vlen_ref[0]) & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32
    )
    m_ref[:, 0] = m_cur

    @pl.when(st == n_kv_tiles - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "bkv", "interpret"))
def verify_attention(
    q: jax.Array,              # [B, T, nh, hd]  (T = draft length + 1, small)
    k: jax.Array,              # [B, S, nkv, hd]
    v: jax.Array,
    offset,                    # scalar: absolute position of q[0]
    valid_len,                 # scalar: valid cache slots
    *,
    window: Optional[int] = None,
    bkv: int = DEFAULT_BKV,
    interpret: bool = False,
) -> jax.Array:
    B, T, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv

    Tp = max(8, T + ((-T) % 8))               # pad queries to 8 sublanes
    bkv = min(bkv, S)
    s_pad = (-S) % bkv
    qt = jnp.moveaxis(q, 1, 2)
    if Tp != T:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if s_pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, s_pad), (0, 0)))
    n_kv_tiles = (S + s_pad) // bkv

    out = pl.pallas_call(
        functools.partial(
            _verify_kernel, bkv=bkv, n_kv_tiles=n_kv_tiles, window=window
        ),
        grid=(B, nh, n_kv_tiles),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (0,)),
            pl.BlockSpec((1,), lambda b, h, j: (0,)),
            pl.BlockSpec((1, 1, Tp, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, j: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Tp, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nh, Tp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Tp, hd), F32),
            pltpu.VMEM((Tp, 1), F32),
            pltpu.VMEM((Tp, 1), F32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(offset, jnp.int32).reshape(1),
        jnp.asarray(valid_len, jnp.int32).reshape(1),
        qt, kt, vt,
    )
    return jnp.moveaxis(out[:, :, :T, :], 2, 1)
