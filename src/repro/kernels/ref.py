"""Pure-jnp oracles for every kernel in this package.

These are the semantics contracts: tests sweep shapes/dtypes and assert
``assert_allclose(kernel(interpret=True), ref)``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def attention_ref(
    q: jax.Array,              # [B, T, nh, hd]
    k: jax.Array,              # [B, S, nkv, hd]
    v: jax.Array,              # [B, S, nkv, hd]
    *,
    offset: int = 0,           # absolute position of q[0]
    valid_len: Optional[int] = None,   # cache entries < valid_len are real
    window: Optional[int] = None,
    causal: bool = True,
) -> jax.Array:
    """Oracle for chunked-prefill and speculative-verification attention.

    q positions are offset..offset+T-1; k positions are 0..S-1.  Entries at
    k positions >= valid_len (defaults to offset+T) are masked garbage.
    """
    B, T, nh, hd = q.shape
    S, nkv = k.shape[1], k.shape[2]
    g = nh // nkv
    valid_len = offset + T if valid_len is None else valid_len

    qg = q.reshape(B, T, nkv, g, hd).astype(F32)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(F32))
    scores /= math.sqrt(hd)

    qp = offset + jnp.arange(T)
    kp = jnp.arange(S)
    mask = kp[None, :] < valid_len
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window is not None:
        mask = mask & (kp[None, :] > qp[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(F32))
    return out.reshape(B, T, nh, hd).astype(q.dtype)


def quantize_ref(x: jax.Array, *, bits: int = 8):
    """Oracle for the wire quantize/pack kernel (wire_quant.py).

    x: [T, D] float.  Per-token (row) symmetric absmax quantization; int4
    packs value pairs split at D/2 into int8 lanes (packed[:, j] holds
    q[:, D/2+j] in the high nibble and q[:, j] in the low nibble).
    Returns (packed int8 [T, D or D/2], scales f32 [T, 1]) — byte-identical
    to repro.wire.codec's numpy encoder.
    """
    assert bits in (4, 8)
    qmax = 127.0 if bits == 8 else 7.0
    x = x.astype(F32)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax == 0.0, 1.0, absmax / qmax).astype(F32)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)
    if bits == 4:
        h = q.shape[-1] // 2
        q = (q[..., h:] << 4) | (q[..., :h] & 0xF)
    return q.astype(jnp.int8), scale


def dequantize_ref(packed: jax.Array, scales: jax.Array, *, bits: int = 8):
    """Oracle for the wire dequantize/unpack kernel.

    packed: int8 [T, D] (int8 codec) or [T, D/2] (int4); scales: f32 [T, 1].
    Returns f32 [T, D].
    """
    assert bits in (4, 8)
    p = packed.astype(jnp.int32)
    if bits == 4:
        lo = ((p & 0xF) ^ 8) - 8
        hi = p >> 4
        p = jnp.concatenate([lo, hi], axis=-1)
    return p.astype(F32) * scales


def mlstm_chunkwise_ref(q, k, v, ig, fg, *, initial=None):
    """Oracle for the chunkwise-parallel mLSTM kernel: plain recurrence.

    q,k,v: [B, T, nh, hd] (q pre-scaled by 1/sqrt(hd)); ig/fg: [B, T, nh]
    raw gate pre-activations.  Returns ([B, T, nh, hd], final_state)."""
    B, T, nh, hd = q.shape
    if initial is None:
        C = jnp.zeros((B, nh, hd, hd), F32)
        n = jnp.zeros((B, nh, hd), F32)
        m = jnp.full((B, nh), -jnp.inf, F32)
    else:
        C, n, m = initial

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, ft = inp
        log_f = -jax.nn.softplus(-ft)
        m_new = jnp.maximum(log_f + m, it)
        i_p = jnp.exp(it - m_new)[..., None]
        f_p = jnp.exp(log_f + m - m_new)[..., None]
        C = f_p[..., None] * C + i_p[..., None] * (vt[..., None] * kt[..., None, :])
        n = f_p * n + i_p * kt
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new))
        h = jnp.einsum("bhvd,bhd->bhv", C, qt) / denom[..., None]
        return (C, n, m_new), h

    xs = tuple(
        jnp.moveaxis(a.astype(F32), 1, 0) for a in (q, k, v, ig, fg)
    )
    (C, n, m), ys = jax.lax.scan(step, (C, n, m), xs)
    return jnp.moveaxis(ys, 0, 1), (C, n, m)
