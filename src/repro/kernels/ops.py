"""Jit'd dispatch wrappers for the kernels.

``attention_op`` routes to the Pallas kernels on TPU (or in interpret mode
for CPU validation) and to the pure-jnp oracle otherwise.  The model's
reference attention (models.layers.attend) remains the default inside the
lowered dry-run graphs; these ops are the TPU-hot-path implementations the
launcher selects with ``--attn-impl pallas``.
"""
from __future__ import annotations

from typing import Optional

import jax

from .prefill_attention import prefill_attention
from .ref import attention_ref, dequantize_ref, quantize_ref
from .verify_attention import verify_attention
from .wire_quant import dequantize_unpack, quantize_pack

VERIFY_MAX_T = 32     # below this query length, the decode-shaped kernel wins


def backend_kind() -> str:
    return jax.default_backend()


def attention_op(
    q, k, v, offset, valid_len,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    impl: str = "auto",          # auto | pallas | interpret | reference
):
    """[B,T,nh,hd] x [B,S,nkv,hd] chunked-cache attention."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return attention_ref(
            q, k, v, offset=offset, valid_len=valid_len,
            window=window, causal=causal,
        )
    interpret = impl == "interpret" or backend_kind() != "tpu"
    T = q.shape[1]
    if causal and T <= VERIFY_MAX_T:
        return verify_attention(
            q, k, v, offset, valid_len, window=window, interpret=interpret
        )
    return prefill_attention(
        q, k, v, offset, valid_len,
        window=window, causal=causal, interpret=interpret,
    )


def quantize_op(x, *, bits: int = 8, impl: str = "auto"):
    """[T, D] hidden rows -> (packed int8, per-token f32 scales).

    Same dispatch contract as attention_op: Pallas on TPU (or interpret
    mode for CPU validation), jnp oracle otherwise."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return quantize_ref(x, bits=bits)
    interpret = impl == "interpret" or backend_kind() != "tpu"
    return quantize_pack(x, bits=bits, interpret=interpret)


def dequantize_op(packed, scales, *, bits: int = 8, impl: str = "auto"):
    """Invert quantize_op -> f32 [T, D]."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return dequantize_ref(packed, scales, bits=bits)
    interpret = impl == "interpret" or backend_kind() != "tpu"
    return dequantize_unpack(packed, scales, bits=bits, interpret=interpret)
