"""Jit'd dispatch wrappers for the kernels.

``attention_op`` routes to the Pallas kernels on TPU (or in interpret mode
for CPU validation) and to the pure-jnp oracle otherwise.  The model's
reference attention (models.layers.attend) remains the default inside the
lowered dry-run graphs; these ops are the TPU-hot-path implementations the
launcher selects with ``--attn-impl pallas``.
"""
from __future__ import annotations

from typing import Optional

import jax

from .prefill_attention import prefill_attention
from .ref import attention_ref
from .verify_attention import verify_attention

VERIFY_MAX_T = 32     # below this query length, the decode-shaped kernel wins


def backend_kind() -> str:
    return jax.default_backend()


def attention_op(
    q, k, v, offset, valid_len,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    impl: str = "auto",          # auto | pallas | interpret | reference
):
    """[B,T,nh,hd] x [B,S,nkv,hd] chunked-cache attention."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return attention_ref(
            q, k, v, offset=offset, valid_len=valid_len,
            window=window, causal=causal,
        )
    interpret = impl == "interpret" or backend_kind() != "tpu"
    T = q.shape[1]
    if causal and T <= VERIFY_MAX_T:
        return verify_attention(
            q, k, v, offset, valid_len, window=window, interpret=interpret
        )
    return prefill_attention(
        q, k, v, offset, valid_len,
        window=window, causal=causal, interpret=interpret,
    )
