"""Jit'd dispatch wrappers for the kernels.

``attention_op`` routes to the Pallas kernels on TPU (or in interpret mode
for CPU validation) and to the pure-jnp oracle otherwise.  The model's
reference attention (models.layers.attend) remains the default inside the
lowered dry-run graphs; these ops are the TPU-hot-path implementations the
launcher selects with ``--attn-impl pallas``.
"""
from __future__ import annotations

from typing import Optional

import jax

from .prefill_attention import prefill_attention
from .ref import attention_ref, dequantize_ref, quantize_ref
from .verify_attention import verify_attention
from .wire_quant import dequantize_unpack, quantize_pack

# Below this query length the decode-shaped kernel wins: a verify strip's
# arithmetic intensity (~2·T flops/byte) is memory-bound, so the kernel that
# pins the whole query block in VMEM and streams KV in large tiles beats the
# MXU-tiled prefill kernel.  32 is where the [bq, bkv] prefill tiling stops
# paying for itself (one 8-sublane-padded query tile).  HAT verify strips
# (draft ≤ 8 ⇒ T ≤ 9) and medusa path commits are always below it.
VERIFY_MAX_T = 32


def backend_kind() -> str:
    return jax.default_backend()


def attention_impl_for(t: int, causal: bool = True) -> str:
    """Which Pallas kernel ``attention_op`` routes a T-row query block to:
    ``"verify"`` (decode-shaped, KV-streaming) for short causal strips,
    ``"prefill"`` (MXU-tiled) otherwise.  Exposed so dispatch is testable
    without monkeypatching the kernels."""
    return "verify" if causal and t <= VERIFY_MAX_T else "prefill"


def attention_op(
    q, k, v, offset, valid_len,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    impl: str = "auto",          # auto | pallas | interpret | reference
):
    """[B,T,nh,hd] x [B,S,nkv,hd] chunked-cache attention."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return attention_ref(
            q, k, v, offset=offset, valid_len=valid_len,
            window=window, causal=causal,
        )
    interpret = impl == "interpret" or backend_kind() != "tpu"
    if attention_impl_for(q.shape[1], causal) == "verify":
        return verify_attention(
            q, k, v, offset, valid_len, window=window, interpret=interpret
        )
    return prefill_attention(
        q, k, v, offset, valid_len,
        window=window, causal=causal, interpret=interpret,
    )


def quantize_op(x, *, bits: int = 8, impl: str = "auto"):
    """[T, D] hidden rows -> (packed int8, per-token f32 scales).

    Same dispatch contract as attention_op: Pallas on TPU (or interpret
    mode for CPU validation), jnp oracle otherwise."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return quantize_ref(x, bits=bits)
    interpret = impl == "interpret" or backend_kind() != "tpu"
    return quantize_pack(x, bits=bits, interpret=interpret)


def dequantize_op(packed, scales, *, bits: int = 8, impl: str = "auto"):
    """Invert quantize_op -> f32 [T, D]."""
    if impl == "reference" or (impl == "auto" and backend_kind() != "tpu"):
        return dequantize_ref(packed, scales, bits=bits)
    interpret = impl == "interpret" or backend_kind() != "tpu"
    return dequantize_unpack(packed, scales, bits=bits, interpret=interpret)
