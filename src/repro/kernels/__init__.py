from .ops import (
    VERIFY_MAX_T,
    attention_impl_for,
    attention_op,
    backend_kind,
    dequantize_op,
    quantize_op,
)
from .prefill_attention import prefill_attention
from .ref import attention_ref, dequantize_ref, mlstm_chunkwise_ref, quantize_ref
from .verify_attention import verify_attention
from .wire_quant import dequantize_unpack, quantize_pack

__all__ = [
    "VERIFY_MAX_T", "attention_impl_for",
    "attention_op", "backend_kind", "dequantize_op", "quantize_op",
    "prefill_attention", "attention_ref", "dequantize_ref",
    "mlstm_chunkwise_ref", "quantize_ref", "verify_attention",
    "dequantize_unpack", "quantize_pack",
]
from .mlstm_chunk import mlstm_chunk_kernel

__all__.append("mlstm_chunk_kernel")
