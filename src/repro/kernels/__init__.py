from .ops import attention_op, backend_kind
from .prefill_attention import prefill_attention
from .ref import attention_ref, mlstm_chunkwise_ref
from .verify_attention import verify_attention

__all__ = [
    "attention_op", "backend_kind", "prefill_attention", "attention_ref",
    "mlstm_chunkwise_ref", "verify_attention",
]
from .mlstm_chunk import mlstm_chunk_kernel

__all__.append("mlstm_chunk_kernel")
