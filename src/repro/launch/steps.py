"""Step builders for the dry-run and the launchers.

For every (arch, input shape) this module builds:
  * the step function (train / prefill / decode / hat_verify),
  * abstract inputs (``input_specs`` — ShapeDtypeStructs, no allocation),
  * in/out shardings on the given mesh.

``train_step`` is a full LM step: loss (+ MoE aux), grads, optimizer update
(AdamW below 10B params, Adafactor at/above — DESIGN.md §5), remat scan.
``prefill_step``/``decode_step`` run the full model with a KV cache.
``hat_verify_step`` is the paper's cloud step: the middle submodel advances
k+1 draft hidden states against the cache (hidden states in/out — exactly
what crosses the device-cloud wire).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..core.split import split_model
from ..distributed.sharding import (
    ShardingRules,
    make_rules,
    param_shardings,
    spec_for_name,
    use_rules,
)
from ..models.model import Model
from ..training.optim import Adafactor, AdamW
from ..training.trainer import lm_loss

PyTree = Any
ADAFACTOR_THRESHOLD = 10e9          # params
HAT_VERIFY_T = 8                    # draft length + 1 in the verify step


@dataclass
class BuiltStep:
    name: str
    fn: Callable                     # jit-able python callable
    abstract_args: Tuple             # ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any               # None -> let XLA choose
    donate_argnums: Tuple[int, ...]
    rules: ShardingRules
    meta: Dict


def _named(rules: ShardingRules, spec: P) -> NamedSharding:
    return NamedSharding(rules.mesh, spec)


def _tokens_sharding(rules):
    return _named(rules, rules.spec("tokens"))


def _cache_shardings(model: Model, rules: ShardingRules, abstract_cache):
    spec_tree = model.cache_spec()
    # cache_spec mirrors init_cache(None, ...); align structures
    return jax.tree.map(
        lambda name: _named(rules, spec_for_name(rules, name)),
        spec_tree,
        is_leaf=lambda x: isinstance(x, str),
    )


def _opt_shardings(rules: ShardingRules, param_spec, abstract_opt_state):
    """Derive optimizer-state shardings from the param spec tree.

    AdamW moments mirror params.  Adafactor's factored vr/vc drop the last /
    second-to-last axis of the param spec.  Scalars replicate."""

    def param_spec_at(path):
        node = param_spec
        for p in path:
            key = p.key if hasattr(p, "key") else p.idx
            node = node[key]
        return node

    def shard_for(path, leaf):
        keys = [p.key if hasattr(p, "key") else p.idx for p in path]
        if keys[-1] == "step":
            return _named(rules, P())
        if keys[0] in ("mu", "nu"):
            name = param_spec_at(path[1:])
            return _named(rules, spec_for_name(rules, name))
        if keys[0] == "f":                      # adafactor
            leaf_kind = keys[-1]
            name = param_spec_at(path[1:-1])
            base = spec_for_name(rules, name)
            if leaf_kind == "v":
                return _named(rules, base)
            if leaf_kind == "vr":               # drop last axis
                return _named(rules, P(*base[:-1]))
            if leaf_kind == "vc":               # drop second-to-last axis
                return _named(rules, P(*(tuple(base[:-2]) + (base[-1],))))
        if keys[0] == "m":                      # sgd momentum
            name = param_spec_at(path[1:])
            return _named(rules, spec_for_name(rules, name))
        return _named(rules, P())

    return jax.tree_util.tree_map_with_path(shard_for, abstract_opt_state)


def make_optimizer(cfg: ModelConfig):
    if cfg.param_count() >= ADAFACTOR_THRESHOLD:
        return Adafactor(lr=1e-3)
    return AdamW(lr=1e-3, weight_decay=0.0)


def input_specs(cfg: ModelConfig, shape: InputShape, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    if cfg.frontend == "vision":
        specs["memory"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), dtype
        )
    if cfg.frontend == "audio":
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), dtype
        )
    return specs


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------


def build_step(
    cfg: ModelConfig,
    shape: InputShape,
    mesh,
    *,
    kind: Optional[str] = None,       # default: from shape.kind
    dtype=jnp.bfloat16,
    fsdp: Optional[bool] = None,
    seq_shard_cache: bool = True,
    seq_parallel_acts: bool = False,
    remat: bool = True,
    microbatch: Optional[int] = None, # grad-accumulation factor (train)
    rules: Optional[ShardingRules] = None,
) -> BuiltStep:
    kind = kind or shape.kind
    if fsdp is None:
        # FSDP(ZeRO-3) param sharding pays off when grads exist; for
        # inference it forces a full weight all-gather EVERY step (§Perf H3:
        # 8.9 GB/chip/step on qwen2-72b decode -> 161x collective reduction
        # from disabling it).  Exception: models whose tp-sharded weights
        # exceed the HBM budget (kimi-1T, dbrx) must keep dp-sharded params
        # even when serving (§Perf H1 iter 2).
        tp = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") else 16
        tp_resident_gb = cfg.param_count() * 2 / tp / 2**30
        fsdp = kind == "train" or tp_resident_gb > 12.0
    dp_total = 1
    for a, n in zip(mesh.axis_names, mesh.devices.shape):
        if a in ("pod", "data"):
            dp_total *= n
    batch_ok = shape.global_batch % dp_total == 0
    rules = rules or make_rules(
        mesh, fsdp_params=fsdp, seq_shard_cache=seq_shard_cache,
        batch_shardable=batch_ok, seq_parallel_acts=seq_parallel_acts,
    )
    model = Model(cfg, dtype=dtype, remat=remat and kind == "train")
    aparams = model.abstract_params()
    pspec = model.param_spec()
    pshard = param_shardings(rules, pspec)
    ins = input_specs(cfg, shape, dtype)
    meta = {"arch": cfg.name, "shape": shape.name, "kind": kind}

    if kind == "train":
        optimizer = make_optimizer(cfg)
        aopt = jax.eval_shape(optimizer.init, aparams)
        oshard = _opt_shardings(rules, pspec, aopt)
        batch_shardings = {
            k: _named(rules, rules.spec("memory_bmd") if v.ndim == 3 else rules.spec("tokens"))
            for k, v in ins.items()
        }

        def train_step(params, opt_state, batch):
            with use_rules(rules):
                def loss_fn(p, toks, memory):
                    return lm_loss(model, p, toks, memory=memory)

                if microbatch and microbatch > 1:
                    # gradient accumulation: K sequential microbatches cut
                    # peak activation memory ~K x at the same math
                    K = microbatch
                    B = batch["tokens"].shape[0]
                    assert B % K == 0, (B, K)
                    toks = batch["tokens"].reshape(K, B // K, *batch["tokens"].shape[1:])
                    mem = batch.get("memory")
                    mem_mb = (
                        mem.reshape(K, B // K, *mem.shape[1:]) if mem is not None else None
                    )

                    def micro(acc, xs):
                        t = xs[0]
                        m_ = xs[1] if mem_mb is not None else None
                        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, t, m_)
                        acc_g, acc_l = acc
                        return (
                            jax.tree.map(lambda a, b: a + b, acc_g, g),
                            acc_l + l,
                        ), None

                    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    xs = (toks, mem_mb) if mem_mb is not None else (toks,)
                    (gsum, lsum), _ = jax.lax.scan(micro, (zero, jnp.zeros((), jnp.float32)), xs)
                    grads = jax.tree.map(lambda g: (g / K).astype(jnp.float32), gsum)
                    loss = lsum / K
                else:
                    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, batch["tokens"], batch.get("memory")
                    )
                updates, opt_state2 = optimizer.update(grads, opt_state, params)
                params2 = jax.tree.map(lambda p, u: p + u, params, updates)
                return params2, opt_state2, loss.astype(jnp.float32)

        # audio enc-dec trains through the encoder: frames feed the encoder
        if cfg.frontend == "audio":
            def train_step(params, opt_state, batch):     # noqa: F811
                with use_rules(rules):
                    def loss_fn(p):
                        memory = model.encode(p, batch["frames"])
                        return lm_loss(model, p, batch["tokens"], memory=memory)

                    (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
                    updates, opt_state2 = optimizer.update(grads, opt_state, params)
                    params2 = jax.tree.map(lambda p, u: p + u, params, updates)
                    return params2, opt_state2, metrics["loss"].astype(jnp.float32)

        return BuiltStep(
            name=f"{cfg.name}:{shape.name}:train",
            fn=train_step,
            abstract_args=(aparams, aopt, ins),
            in_shardings=(pshard, oshard, batch_shardings),
            out_shardings=None,
            donate_argnums=(0, 1),
            rules=rules,
            meta={**meta, "optimizer": type(optimizer).__name__},
        )

    # ---- inference kinds ----------------------------------------------------
    B = shape.global_batch
    if kind == "hat_verify":
        split = split_model(cfg, model.abstract_params(), dtype=dtype)
        mid = split.middle_model
        acache = jax.eval_shape(
            lambda: mid.init_cache(None, B, shape.seq_len, dtype=dtype)
        )
        cshard = _cache_shardings(mid, rules, acache)
        hidden = jax.ShapeDtypeStruct((B, HAT_VERIFY_T, cfg.d_model), dtype)
        offsets = jax.ShapeDtypeStruct((B,), jnp.int32)
        mid_pshard = param_shardings(rules, mid.param_spec())

        def verify_step(mparams, cache, hidden, offsets):
            with use_rules(rules):
                deep, new_cache, _ = mid.apply(
                    mparams, None, inputs_embeds=hidden, cache=cache,
                    offset=offsets,
                )
                return deep, new_cache

        return BuiltStep(
            name=f"{cfg.name}:{shape.name}:hat_verify",
            fn=verify_step,
            abstract_args=(split.middle_model.abstract_params(), acache, hidden, offsets),
            in_shardings=(
                mid_pshard, cshard,
                _named(rules, rules.spec("act_btd")), _named(rules, rules.spec("batch_vec")),
            ),
            out_shardings=None,
            donate_argnums=(1,),
            rules=rules,
            meta={**meta, "verify_T": HAT_VERIFY_T},
        )

    # prefill / decode on the full model
    cache_len = shape.seq_len
    acache = jax.eval_shape(
        lambda: model.init_cache(None, B, cache_len, dtype=dtype)
    )
    cshard = _cache_shardings(model, rules, acache)
    extra = {k: v for k, v in ins.items() if k != "tokens"}
    extra_shardings = {
        k: _named(rules, rules.spec("memory_bmd")) for k in extra
    }
    offset_spec = jax.ShapeDtypeStruct((), jnp.int32)

    def infer_step(params, cache, tokens, offset, extra):
        with use_rules(rules):
            memory = extra.get("memory")
            if cfg.frontend == "audio" and "frames" in extra:
                memory = model.encode(params, extra["frames"])
            logits, new_cache, _ = model.apply(
                params, tokens, cache=cache, offset=offset, memory=memory,
            )
            return logits[:, -1, :], new_cache

    return BuiltStep(
        name=f"{cfg.name}:{shape.name}:{kind}",
        fn=infer_step,
        abstract_args=(aparams, acache, ins["tokens"], offset_spec, extra),
        in_shardings=(
            pshard, cshard, _tokens_sharding(rules),
            _named(rules, P()), extra_shardings,
        ),
        out_shardings=None,
        donate_argnums=(1,),
        rules=rules,
        meta=meta,
    )


def lower_step(built: BuiltStep, mesh):
    jitted = jax.jit(
        built.fn,
        in_shardings=built.in_shardings,
        out_shardings=built.out_shardings,
        donate_argnums=built.donate_argnums,
    )
    with mesh:
        return jitted.lower(*built.abstract_args)
