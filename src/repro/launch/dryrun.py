import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  This module is the ONLY place that forces 512
# placeholder devices — tests and benches see the real single CPU device.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) this lowers AND compiles the
appropriate step with ShapeDtypeStruct inputs (zero allocation), captures
``memory_analysis()`` / ``cost_analysis()`` / the optimized HLO's collective
bytes, and writes one JSON record per combination under ``reports/dryrun``.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Failures (sharding mismatch, unsupported collective) are bugs; the record
stores the exception instead of crashing the sweep.
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp


def _mem_fields(mem) -> dict:
    if mem is None:
        return {}
    fields = [
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes", "host_argument_size_in_bytes",
        "host_output_size_in_bytes", "host_temp_size_in_bytes",
    ]
    out = {}
    for f in fields:
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            kind: Optional[str] = None, out_dir: str = "reports/dryrun",
            overrides: Optional[dict] = None, save_hlo: bool = False,
            tag: str = "") -> dict:
    from ..configs import SHAPES, get_config, shape_applicable
    from ..roofline.analysis import analyze
    from .mesh import make_production_mesh
    from .steps import build_step, lower_step

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": kind or shape.kind, "tag": tag, "ok": False,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(skipped=True, reason=reason)
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = mesh.devices.size
        t0 = time.time()
        built = build_step(cfg, shape, mesh, kind=kind, **(overrides or {}))
        lowered = lower_step(built, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
        terms = analyze(
            cfg=cfg, shape=shape, mesh_name=mesh_name, n_chips=n_chips,
            cost=cost, hlo_text=hlo, kind=kind,
        )
        rec.update(
            ok=True,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_fields(mem),
            cost={k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float))},
            roofline=terms.to_dict(),
            meta=built.meta,
            hlo_bytes=len(hlo),
        )
        if save_hlo:
            os.makedirs(out_dir, exist_ok=True)
            with open(os.path.join(
                out_dir, f"{arch}.{shape_name}.{mesh_name}{tag}.hlo.txt"
            ), "w") as f:
                f.write(hlo)
    except Exception as e:  # noqa: BLE001 — sweep must survive
        rec.update(error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    finally:
        # each combination builds 256/512-way sharded constants in caches;
        # drop them so the sweep's host memory stays bounded
        jax.clear_caches()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        name = f"{arch}.{shape_name}.{mesh_name}{tag}.json"
        with open(os.path.join(out_dir, name), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    from ..configs import ASSIGNED, SHAPES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--kind", default=None,
                    help="override step kind (e.g. hat_verify)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                t0 = time.time()
                rec = run_one(arch, shape, mp, kind=args.kind,
                              out_dir=args.out, save_hlo=args.save_hlo,
                              tag=args.tag)
                status = ("SKIP " + rec.get("reason", "")) if rec.get("skipped") \
                    else ("ok" if rec["ok"] else "FAIL " + rec.get("error", ""))
                mesh_name = rec["mesh"]
                print(f"[{time.time()-t0:7.1f}s] {arch:24s} {shape:12s} "
                      f"{mesh_name:8s} {status}", flush=True)
                results.append(rec)
    n_ok = sum(r["ok"] for r in results)
    n_skip = sum(bool(r.get("skipped")) for r in results)
    print(f"\n{n_ok} ok / {n_skip} skipped / "
          f"{len(results) - n_ok - n_skip} failed / {len(results)} total")


if __name__ == "__main__":
    main()
