"""Serving launcher: the HAT device-cloud system end to end.

  PYTHONPATH=src python -m repro.launch.serve --framework hat --rate 6 --requests 200
  PYTHONPATH=src python -m repro.launch.serve --framework u-shape --workload cnn_dm
  PYTHONPATH=src python -m repro.launch.serve --runtime engine --requests 8
  PYTHONPATH=src python -m repro.launch.serve --net tcp --devices 2 --requests 4

Runs the 30-device fleet simulator (all algorithmic components real; delay
models calibrated to the paper's testbed — DESIGN.md §3) through the typed
session configuration (``ServeConfig`` + ``SimulatorRuntime``).  ``--real``
swaps the statistical backend for actual JAX models (reduced config):
slower but every token is really drafted/verified through DeviceClient /
CloudServer sessions.

``--runtime engine`` serves through the real-tensor :class:`EngineRuntime`
instead: every session is a DeviceClient coroutine scheduled against the
shared virtual clock, and the cloud batches prefill chunks + verify strips
*across* sessions in slot-batched middle-submodel steps (continuous
batching).  ``--sequential-engine`` keeps the legacy one-session-at-a-time
parity mode.

``--net tcp`` leaves simulation behind entirely: the launcher spawns one
``repro.net.service`` cloud process plus ``--devices`` real device worker
processes talking ``repro.wire`` frames over localhost TCP, then reports
**measured** wall-clock TTFT/TBT and the merged cross-process Chrome trace.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--framework", default="hat",
                    choices=["hat", "u-shape", "u-sarathi", "u-medusa"])
    ap.add_argument("--workload", default="specbench", choices=["specbench", "cnn_dm"])
    ap.add_argument("--rate", type=float, default=6.0)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--pipeline-len", type=int, default=4)
    ap.add_argument("--devices", type=int, default=None,
                    help="fleet size (default 30 simulated; 2 with --net)")
    ap.add_argument("--real", action="store_true",
                    help="real JAX models (reduced config) instead of the "
                         "statistical backend")
    ap.add_argument("--runtime", default="sim", choices=["sim", "engine"],
                    help="sim: discrete-event fleet simulator; engine: "
                         "real-tensor EngineRuntime (DeviceClient sessions "
                         "through the slot-batched CloudEngine)")
    ap.add_argument("--sequential-engine", action="store_true",
                    help="with --runtime engine: disable the concurrent "
                         "scheduler (legacy one-session-at-a-time mode)")
    ap.add_argument("--slots", type=int, default=8,
                    help="engine slot pool (concurrent sessions in flight)")
    ap.add_argument("--max-len", type=int, default=512,
                    help="engine slot capacity (tokens per session)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--wire-codec", default=None,
                    help="hidden-state transport codec (default: fp16 byte "
                         "accounting, backend codec untouched)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--net", default=None, choices=["tcp"],
                    help="serve over real sockets: spawn 1 cloud + N device "
                         "processes on localhost and measure wall-clock "
                         "TTFT/TBT (no delay models)")
    ap.add_argument("--net-workdir", default=None,
                    help="with --net: directory for per-process logs, "
                         "result JSONs and the merged Chrome trace")
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="with --net: tokens per synthetic prompt")
    ap.add_argument("--new-tokens", type=int, default=4,
                    help="with --net: tokens generated per request")
    args = ap.parse_args()

    if args.net == "tcp":
        from ..net import run_cluster

        devices = args.devices if args.devices is not None else 2
        result = run_cluster(
            args.arch,
            n_devices=devices,
            requests_per_device=max(1, -(-args.requests // devices)),
            prompt_len=args.prompt_len,
            new_tokens=args.new_tokens,
            slots=args.slots,
            max_len=args.max_len,
            wire_codec=args.wire_codec or "fp16",
            draft=args.framework == "hat",
            seed=args.seed,
            workdir=args.net_workdir,
        )
        result.pop("workers")        # per-request detail lives in the JSONs
        print(json.dumps(result, indent=1))
        return

    from ..data import CNN_DM, SPECBENCH, sample_workload
    from ..serving import EngineRuntime, ServeConfig, SimulatorRuntime

    args.devices = args.devices if args.devices is not None else 30
    spec = SPECBENCH if args.workload == "specbench" else CNN_DM
    d_model = 4096 if args.workload == "specbench" else 5120
    rng = np.random.default_rng(args.seed)

    backend = None
    split = adapter = medusa = None
    if args.real or args.runtime == "engine":
        import jax

        from ..configs import get_config
        from ..core import init_adapter, split_model
        from ..models import Model
        from ..serving import RealBackend, init_medusa

        cfg = get_config(args.arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        split = split_model(cfg, params)
        adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
        medusa, _ = init_medusa(cfg, jax.random.PRNGKey(8))
        if args.runtime == "sim":
            backend = RealBackend(split, adapter_params=adapter,
                                  medusa_params=medusa, max_len=512,
                                  wire_codec=args.wire_codec)
        d_model = cfg.d_model

    config = ServeConfig.from_framework(
        args.framework,
        wire_codec=args.wire_codec,
        d_model=d_model,
        pipeline_len=args.pipeline_len,
        n_devices=args.devices,
    )
    reqs = sample_workload(
        spec, rng, n_requests=args.requests, rate_per_s=args.rate,
        n_devices=args.devices,
        with_tokens=args.real or args.runtime == "engine",
    )
    if args.runtime == "engine":
        runtime = EngineRuntime(
            config, split,
            adapter_params=adapter if config.sd == "draft" else None,
            medusa_params=medusa if config.sd == "medusa" else None,
            rng=np.random.default_rng(args.seed + 1),
            n_slots=args.slots, max_len=args.max_len,
            concurrent=not args.sequential_engine,
        )
    else:
        runtime = SimulatorRuntime(config, backend=backend,
                                   rng=np.random.default_rng(args.seed + 1))
    print(json.dumps(runtime.serve(reqs).summary(), indent=1))


if __name__ == "__main__":
    main()
