"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then builds meshes.

Target: TPU v5e.  Single pod: 16×16 = 256 chips, axes (data, model).
Multi-pod: 2×16×16 = 512 chips, axes (pod, data, model) — the "pod" axis
carries data parallelism across the DCN/ICI boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 1, model_axis: int = 1):
    """Tiny mesh over however many devices exist (tests)."""
    n = min(n_devices, len(jax.devices()))
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


# v5e hardware constants (roofline; see repro.roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
