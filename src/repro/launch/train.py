"""Training launcher.

Two modes:
  * ``--smoke``: really train a reduced config on the local device(s) with
    synthetic data (what the CPU container can execute).
  * default: build the production train step for the full config on the
    requested mesh and AOT-compile it (execution requires the real pod; on
    this container that is the dry-run path).

  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b --smoke --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --shape train_4k
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    if args.smoke:
        import jax
        import numpy as np

        from ..configs import get_config
        from ..data import markov_corpus, token_batches
        from ..models import Model
        from ..training import AdamW, save_checkpoint, train_loop

        cfg = get_config(args.arch).reduced()
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        corpus = markov_corpus(rng, cfg.vocab_size, 50_000)
        batches = token_batches(rng, corpus, args.batch, args.seq)
        params, res = train_loop(
            model, params, AdamW(lr=args.lr), batches,
            max_steps=args.steps, log_every=max(args.steps // 10, 1),
        )
        print(f"done: {res.steps} steps in {res.wall_s:.1f}s, "
              f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
        if args.checkpoint:
            save_checkpoint(args.checkpoint, params, step=res.steps)
            print("checkpoint ->", args.checkpoint)
        return

    # production path: AOT-build the sharded step (see dryrun for sweeps)
    from .dryrun import run_one

    rec = run_one(args.arch, args.shape, args.multi_pod, out_dir="reports/dryrun")
    if rec["ok"]:
        rf = rec["roofline"]
        print(f"compiled {args.arch}/{args.shape} on {rec['mesh']}: "
              f"compute={rf['compute_s']*1e3:.1f}ms memory={rf['memory_s']*1e3:.1f}ms "
              f"collective={rf['collective_s']*1e3:.1f}ms dominant={rf['dominant']}")
    else:
        raise SystemExit(f"FAILED: {rec.get('error')}")


if __name__ == "__main__":
    main()
