"""Real-model backend for the device-cloud simulator.

Where ``StatisticalBackend`` samples outcomes, ``RealBackend`` runs actual
JAX models: the device's draft model (shallow layers + distilled Λ + head),
the cloud's middle submodel, and (for U-Medusa) real Medusa heads with tree
verification.  The simulator still owns all wall-clock accounting — this
backend answers *what tokens happen*, which is where accept lengths
(Table 4) and ablation effects (Table 5) come from.

SSM/hybrid archs roll back recurrent state by snapshot + re-advance over the
accepted prefix (core/speculative.py, DESIGN.md §4).

With a lossy ``wire_codec`` the backend round-trips the actual hidden
states through the codec at both wire crossings — shallow states before the
middle submodel (uplink) and deep states before the output head (downlink)
— so measured accept lengths carry the true quantization error rather than
a calibrated penalty.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapter import DraftModel
from ..core.speculative import (
    draft_until_threshold,
    accept_greedy_rows,
    has_ssm_state,
    restore_states,
    snapshot_states,
)
from ..core.split import SplitModels
from ..wire import get_codec
from . import medusa as medusa_mod
from .request import Request

Params = Dict


@dataclass
class _ReqState:
    in_cache: Dict
    mid_cache: Dict
    offset: int                      # U-path cache position (verified tokens)
    draft_cache: Optional[Dict]
    draft_offset: int
    last_token: int = -1
    topk_last: Optional[np.ndarray] = None
    last_bonus: int = -1
    deep_last: Optional[np.ndarray] = None
    prompt: Optional[np.ndarray] = None


class RealBackend:
    def __init__(
        self,
        split: SplitModels,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        *,
        eta: float = 0.6,
        max_draft: int = 8,
        topk: int = 4,
        max_len: int = 512,
        rng: Optional[np.random.Generator] = None,
        memory: Optional[jax.Array] = None,
        wire_codec: Optional[str] = None,
    ):
        self.split = split
        self.codec = get_codec(wire_codec) if wire_codec is not None else None
        self.cfg = split.cfg
        self.draft_model = (
            DraftModel(split, adapter_params) if adapter_params is not None else None
        )
        self.medusa_params = medusa_params
        self.eta = eta
        self.max_draft = max_draft
        self.topk = topk
        self.max_len = max_len
        self.rng = rng or np.random.default_rng(0)
        self.memory = memory
        self.ssm = has_ssm_state(self.cfg)
        self.states: Dict[int, _ReqState] = {}

    # ------------------------------------------------------------ plumbing
    def set_wire_codec(self, codec) -> None:
        """run_fleet hook: the fleet's wire codec governs the run."""
        self.codec = codec

    def _wire(self, hidden: jax.Array) -> jax.Array:
        """One wire crossing: encode/decode through the transport codec."""
        if self.codec is None or not self.codec.lossy:
            return hidden
        return jnp.asarray(self.codec.roundtrip(np.asarray(hidden, np.float32)))

    def _u_forward(self, st: _ReqState, tokens: np.ndarray):
        """Run [1, T] tokens through the U path at st.offset; returns
        (logits [T, V], deep [T, D]) and updates both caches.

        The two ``_wire`` calls are the device->cloud and cloud->device
        hops: the middle submodel only ever sees codec-round-tripped
        shallow states, the head only codec-round-tripped deep states."""
        toks = jnp.asarray(tokens, jnp.int32)[None]
        shallow, st.in_cache, _ = self.split.input_model.apply(
            self.split.input_params, toks, cache=st.in_cache,
            offset=st.offset, memory=self.memory, return_hidden=True,
        )
        deep, st.mid_cache, _ = self.split.middle_model.apply(
            self.split.middle_params, None, inputs_embeds=self._wire(shallow),
            cache=st.mid_cache, offset=st.offset, memory=self.memory,
            return_hidden=True,
        )
        deep = self._wire(deep)
        logits = self.split.head_logits(deep)
        return np.asarray(logits[0], np.float32), np.asarray(deep[0], np.float32)

    def _prompt(self, req: Request) -> np.ndarray:
        if req.prompt is not None:
            return np.asarray(req.prompt, np.int32)
        return self.rng.integers(
            3, self.cfg.vocab_size, size=req.prompt_len
        ).astype(np.int32)

    # ----------------------------------------------------------- interface
    def first_token(self, req: Request) -> int:
        prompt = self._prompt(req)[: self.max_len // 2]
        st = _ReqState(
            in_cache=self.split.input_model.init_cache(
                self.split.input_params, 1, self.max_len, memory=self.memory
            ),
            mid_cache=self.split.middle_model.init_cache(
                self.split.middle_params, 1, self.max_len, memory=self.memory
            ),
            offset=0,
            draft_cache=None,
            draft_offset=0,
            prompt=prompt,
        )
        logits, deep = self._u_forward(st, prompt)
        st.offset = len(prompt)
        st.deep_last = deep[-1]
        tok = int(logits[-1].argmax())
        st.last_token = tok
        if self.draft_model is not None:
            st.draft_cache = self.draft_model.init_cache(
                1, self.max_len, memory=self.memory
            )
            _, st.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(prompt, jnp.int32)[None], cache=st.draft_cache,
                offset=0, memory=self.memory,
            )
            st.draft_offset = len(prompt)
        self.states[req.req_id] = st
        return tok

    def draft(self, req: Request, max_draft: int) -> List[int]:
        st = self.states[req.req_id]
        snap = snapshot_states(st.draft_cache["input"]) if self.ssm else None
        res, st.draft_cache, st.draft_offset = draft_until_threshold(
            self.draft_model, st.draft_cache,
            jnp.asarray([[st.last_token]], jnp.int32),
            st.draft_offset, eta=self.eta,
            max_draft=min(max_draft, self.max_draft), topk=self.topk,
            memory=self.memory,
        )
        st.topk_last = res.topk_last
        st._draft_snap = snap
        return res.tokens.tolist()

    def verify(self, req: Request, draft: List[int]) -> Tuple[int, int]:
        st = self.states[req.req_id]
        toks = np.asarray([st.last_token] + list(draft), np.int32)
        mid_snap = snapshot_states(st.mid_cache) if self.ssm else None
        in_snap = snapshot_states(st.in_cache) if self.ssm else None
        logits, deep = self._u_forward(st, toks)
        if draft:
            n, bonus = accept_greedy_rows(np.asarray(draft), logits)
        else:
            n, bonus = 0, int(logits[-1].argmax())
        accepted = 1 + n                 # last_token + accepted drafts
        if self.ssm and n < len(draft):
            # roll back recurrent state and re-advance the accepted prefix
            st.mid_cache = restore_states(st.mid_cache, mid_snap)
            st.in_cache = restore_states(st.in_cache, in_snap)
            logits2, deep2 = self._u_forward(st, toks[:accepted])
            deep = deep2
        st.offset += accepted
        st.deep_last = deep[accepted - 1]
        # device-side draft cache: positional rollback for attention; state
        # rollback + re-advance for SSM draft layers
        if self.draft_model is not None:
            if self.ssm and getattr(st, "_draft_snap", None) is not None:
                st.draft_cache["input"] = restore_states(
                    st.draft_cache["input"], st._draft_snap
                )
            _, st.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(toks[:accepted], jnp.int32)[None],
                cache=st.draft_cache, offset=st.offset - accepted,
                memory=self.memory,
            )
            st.draft_offset = st.offset
        st.last_bonus = bonus
        st.last_token = bonus
        return n, bonus

    def parallel_draft_hit(self, req: Request) -> bool:
        st = self.states.get(req.req_id)
        if st is None or st.topk_last is None:
            return False
        return int(st.last_bonus) in set(np.asarray(st.topk_last).tolist())

    # ------------------------------------------------------------- medusa
    def medusa_tree(self, req: Request) -> int:
        st = self.states[req.req_id]
        paths = medusa_mod.build_tree_paths(
            self.medusa_params, jnp.asarray(st.deep_last), tree_size=8
        )
        st._paths = paths
        return 8                          # tree size charged to the wire/cloud

    def medusa_verify(self, req: Request) -> Tuple[int, int]:
        st = self.states[req.req_id]
        paths = getattr(st, "_paths", None) or [[0]]
        mid_snap = snapshot_states(st.mid_cache) if self.ssm else None
        in_snap = snapshot_states(st.in_cache) if self.ssm else None
        greedy_rows = []
        for path in paths:
            toks = np.asarray([st.last_token] + list(path), np.int32)
            if self.ssm:
                st.mid_cache = restore_states(st.mid_cache, mid_snap)
                st.in_cache = restore_states(st.in_cache, in_snap)
            logits, _ = self._u_forward(st, toks)
            greedy_rows.append(logits.argmax(-1))
            # positional rollback: next path overwrites the same offsets
        best_pi, n, bonus = medusa_mod.accept_best_path(paths, greedy_rows)
        # commit the winning path's prefix
        commit = np.asarray([st.last_token] + list(paths[best_pi][:n]), np.int32)
        if self.ssm:
            st.mid_cache = restore_states(st.mid_cache, mid_snap)
            st.in_cache = restore_states(st.in_cache, in_snap)
        logits, deep = self._u_forward(st, commit)
        st.offset += len(commit)
        st.deep_last = deep[-1]
        st.last_token = bonus
        return n, bonus
