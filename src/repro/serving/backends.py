"""Real-model backend for the device-cloud simulator.

Where ``StatisticalBackend`` samples outcomes, ``RealBackend`` runs actual
JAX models.  It is a thin adaptor between the simulator's backend interface
(the simulator owns all wall-clock accounting; the backend answers *what
tokens happen*) and the session API: every request is a
:class:`~repro.serving.api.DeviceClient` session speaking serialized
``repro.wire`` frames over a :class:`~repro.serving.api.LoopbackTransport`
into a :class:`~repro.serving.api.CloudServer` — so measured accept lengths
(Table 4/5) exercise the same frames, codecs, slot-batched engine steps and
KV admission as production serving, not a private re-implementation of the
U path.

With a lossy ``wire_codec`` the hidden states genuinely cross the codec at
both wire hops (shallow uplink, deep downlink), so measured accept lengths
carry the true quantization error rather than a calibrated penalty.  With
``wire_codec=None`` the wire is the bit-exact ``fp32`` codec: speculative
output equals the teacher's greedy output token for token.

SSM/hybrid archs roll recurrent state back through the transport's control
channel (engine slot snapshot/restore) plus the device-local snapshot —
see ``core/speculative.py`` and DESIGN.md §4.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from ..core.split import SplitModels
from .api import CloudServer, DeviceClient, LoopbackTransport
from .kv_manager import KVBudget
from .request import Request

Params = Dict


class RealBackend:
    def __init__(
        self,
        split: SplitModels,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        *,
        eta: float = 0.6,
        max_draft: int = 8,
        topk: int = 4,
        max_len: int = 512,
        rng: Optional[np.random.Generator] = None,
        memory: Optional[jax.Array] = None,
        wire_codec: Optional[str] = None,
        n_slots: int = 8,
        max_batch_tokens: int = 256,
    ):
        self.split = split
        self.cfg = split.cfg
        self.max_len = max_len
        self.rng = rng or np.random.default_rng(0)
        # None = "no codec requested": the exact fp32 wire (identity on f32)
        codec_name = wire_codec if wire_codec is not None else "fp32"
        # the simulator drives concurrency from outside (a slot is held from
        # first_token until completion), so the engine pool auto-grows and
        # the block budget is effectively unbounded — matching the old
        # per-request-dict backend, which never capped concurrency
        self.server = CloudServer(
            split, n_slots=n_slots, max_len=max_len,
            max_batch_tokens=max_batch_tokens, wire_codec=codec_name,
            memory=memory, auto_grow=True,
            kv_budget=KVBudget(block_tokens=128, total_blocks=1 << 30),
        )
        self.transport = LoopbackTransport(self.server)
        self.client = DeviceClient(
            split, self.transport,
            adapter_params=adapter_params, medusa_params=medusa_params,
            sd="auto", eta=eta, max_draft=max_draft, topk=topk,
            max_len=max_len, wire_codec=codec_name, memory=memory,
        )

    # ------------------------------------------------------------ plumbing
    @property
    def codec(self):
        return self.client.codec

    def set_wire_codec(self, codec) -> None:
        """Fleet hook (``ServeConfig.configure_backend``): the run's wire
        codec governs both hops — the client's uplink and the engine's
        downlink encoding."""
        self.client.codec = codec
        self.server.engine.codec = codec

    def _prompt(self, req: Request) -> np.ndarray:
        if req.prompt is not None:
            return np.asarray(req.prompt, np.int32)
        return self.rng.integers(
            3, self.cfg.vocab_size, size=req.prompt_len
        ).astype(np.int32)

    # ----------------------------------------------------------- interface
    def first_token(self, req: Request) -> int:
        prompt = self._prompt(req)[: self.max_len // 2]
        return self.client.prefill(
            req.req_id, prompt, expected_new_tokens=req.max_new_tokens
        )

    def draft(self, req: Request, max_draft: int) -> List[int]:
        return self.client.draft(req.req_id, max_draft)

    def verify(self, req: Request, draft: List[int]) -> Tuple[int, int]:
        return self.client.verify(req.req_id, draft)

    def parallel_draft_hit(self, req: Request) -> bool:
        return self.client.parallel_draft_hit(req.req_id)

    def medusa_tree(self, req: Request) -> int:
        return self.client.medusa_tree(req.req_id)

    def medusa_verify(self, req: Request) -> Tuple[int, int]:
        return self.client.medusa_verify(req.req_id)

    def finish_request(self, req_id: int) -> None:
        """Simulator completion hook: release the device session and its
        cloud engine slot."""
        self.client.finish(req_id)
