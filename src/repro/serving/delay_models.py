"""Calibrated delay models for the device-cloud testbed (paper §2.3, §4.1).

All constants trace to measurements reported in the paper:

* Hidden-state wire size  A = d_model × 2 B (fp16).  Vicuna-7B: 8 KiB/token.
  Anchor: §2.3 — a 2k-token prompt costs 3.20 s of communication in U-shaped
  inference; 2048 × 8 KiB = 16 MiB at ~5 MB/s ≈ 3.2 s.  ✓
* Device→cloud bandwidth 5–10 MB/s up, 10–15 MB/s down (§4.1, iperf3).
* In-cloud computation: §2.3 — 0.28 s for a 2k-token prompt on the A6000
  server ⇒ ≈0.137 ms/token in the linear regime; Fig. 1(c) — batching ≤~256
  tokens costs ≈ +10% over a 1-token batch (base latency dominates).
* Device compute: Jetson AGX Orin ≈10× AGX Xavier-low (§4.1); local shallow
  layers ≈ 2.5% of the 2k-prompt TTFT = 0.09 s ⇒ ≈ 44 µs/token on Orin.
* Draft-model step (2 layers + Λ + head on Vicuna-7B): anchored so that HAT's
  measured TBT (≈26–39 ms) is reproduced with accept length ≈ 2.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class CloudDelayModel:
    """g(batched tokens) -> seconds, per pipeline stage group.

    delay(n) = base · (1 + 0.1 · min(n, sat)/sat) + slope · max(n − sat, 0)

    matches Fig. 1(c): near-flat to ``sat`` tokens (+10% at sat), then linear.
    ``pipeline_len`` P: stage occupancy = delay/P (a new batch may enter a
    P-deep pipeline every delay/P; full traversal still costs ~delay).
    """

    base_s: float = 0.045
    sat_tokens: int = 256
    slope_s_per_token: float = 0.000137
    pipeline_len: int = 4

    def delay(self, tokens: int) -> float:
        t = max(int(tokens), 0)
        d = self.base_s * (1.0 + 0.1 * min(t, self.sat_tokens) / self.sat_tokens)
        if t > self.sat_tokens:
            d += self.slope_s_per_token * (t - self.sat_tokens)
        return d

    def stage_time(self, tokens: int) -> float:
        return self.delay(tokens) / max(self.pipeline_len, 1)


@dataclass
class DeviceProfile:
    """One Jetson-class device with mode-dependent compute (paper §4.1).

    ``speed`` multiplies compute delays: Orin mode-0 = 1.0; Xavier low
    mode = 10.0 (the paper's 10× span).  Modes are re-drawn every 5 requests.
    """

    dev_id: int
    kind: str                          # "orin" | "xavier"
    rng: np.random.Generator
    distance_m: float = 2.0            # 2 / 8 / 14 m from the WiFi router

    speed: float = 1.0
    requests_since_mode_change: int = 0

    # calibrated per-token / per-step costs at speed=1.0 (Orin mode 0)
    shallow_s_per_token: float = 44e-6     # input-submodel compute
    draft_step_s: float = 0.003            # one draft-model AR step
    head_s: float = 0.0005                 # output head on deep hidden

    def __post_init__(self):
        self.resample_mode()

    def resample_mode(self) -> None:
        if self.kind == "orin":
            self.speed = float(self.rng.uniform(1.0, 2.0))
        else:
            # Xavier spans up to the paper's 10x at its lowest mode
            self.speed = float(self.rng.uniform(1.5, 5.0))
        self.requests_since_mode_change = 0

    def maybe_rotate_mode(self) -> None:
        self.requests_since_mode_change += 1
        if self.requests_since_mode_change >= 5:       # paper: every 5 requests
            self.resample_mode()

    def shallow_delay(self, tokens: int) -> float:
        return self.speed * self.shallow_s_per_token * tokens

    def draft_delay(self, steps: int) -> float:
        return self.speed * self.draft_step_s * steps

    def head_delay(self) -> float:
        return self.speed * self.head_s


@dataclass
class NetworkModel:
    """WiFi links: per-device time-varying bandwidth (paper §4.1).

    Up 5–10 MB/s, down 10–15 MB/s, modulated by distance group and random
    channel noise per transfer; transfers on one device's link serialize.

    ``up_fixed`` / ``down_fixed`` (bytes/s) pin the link to a constant rate
    for controlled sweeps (benchmarks/bench_wire.py: codec × uplink grid)."""

    rng: np.random.Generator
    up_fixed: Optional[float] = None
    down_fixed: Optional[float] = None

    # distance group -> measured bandwidth sub-range (iperf3, §4.1: overall
    # 5-10 MB/s up, 10-15 MB/s down across the three placements)
    UP_RANGE = {2.0: (8e6, 10e6), 8.0: (6.5e6, 8.5e6), 14.0: (5e6, 7e6)}
    DOWN_RANGE = {2.0: (13e6, 15e6), 8.0: (11.5e6, 13.5e6), 14.0: (10e6, 12e6)}

    def up_bw(self, dev: DeviceProfile) -> float:
        if self.up_fixed is not None:
            return self.up_fixed
        lo, hi = self.UP_RANGE.get(dev.distance_m, (5e6, 10e6))
        return self.rng.uniform(lo, hi)

    def down_bw(self, dev: DeviceProfile) -> float:
        if self.down_fixed is not None:
            return self.down_fixed
        lo, hi = self.DOWN_RANGE.get(dev.distance_m, (10e6, 15e6))
        return self.rng.uniform(lo, hi)

    def up_time(self, dev: DeviceProfile, nbytes: float) -> float:
        return nbytes / self.up_bw(dev)

    def down_time(self, dev: DeviceProfile, nbytes: float) -> float:
        return nbytes / self.down_bw(dev)


def pipelined_prefill_estimate_s(
    chunks,
    *,
    dev: DeviceProfile,
    cloud: CloudDelayModel,
    beta_up: float,
    hidden_bytes_per_token: float,
    pipeline_depth: int = 0,
) -> float:
    """Prefill-completion estimate (seconds) under uplink/compute overlap.

    Glues the calibrated testbed models onto the §4.2 overlap recurrence
    (:func:`repro.core.chunking.pipelined_prefill_time`): per-chunk upload
    at ``beta_up`` bytes/s, per-chunk cloud occupancy ``stage_time``, plus
    the first chunk's shallow compute as lead-in (later chunks' shallow
    compute hides under uploads).  Downlink + head are plan-independent
    and excluded — compare plans, not absolute TTFT."""
    from ..core.chunking import pipelined_prefill_time

    if not chunks:
        return 0.0
    lead = dev.shallow_delay(chunks[0])
    return lead + pipelined_prefill_time(
        list(chunks),
        up_time=lambda x: x * hidden_bytes_per_token / max(beta_up, 1e-9),
        step_time=cloud.stage_time,
        pipeline_depth=pipeline_depth,
    )


def make_fleet(rng: np.random.Generator, n_devices: int = 30):
    """20 Xavier + 10 Orin across 3 distance groups (paper §4.1)."""
    fleet = []
    for i in range(n_devices):
        kind = "orin" if i % 3 == 2 else "xavier"      # 10 orin / 20 xavier
        dist = [2.0, 8.0, 14.0][i % 3]
        fleet.append(DeviceProfile(dev_id=i, kind=kind, rng=rng, distance_m=dist))
    return fleet
