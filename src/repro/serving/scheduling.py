"""Shared cloud-scheduler semantics: Sarathi-style budgeted admission.

One policy, two executors.  The discrete-event :class:`~.simulator.Simulator`
and the real-tensor :class:`~.engine.CloudEngine` must admit work into a
batch the same way, or the simulator's contention numbers stop predicting
the engine's — this module is the single implementation both call.

The policy (paper §3.3 / Sarathi-Serve):

* decode work (``verify`` strips) is admitted before prefill chunks —
  decode latency is the SLA-bound quantity;
* a token budget caps the batch (``max_batch_tokens``); an oversized job is
  admitted *alone* rather than starved;
* ``max_batch_tokens=None`` is the naive baseline: batch everything
  (U-shape / U-Medusa — long prompts interfere with decode, Fig. 1(c));
* at most one job per engine slot (``slot_of``): two jobs of one request
  are sequentially dependent through its KV cache rows.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple, TypeVar

J = TypeVar("J")


def budgeted_admission(
    jobs: Sequence[J],
    max_batch_tokens: Optional[int],
    *,
    tokens_of: Callable[[J], int],
    kind_of: Callable[[J], str] = lambda j: j.kind,
    slot_of: Optional[Callable[[J], int]] = None,
) -> Tuple[List[J], List[J]]:
    """Pick one batch from ``jobs`` -> (chosen, remaining).

    ``remaining`` preserves the original queue order of the jobs that were
    not admitted (continuous batching: they stay queued for the next step).
    """
    if not jobs:
        return [], []
    if max_batch_tokens is None:
        # naive batching admits everything anyway: keep queue order so the
        # baselines' event ordering (and RNG draws) match the historical
        # unbudgeted path exactly
        order = list(jobs)
    else:
        order = sorted(jobs, key=lambda j: 0 if kind_of(j) == "verify" else 1)
    budget = float("inf") if max_batch_tokens is None else max_batch_tokens
    chosen: List[J] = []
    busy: set = set()
    for j in order:
        if budget <= 0:
            break
        slot = slot_of(j) if slot_of is not None else None
        if slot is not None and slot in busy:
            continue
        t = tokens_of(j)
        if chosen and t > budget:
            continue                      # oversized mid-batch: wait its turn
        chosen.append(j)
        if slot is not None:
            busy.add(slot)
        budget -= t
    chosen_ids = {id(j) for j in chosen}
    rest = [j for j in jobs if id(j) not in chosen_ids]
    return chosen, rest
