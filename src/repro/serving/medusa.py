"""U-Medusa baseline (paper §4.1): Medusa heads + tree verification inside
the U-shaped framework.

4 Medusa heads live on the device with the input/output submodels; head i
predicts the token at position t+1+i from the deep hidden state at t.  Each
head is a residual SiLU block + its own unembedding — this is why U-Medusa
trains 591M/760M parameters where HAT's Λ needs 67M/105M (Table 4).

Tree verification: the heads' top candidates form ``tree_size`` root-to-leaf
paths; all paths are verified against the LLM in one step.  We evaluate the
tree as batched candidate paths (mathematically identical to tree-attention
masking; DESIGN.md §5) and the cost model charges the paper's tree size.
"""
from __future__ import annotations

import itertools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.layers import F32, dense_init, rms_norm, zeros

Params = Dict

N_HEADS = 4


def init_medusa(cfg: ModelConfig, key, dtype=jnp.float32) -> Tuple[Params, Params]:
    d, v = cfg.d_model, cfg.vocab_size
    ks = jax.random.split(key, 2 * N_HEADS)
    p, s = {}, {}
    for i in range(N_HEADS):
        p[f"h{i}"] = {
            "w": dense_init(ks[2 * i], d, d, dtype, scale=0.01),
            "b": zeros((d,), dtype),
            "out": dense_init(ks[2 * i + 1], d, v, dtype),
        }
        s[f"h{i}"] = {"w": "mlp_in", "b": "norm", "out": "head_dv"}
    return p, s


def medusa_param_count(cfg: ModelConfig) -> int:
    d, v = cfg.d_model, cfg.vocab_size
    return N_HEADS * (d * d + d + d * v)


def medusa_logits(params: Params, deep_hidden: jax.Array) -> jax.Array:
    """deep_hidden [..., D] -> [N_HEADS, ..., V]."""
    outs = []
    for i in range(N_HEADS):
        h = params[f"h{i}"]
        x = deep_hidden + jax.nn.silu(deep_hidden @ h["w"] + h["b"])
        outs.append(x @ h["out"])
    return jnp.stack(outs)


def medusa_loss(params: Params, deep_hidden: jax.Array, tokens: jax.Array):
    """CE of head i against the token i+1 steps ahead.

    deep_hidden [B, T, D] (teacher pre-head states), tokens [B, T]."""
    logits = medusa_logits(params, deep_hidden)        # [H, B, T, V]
    loss = jnp.zeros((), F32)
    for i in range(N_HEADS):
        tgt = tokens[:, i + 1 :]
        lg = logits[i][:, : tgt.shape[1]]
        logp = jax.nn.log_softmax(lg.astype(F32), -1)
        loss += -jnp.mean(jnp.take_along_axis(logp, tgt[..., None], -1))
    return loss / N_HEADS


def build_tree_paths(
    params: Params,
    deep_hidden_last: jax.Array,        # [D] deep hidden at current position
    *,
    tree_size: int = 8,
    branching: Tuple[int, ...] = (4, 2, 1, 1),
) -> List[List[int]]:
    """Top candidates per head -> root-to-leaf token paths (≤ tree_size)."""
    logits = medusa_logits(params, deep_hidden_last[None])[:, 0]   # [H, V]
    tops = [
        np.asarray(jax.lax.top_k(logits[i], branching[i])[1]).tolist()
        for i in range(N_HEADS)
    ]
    paths = []
    for combo in itertools.product(*tops):
        paths.append(list(combo))
        if len(paths) >= tree_size:
            break
    return paths


def accept_best_path(
    paths: List[List[int]],
    greedy_rows: List[np.ndarray],
) -> Tuple[int, int, int]:
    """Pick the path with the longest greedy-matched prefix.

    ``greedy_rows[p]`` are the LLM's greedy tokens for path p's positions
    (k+1 rows: one per path token plus the bonus position).  Returns
    (best_path_idx, n_accept, bonus_token)."""
    best = (0, 0, int(greedy_rows[0][0]))
    for pi, (path, greedy) in enumerate(zip(paths, greedy_rows)):
        n = 0
        while n < len(path) and int(path[n]) == int(greedy[n]):
            n += 1
        if n > best[1]:
            best = (pi, n, int(greedy[n]))
    if best[1] == 0:
        best = (0, 0, int(greedy_rows[0][0]))
    return best
