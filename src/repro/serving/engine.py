"""Batched cloud engine: continuous batching over the HAT middle submodel.

The cloud holds the middle submodel sharded over the mesh (or a single
device in the runnable examples).  Requests occupy *slots*; each engine step
builds one [n_slots, T_step] chunk where every active slot contributes its
pending work (a prefill chunk or a verification strip), padded to the step
width; per-slot vector offsets place each row at its own cache position.
Admission follows the Sarathi-style token budget (scheduler semantics shared
with the simulator), capacity follows SlotKVManager.

This is the *real-tensor* counterpart of the simulator's cloud: the serve
example and the engine tests run actual JAX compute through it.

Ingress/egress is the repro.wire transport: ``submit_frame`` decodes a
serialized chunk frame (codec-quantized hidden states) before the middle
submodel runs, and ``encode_result`` re-encodes deep hidden states with the
engine's downlink codec for the device-bound hop.  The bare-array
``submit``/``EngineJob`` path remains for in-process callers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.split import SplitModels
from ..wire import KIND_DEEP, Frame, decode_hidden, encode_hidden, get_codec
from .kv_manager import KVBudget, SlotKVManager

F32 = jnp.float32


@dataclass
class EngineJob:
    req_id: int
    hidden: np.ndarray          # [T, D] shallow hidden states (the wire data)
    offset: int                 # cache position of hidden[0]
    kind: str                   # "prefill" | "verify"
    want_deep: bool = True      # return deep hidden states (last chunk/verify)


@dataclass
class EngineResult:
    req_id: int
    deep: Optional[np.ndarray]  # [T, D] deep hidden states (device runs head)
    kind: str
    offset: int = 0             # cache position of deep[0]


class CloudEngine:
    def __init__(
        self,
        split: SplitModels,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        max_batch_tokens: int = 256,
        kv_budget: Optional[KVBudget] = None,
        memory: Optional[jax.Array] = None,
        wire_codec: str = "fp16",
    ):
        self.split = split
        self.codec = get_codec(wire_codec)       # downlink (deep-state) codec
        self.wire_bytes_in = 0
        self.wire_bytes_out = 0
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_batch_tokens = max_batch_tokens
        self.kv = SlotKVManager(n_slots, max_len, kv_budget)
        mem = None
        if memory is not None:
            mem = jnp.broadcast_to(memory, (n_slots,) + memory.shape[-2:])
        self.cache = split.middle_model.init_cache(
            split.middle_params, n_slots, max_len, memory=mem
        )
        self.queue: List[EngineJob] = []
        self.d_model = split.cfg.d_model
        self._step_fn = jax.jit(self._raw_step, static_argnames=("t_step",))
        self.steps = 0
        self.batched_token_history: List[int] = []

    # --------------------------------------------------------------- admit
    def add_request(self, req_id: int, expected_tokens: int) -> bool:
        if not self.kv.can_admit(expected_tokens):
            return False
        self.kv.admit(req_id, expected_tokens)
        return True

    def finish_request(self, req_id: int) -> None:
        self.kv.release(req_id)

    def submit(self, job: EngineJob) -> None:
        assert job.req_id in self.kv.slot_of, "request not admitted"
        self.queue.append(job)

    # ---------------------------------------------------------------- wire
    def submit_frame(self, data: bytes) -> None:
        """Decode one serialized chunk frame (repro.wire) and enqueue it.

        The frame names its own codec, so a fleet of devices may mix
        uplink codecs against one engine."""
        frame = Frame.from_bytes(data) if isinstance(data, (bytes, bytearray)) else data
        if frame.kind == KIND_DEEP:
            raise ValueError("deep frames flow cloud->device, not into the engine")
        self.wire_bytes_in += frame.nbytes()
        hidden = decode_hidden(frame, self.d_model)
        self.submit(EngineJob(frame.req_id, hidden, frame.offset,
                              frame.kind_name, want_deep=frame.want_deep))

    def encode_result(self, res: EngineResult) -> bytes:
        """Serialize a step result's deep hidden states for the downlink."""
        assert res.deep is not None, "result carries no deep states"
        data = encode_hidden(self.codec, res.deep, req_id=res.req_id,
                             offset=res.offset, kind="deep", want_deep=False)
        self.wire_bytes_out += len(data)
        return data

    # ---------------------------------------------------------------- step
    def _raw_step(self, params, cache, hidden, offsets, t_step: int):
        deep, new_cache, _ = self.split.middle_model.apply(
            params, None, inputs_embeds=hidden, cache=cache, offset=offsets,
        )
        return deep, new_cache

    def step(self) -> List[EngineResult]:
        """One engine iteration: admit jobs under the token budget, run the
        middle submodel once, return deep hidden states per job."""
        if not self.queue:
            return []
        # --- budgeted admission: verifies first, then prefill chunks -------
        budget = self.max_batch_tokens
        chosen: List[EngineJob] = []
        busy_slots = set()
        for job in sorted(self.queue, key=lambda j: 0 if j.kind == "verify" else 1):
            t = len(job.hidden)
            slot = self.kv.slot_of[job.req_id]
            if slot in busy_slots or (chosen and t > budget):
                continue
            chosen.append(job)
            busy_slots.add(slot)
            budget -= t
            if budget <= 0:
                break
        chosen_ids = {id(j) for j in chosen}
        self.queue = [j for j in self.queue if id(j) not in chosen_ids]

        t_step = max(len(j.hidden) for j in chosen)
        B = self.n_slots
        hidden = np.zeros((B, t_step, self.d_model), np.float32)
        offsets = np.zeros((B,), np.int32)
        for j in chosen:
            slot = self.kv.slot_of[j.req_id]
            hidden[slot, : len(j.hidden)] = j.hidden
            offsets[slot] = j.offset
            self.kv.extend(j.req_id, j.offset + len(j.hidden))

        deep, self.cache = self._step_fn(
            self.split.middle_params, self.cache,
            jnp.asarray(hidden), jnp.asarray(offsets), t_step=t_step,
        )
        deep = np.asarray(deep)
        self.steps += 1
        self.batched_token_history.append(sum(len(j.hidden) for j in chosen))

        out = []
        for j in chosen:
            slot = self.kv.slot_of[j.req_id]
            d = deep[slot, : len(j.hidden)] if j.want_deep else None
            out.append(EngineResult(j.req_id, d, j.kind, offset=j.offset))
        return out

    def drain(self) -> List[EngineResult]:
        res = []
        while self.queue:
            res.extend(self.step())
        return res
