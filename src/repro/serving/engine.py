"""Batched cloud engine: continuous batching over the HAT middle submodel.

The cloud holds the middle submodel sharded over the mesh (or a single
device in the runnable examples).  Requests occupy *slots*; each engine step
builds one [n_slots, T_step] chunk where every active slot contributes its
pending work (a prefill chunk or a verification strip), padded to the step
width; per-slot vector offsets place each row at its own cache position.
Admission follows the Sarathi-style token budget (scheduler semantics shared
with the simulator), capacity follows SlotKVManager.

This is the *real-tensor* counterpart of the simulator's cloud: the serve
example and the engine tests run actual JAX compute through it.

Ingress/egress is the repro.wire transport: ``submit_frame`` decodes a
serialized chunk frame (codec-quantized hidden states) before the middle
submodel runs, and ``encode_result`` re-encodes deep hidden states with the
engine's downlink codec for the device-bound hop.  The bare-array
``submit``/``EngineJob`` path remains for in-process callers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.speculative import SSM_STATE_KEYS
from ..core.split import SplitModels
from ..obs import NULL_TRACER, TID_CLOUD, Tracer
from ..wire import KIND_DEEP, Frame, decode_hidden, encode_hidden, get_codec
from .kv_manager import KVAccountingError, KVBudget, SlotKVManager
from .scheduling import budgeted_admission

F32 = jnp.float32


def bucket_t_step(t: int, max_len: int) -> int:
    """Round a step width up to the next power of two (clamped to the slot
    capacity).  The jitted step is compiled per distinct ``t_step``, so
    bucketing bounds the compile count at O(log max_len) instead of one
    variant per distinct chunk/strip width the fleet ever produces."""
    assert 1 <= t <= max_len, (t, max_len)
    return min(1 << (t - 1).bit_length(), max_len)


class EngineOverflowError(RuntimeError):
    """A job would write past its slot's KV cache (offset + T > max_len).

    Raised per request at submit time; the offending request's slot is
    released so the rest of the batch keeps serving."""

    def __init__(self, req_id: int, offset: int, n_tokens: int, max_len: int):
        self.req_id = req_id
        super().__init__(
            f"request {req_id}: job spans cache positions "
            f"[{offset}, {offset + n_tokens}) but the slot holds max_len="
            f"{max_len}; slot released"
        )


@dataclass
class EngineJob:
    req_id: int
    hidden: np.ndarray          # [T, D] shallow hidden states (the wire data)
    offset: int                 # cache position of hidden[0]
    kind: str                   # "prefill" | "verify"
    want_deep: bool = True      # return deep hidden states (last chunk/verify)
    ready_s: float = 0.0        # frame event timestamp (sender clock)
    n_frames: int = 1           # wire frames merged into this job (coalescing)


@dataclass
class EngineResult:
    req_id: int
    deep: Optional[np.ndarray]  # [T, D] deep hidden states (device runs head)
    kind: str
    offset: int = 0             # cache position of deep[0]


class CloudEngine:
    def __init__(
        self,
        split: SplitModels,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        max_batch_tokens: Optional[int] = 256,   # None = unbudgeted (naive)
        kv_budget: Optional[KVBudget] = None,
        memory: Optional[jax.Array] = None,
        wire_codec: str = "fp16",
        auto_grow: bool = False,
        coalesce_prefill: bool = False,
        tracer: Optional[Tracer] = None,
    ):
        self.split = split
        # host-side flight recorder: step() phases land as wall-clock spans
        # under PID_HOST (a separate time domain from the runtimes' virtual
        # clocks), plus batched-token / slot-occupancy counters
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.codec = get_codec(wire_codec)       # downlink (deep-state) codec
        self.wire_bytes_in = 0
        self.wire_bytes_out = 0
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_batch_tokens = max_batch_tokens
        # auto_grow: double the slot pool instead of rejecting admission when
        # every slot is occupied (session-adaptor use, where concurrency is
        # driven from outside); explicit-capacity callers keep the hard cap
        self.auto_grow = auto_grow
        # coalesce_prefill: merge contiguous queued prefill chunks of one
        # session into a single job before admission.  A pipelined device
        # streams many small chunks; one long prefill row is numerically
        # identical (same causal attention / recurrence over the same
        # positions) and costs one step instead of N — the TCP service
        # turns this on, in-process runtimes keep per-chunk steps so their
        # pinned batching traces stay byte-stable.
        self.coalesce_prefill = coalesce_prefill
        self.frames_coalesced = 0
        self.kv = SlotKVManager(n_slots, max_len, kv_budget)
        self._memory = memory
        mem = None
        if memory is not None:
            mem = jnp.broadcast_to(memory, (n_slots,) + memory.shape[-2:])
        self.cache = split.middle_model.init_cache(
            split.middle_params, n_slots, max_len, memory=mem
        )
        self.queue: List[EngineJob] = []
        self.d_model = split.cfg.d_model
        # the cache is donated into the jitted step: the middle submodel's
        # KV/state tree is by far the engine's largest buffer, and without
        # donation XLA copies it wholesale every step (launch/steps.py
        # donates the same way for the lowered serving steps)
        self._step_fn = jax.jit(
            self._raw_step, static_argnames=("t_step",), donate_argnums=(1,)
        )
        self.steps = 0
        self.batched_token_history: List[int] = []
        self._compiled: set = set()          # (n_slots, t_step) variants
        self.last_step_info: List[Dict] = []  # per-job metadata of last step
        self.step_wall_s = 0.0               # host wall time inside step()

    @property
    def jit_compiles(self) -> int:
        """Distinct (n_slots, t_step) step variants compiled so far."""
        return len(self._compiled)

    # --------------------------------------------------------------- admit
    def add_request(self, req_id: int, expected_tokens: int) -> bool:
        if self.auto_grow and not self.kv.free_slots:
            self._grow_slots(self.n_slots + 1)
        if not self.kv.can_admit(expected_tokens):
            return False
        self.kv.admit(req_id, expected_tokens)
        return True

    def _grow_slots(self, min_slots: int) -> None:
        """Double the slot pool, carrying every live slot's cache rows over.

        The slot batch axis of every cache leaf is axis 1 (after the
        scan-repetition axis), so the old cache copies into the head of a
        freshly initialized larger one.  Each new batch width recompiles
        the jitted step once; doubling keeps that logarithmic."""
        new_n = max(self.n_slots * 2, min_slots)
        mem = None
        if self._memory is not None:
            mem = jnp.broadcast_to(
                self._memory, (new_n,) + self._memory.shape[-2:]
            )
        new_cache = self.split.middle_model.init_cache(
            self.split.middle_params, new_n, self.max_len, memory=mem
        )
        self.cache = jax.tree.map(
            lambda new, old: new.at[:, : old.shape[1]].set(old),
            new_cache, self.cache,
        )
        self.n_slots = new_n
        self.kv.grow(new_n)

    def finish_request(self, req_id: int) -> None:
        self.kv.release(req_id)

    def submit(self, job: EngineJob) -> None:
        if job.req_id not in self.kv.slot_of:
            raise KVAccountingError(
                f"submit for unadmitted request {job.req_id}")
        if job.offset < 0 or job.offset + len(job.hidden) > self.max_len:
            # previously this scribbled past the slot cache silently (XLA
            # clamps dynamic-update-slice indices): fail loudly instead and
            # free the capacity the broken request held
            self.queue = [j for j in self.queue if j.req_id != job.req_id]
            self.kv.release(job.req_id)
            raise EngineOverflowError(
                job.req_id, job.offset, len(job.hidden), self.max_len
            )
        self.queue.append(job)

    # ---------------------------------------------------------------- wire
    def submit_frame(self, data: bytes) -> None:
        """Decode one serialized chunk frame (repro.wire) and enqueue it.

        The frame names its own codec, so a fleet of devices may mix
        uplink codecs against one engine."""
        frame = Frame.from_bytes(data) if isinstance(data, (bytes, bytearray)) else data
        if frame.kind == KIND_DEEP:
            raise ValueError("deep frames flow cloud->device, not into the engine")
        self.wire_bytes_in += frame.nbytes()
        hidden = decode_hidden(frame, self.d_model)
        self.submit(EngineJob(frame.req_id, hidden, frame.offset,
                              frame.kind_name, want_deep=frame.want_deep,
                              ready_s=frame.t_send))

    def encode_result(self, res: EngineResult) -> bytes:
        """Serialize a step result's deep hidden states for the downlink."""
        assert res.deep is not None, "result carries no deep states"
        data = encode_hidden(self.codec, res.deep, req_id=res.req_id,
                             offset=res.offset, kind="deep", want_deep=False)
        self.wire_bytes_out += len(data)
        return data

    # ---------------------------------------------------------------- step
    def _raw_step(self, params, cache, hidden, offsets, lengths, t_step: int):
        mask = lengths > 0
        deep, new_cache, _ = self.split.middle_model.apply(
            params, None, inputs_embeds=hidden, cache=cache, offset=offsets,
            lengths=lengths,
        )
        # the model writes cache rows for EVERY batch slot — including idle
        # ones, whose zero-input activations would scribble over other
        # sessions' KV entries (and advance their recurrent state) at the
        # leftover offset.  Keep the old cache for slots without a job in
        # this batch; padded *rows* of active slots are handled inside the
        # model (causality for attention, ``lengths`` identity updates for
        # recurrent state).  [reps, n_slots, ...] leaves: mask broadcasts
        # on axis 1.
        def keep_active(new, old):
            m = mask.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return deep, jax.tree.map(keep_active, new_cache, cache)

    def step(self) -> List[EngineResult]:
        """One engine iteration: admit jobs under the token budget, run the
        middle submodel once, return deep hidden states per job.

        Admission is the shared Sarathi-style policy (scheduling.py): with a
        multi-request queue, one step carries prefill chunks and verify
        strips of *different* sessions — the batch is right-padded to a
        power-of-two ``t_step``, padding/scatter stays on device, and only
        the rows of slots that asked for deep states come back to the host.
        """
        if not self.queue:
            return []
        t_start = time.perf_counter()
        if self.coalesce_prefill:
            self._coalesce_queue()
        with self.tracer.span("batch_build", tid=TID_CLOUD) as build_a:
            chosen, self.queue = budgeted_admission(
                self.queue, self.max_batch_tokens,
                tokens_of=lambda j: len(j.hidden),
                slot_of=lambda j: self.kv.slot_of[j.req_id],
            )

            t_step = bucket_t_step(
                max(len(j.hidden) for j in chosen), self.max_len
            )
            B = self.n_slots
            # device-side batch assembly in ONE scatter: the host transfers
            # exactly the jobs' own rows (the wire payload, concatenated)
            # plus a flat index vector; zero-padding to [B, t_step, D]
            # happens on device, with no full-batch host round trip and no
            # per-job dispatch chain re-materializing the padded buffer
            offsets = np.zeros((B,), np.int32)
            lengths = np.zeros((B,), np.int32)
            flat_idx: List[np.ndarray] = []
            for j in chosen:
                slot = self.kv.slot_of[j.req_id]
                offsets[slot] = j.offset
                lengths[slot] = len(j.hidden)
                flat_idx.append(slot * t_step + np.arange(len(j.hidden)))
                self.kv.extend(j.req_id, j.offset + len(j.hidden))
            rows = np.concatenate(
                [np.asarray(j.hidden, np.float32) for j in chosen], axis=0
            )
            hidden = (
                jnp.zeros((B * t_step, self.d_model), F32)
                .at[jnp.asarray(np.concatenate(flat_idx), np.int32)]
                .set(jnp.asarray(rows))
                .reshape(B, t_step, self.d_model)
            )
            tokens = sum(len(j.hidden) for j in chosen)
            build_a["jobs"] = len(chosen)
            build_a["tokens"] = tokens

        with self.tracer.span("jit_step", tid=TID_CLOUD,
                              t_step=t_step, tokens=tokens):
            self._compiled.add((B, t_step))
            deep, self.cache = self._step_fn(
                self.split.middle_params, self.cache, hidden,
                jnp.asarray(offsets), jnp.asarray(lengths), t_step=t_step,
            )
            jax.block_until_ready(deep)    # charge the step its own compute
        self.steps += 1
        self.batched_token_history.append(tokens)
        self.last_step_info = [
            {"req_id": j.req_id, "kind": j.kind, "tokens": len(j.hidden),
             "ready_s": j.ready_s, "want_deep": j.want_deep,
             "n_frames": j.n_frames}
            for j in chosen
        ]

        with self.tracer.span("gather", tid=TID_CLOUD):
            out = []
            for j in chosen:
                slot = self.kv.slot_of[j.req_id]
                # only want_deep rows cross back to the host (the
                # downlink); other slots' deep states never leave device
                d = (np.asarray(deep[slot, : len(j.hidden)])
                     if j.want_deep else None)
                out.append(EngineResult(j.req_id, d, j.kind, offset=j.offset))
        self.tracer.counter("batched_tokens", tokens)
        self.tracer.counter(
            "slot_occupancy", self.n_slots - len(self.kv.free_slots)
        )
        self.tracer.record_hist("batch_tokens", tokens)
        self.step_wall_s += time.perf_counter() - t_start
        return out

    def _coalesce_queue(self) -> None:
        """Merge contiguous queued prefill chunks of one session in place.

        A chunk merges into that session's previous queued prefill job when
        it continues it exactly (``offset == prev.offset + len(prev)``),
        the previous job isn't a stream tail (``want_deep`` stays with the
        last chunk) and the merged width still fits the token budget.
        Per-session order is untouched, so the recurrence/attention the
        merged row computes is identical to stepping the chunks one by one.
        """
        out: List[EngineJob] = []
        last_by_req: Dict[int, EngineJob] = {}
        for j in self.queue:
            prev = last_by_req.get(j.req_id)
            if (
                j.kind == "prefill"
                and prev is not None
                and prev.kind == "prefill"
                and not prev.want_deep
                and prev.offset + len(prev.hidden) == j.offset
                and (self.max_batch_tokens is None
                     or len(prev.hidden) + len(j.hidden)
                     <= self.max_batch_tokens)
            ):
                prev.hidden = np.concatenate(
                    [np.asarray(prev.hidden), np.asarray(j.hidden)], axis=0
                )
                prev.want_deep = j.want_deep
                prev.n_frames += j.n_frames
                self.frames_coalesced += j.n_frames
                continue
            out.append(j)
            last_by_req[j.req_id] = j
        self.queue = out

    def drain(self) -> List[EngineResult]:
        res = []
        while self.queue:
            res.extend(self.step())
        return res

    # ---------------------------------------------------- SSM slot rollback
    # Attention slots roll back *positionally* (the next job overwrites the
    # rejected cache rows), but recurrent layers (mamba2/mlstm/slstm) carry
    # state, not positions: speculative rollback needs the pre-verification
    # state back.  These two methods give the cloud side of the session
    # protocol a per-slot snapshot/restore, mirroring
    # core.speculative.{snapshot,restore}_states at batch granularity.

    def snapshot_slot(self, req_id: int):
        """Copy the recurrent-state pieces of one request's slot.

        State subtrees live under keys ``m2``/``ml``/``sl`` of each layer's
        cache piece, with shape [reps, n_slots, ...] — the slot's batch row
        sits on axis 1, after the scan-repetition axis."""
        slot = self.kv.slot_of[req_id]
        snap = []
        for g in self.cache["groups"]:
            snap.append({
                lk: {k: jax.tree.map(lambda a: a[:, slot], piece[k])
                     for k in SSM_STATE_KEYS if k in piece}
                for lk, piece in g.items()
            })
        return snap

    def restore_slot(self, req_id: int, snap) -> None:
        """Overwrite one slot's recurrent-state pieces from a snapshot."""
        slot = self.kv.slot_of[req_id]
        new_groups = []
        for g, sg in zip(self.cache["groups"], snap):
            ng = {}
            for lk, piece in g.items():
                np_ = dict(piece)
                for k, v in sg.get(lk, {}).items():
                    np_[k] = jax.tree.map(
                        lambda a, s: a.at[:, slot].set(s), np_[k], v
                    )
                ng[lk] = np_
            new_groups.append(ng)
        self.cache = {"groups": new_groups}

    # ------------------------------------------------ whole-pool checkpoint
    # snapshot_slot/restore_slot move *one* slot's recurrent state for the
    # in-band session protocol; these two move the entire pool — every
    # slot's KV rows and SSM state plus the SlotKVManager books — so a new
    # cloud process can pick up mid-generation sessions after a restart.

    def checkpoint_state(self) -> Dict:
        """Whole-pool snapshot: the full cache pytree (KV + recurrent state
        for every slot) as host arrays, the slot/block accounting, and the
        shape config needed to validate a restore."""
        return {
            "config": {
                "n_slots": int(self.n_slots),
                "max_len": int(self.max_len),
                "d_model": int(self.d_model),
            },
            "cache": jax.tree.map(np.asarray, self.cache),
            "kv": self.kv.state_dict(),
        }

    def restore_state(self, state: Dict) -> None:
        """Restore a :meth:`checkpoint_state` snapshot into this engine.

        The engine grows its slot pool if the checkpoint had more slots;
        any other shape/structure mismatch raises
        :class:`~repro.training.checkpoint.CheckpointError`.  The pending
        job queue is dropped — a checkpoint is consistent at the
        *processed* watermark, and unprocessed frames are replayed by the
        devices on resume.
        """
        from ..training.checkpoint import CheckpointError

        try:
            cfg = state["config"]
            ckpt_slots = int(cfg["n_slots"])
            if (int(cfg["max_len"]), int(cfg["d_model"])) != (self.max_len, self.d_model):
                raise CheckpointError(
                    f"checkpoint shape (max_len={cfg['max_len']}, "
                    f"d_model={cfg['d_model']}) does not match engine "
                    f"(max_len={self.max_len}, d_model={self.d_model})")
            if ckpt_slots < self.n_slots:
                raise CheckpointError(
                    f"checkpoint has {ckpt_slots} slots, engine already has "
                    f"{self.n_slots} — refusing to shrink the pool")
        except (KeyError, TypeError, ValueError) as e:
            raise CheckpointError(f"malformed engine checkpoint: {e}") from e
        if ckpt_slots > self.n_slots:
            mem = None
            if self._memory is not None:
                mem = jnp.broadcast_to(
                    self._memory, (ckpt_slots,) + self._memory.shape[-2:]
                )
            self.cache = self.split.middle_model.init_cache(
                self.split.middle_params, ckpt_slots, self.max_len, memory=mem
            )
            self.n_slots = ckpt_slots

        def _load_leaf(cur, saved):
            saved = np.asarray(saved)
            if tuple(saved.shape) != tuple(cur.shape):
                raise CheckpointError(
                    f"cache leaf shape {saved.shape} != engine {cur.shape}")
            return jnp.asarray(saved, dtype=cur.dtype)

        try:
            self.cache = jax.tree.map(_load_leaf, self.cache, state["cache"])
        except CheckpointError:
            raise
        except (KeyError, TypeError, ValueError) as e:
            raise CheckpointError(
                f"engine checkpoint cache structure mismatch: {e}") from e
        self.kv.load_state_dict(state["kv"])
        self.queue = []
        self.last_step_info = []
