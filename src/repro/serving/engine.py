"""Batched cloud engine: continuous batching over the HAT middle submodel.

The cloud holds the middle submodel sharded over the mesh (or a single
device in the runnable examples).  Requests occupy *slots*; each engine step
builds one [n_slots, T_step] chunk where every active slot contributes its
pending work (a prefill chunk or a verification strip), padded to the step
width; per-slot vector offsets place each row at its own cache position.
Admission follows the Sarathi-style token budget (scheduler semantics shared
with the simulator), capacity follows SlotKVManager.

This is the *real-tensor* counterpart of the simulator's cloud: the serve
example and the engine tests run actual JAX compute through it.

Ingress/egress is the repro.wire transport: ``submit_frame`` decodes a
serialized chunk frame (codec-quantized hidden states) before the middle
submodel runs, and ``encode_result`` re-encodes deep hidden states with the
engine's downlink codec for the device-bound hop.  The bare-array
``submit``/``EngineJob`` path remains for in-process callers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.speculative import SSM_STATE_KEYS
from ..core.split import SplitModels
from ..wire import KIND_DEEP, Frame, decode_hidden, encode_hidden, get_codec
from .kv_manager import KVBudget, SlotKVManager

F32 = jnp.float32


class EngineOverflowError(RuntimeError):
    """A job would write past its slot's KV cache (offset + T > max_len).

    Raised per request at submit time; the offending request's slot is
    released so the rest of the batch keeps serving."""

    def __init__(self, req_id: int, offset: int, n_tokens: int, max_len: int):
        self.req_id = req_id
        super().__init__(
            f"request {req_id}: job spans cache positions "
            f"[{offset}, {offset + n_tokens}) but the slot holds max_len="
            f"{max_len}; slot released"
        )


@dataclass
class EngineJob:
    req_id: int
    hidden: np.ndarray          # [T, D] shallow hidden states (the wire data)
    offset: int                 # cache position of hidden[0]
    kind: str                   # "prefill" | "verify"
    want_deep: bool = True      # return deep hidden states (last chunk/verify)


@dataclass
class EngineResult:
    req_id: int
    deep: Optional[np.ndarray]  # [T, D] deep hidden states (device runs head)
    kind: str
    offset: int = 0             # cache position of deep[0]


class CloudEngine:
    def __init__(
        self,
        split: SplitModels,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        max_batch_tokens: int = 256,
        kv_budget: Optional[KVBudget] = None,
        memory: Optional[jax.Array] = None,
        wire_codec: str = "fp16",
        auto_grow: bool = False,
    ):
        self.split = split
        self.codec = get_codec(wire_codec)       # downlink (deep-state) codec
        self.wire_bytes_in = 0
        self.wire_bytes_out = 0
        self.n_slots = n_slots
        self.max_len = max_len
        self.max_batch_tokens = max_batch_tokens
        # auto_grow: double the slot pool instead of rejecting admission when
        # every slot is occupied (session-adaptor use, where concurrency is
        # driven from outside); explicit-capacity callers keep the hard cap
        self.auto_grow = auto_grow
        self.kv = SlotKVManager(n_slots, max_len, kv_budget)
        self._memory = memory
        mem = None
        if memory is not None:
            mem = jnp.broadcast_to(memory, (n_slots,) + memory.shape[-2:])
        self.cache = split.middle_model.init_cache(
            split.middle_params, n_slots, max_len, memory=mem
        )
        self.queue: List[EngineJob] = []
        self.d_model = split.cfg.d_model
        self._step_fn = jax.jit(self._raw_step, static_argnames=("t_step",))
        self.steps = 0
        self.batched_token_history: List[int] = []

    # --------------------------------------------------------------- admit
    def add_request(self, req_id: int, expected_tokens: int) -> bool:
        if self.auto_grow and not self.kv.free_slots:
            self._grow_slots(self.n_slots + 1)
        if not self.kv.can_admit(expected_tokens):
            return False
        self.kv.admit(req_id, expected_tokens)
        return True

    def _grow_slots(self, min_slots: int) -> None:
        """Double the slot pool, carrying every live slot's cache rows over.

        The slot batch axis of every cache leaf is axis 1 (after the
        scan-repetition axis), so the old cache copies into the head of a
        freshly initialized larger one.  Each new batch width recompiles
        the jitted step once; doubling keeps that logarithmic."""
        new_n = max(self.n_slots * 2, min_slots)
        mem = None
        if self._memory is not None:
            mem = jnp.broadcast_to(
                self._memory, (new_n,) + self._memory.shape[-2:]
            )
        new_cache = self.split.middle_model.init_cache(
            self.split.middle_params, new_n, self.max_len, memory=mem
        )
        self.cache = jax.tree.map(
            lambda new, old: new.at[:, : old.shape[1]].set(old),
            new_cache, self.cache,
        )
        self.n_slots = new_n
        self.kv.grow(new_n)

    def finish_request(self, req_id: int) -> None:
        self.kv.release(req_id)

    def submit(self, job: EngineJob) -> None:
        assert job.req_id in self.kv.slot_of, "request not admitted"
        if job.offset < 0 or job.offset + len(job.hidden) > self.max_len:
            # previously this scribbled past the slot cache silently (XLA
            # clamps dynamic-update-slice indices): fail loudly instead and
            # free the capacity the broken request held
            self.queue = [j for j in self.queue if j.req_id != job.req_id]
            self.kv.release(job.req_id)
            raise EngineOverflowError(
                job.req_id, job.offset, len(job.hidden), self.max_len
            )
        self.queue.append(job)

    # ---------------------------------------------------------------- wire
    def submit_frame(self, data: bytes) -> None:
        """Decode one serialized chunk frame (repro.wire) and enqueue it.

        The frame names its own codec, so a fleet of devices may mix
        uplink codecs against one engine."""
        frame = Frame.from_bytes(data) if isinstance(data, (bytes, bytearray)) else data
        if frame.kind == KIND_DEEP:
            raise ValueError("deep frames flow cloud->device, not into the engine")
        self.wire_bytes_in += frame.nbytes()
        hidden = decode_hidden(frame, self.d_model)
        self.submit(EngineJob(frame.req_id, hidden, frame.offset,
                              frame.kind_name, want_deep=frame.want_deep))

    def encode_result(self, res: EngineResult) -> bytes:
        """Serialize a step result's deep hidden states for the downlink."""
        assert res.deep is not None, "result carries no deep states"
        data = encode_hidden(self.codec, res.deep, req_id=res.req_id,
                             offset=res.offset, kind="deep", want_deep=False)
        self.wire_bytes_out += len(data)
        return data

    # ---------------------------------------------------------------- step
    def _raw_step(self, params, cache, hidden, offsets, mask, t_step: int):
        deep, new_cache, _ = self.split.middle_model.apply(
            params, None, inputs_embeds=hidden, cache=cache, offset=offsets,
        )
        # the model writes cache rows for EVERY batch slot — including idle
        # ones, whose zero-input activations would scribble over other
        # sessions' KV entries (and advance their recurrent state) at the
        # leftover offset.  Keep the old cache for slots without a job in
        # this batch.  [reps, n_slots, ...] leaves: mask broadcasts on axis 1.
        def keep_active(new, old):
            m = mask.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)

        return deep, jax.tree.map(keep_active, new_cache, cache)

    def step(self) -> List[EngineResult]:
        """One engine iteration: admit jobs under the token budget, run the
        middle submodel once, return deep hidden states per job."""
        if not self.queue:
            return []
        # --- budgeted admission: verifies first, then prefill chunks -------
        budget = self.max_batch_tokens
        chosen: List[EngineJob] = []
        busy_slots = set()
        for job in sorted(self.queue, key=lambda j: 0 if j.kind == "verify" else 1):
            t = len(job.hidden)
            slot = self.kv.slot_of[job.req_id]
            if slot in busy_slots or (chosen and t > budget):
                continue
            chosen.append(job)
            busy_slots.add(slot)
            budget -= t
            if budget <= 0:
                break
        chosen_ids = {id(j) for j in chosen}
        self.queue = [j for j in self.queue if id(j) not in chosen_ids]

        t_step = max(len(j.hidden) for j in chosen)
        B = self.n_slots
        hidden = np.zeros((B, t_step, self.d_model), np.float32)
        offsets = np.zeros((B,), np.int32)
        mask = np.zeros((B,), bool)
        for j in chosen:
            slot = self.kv.slot_of[j.req_id]
            hidden[slot, : len(j.hidden)] = j.hidden
            offsets[slot] = j.offset
            mask[slot] = True
            self.kv.extend(j.req_id, j.offset + len(j.hidden))

        deep, self.cache = self._step_fn(
            self.split.middle_params, self.cache,
            jnp.asarray(hidden), jnp.asarray(offsets), jnp.asarray(mask),
            t_step=t_step,
        )
        deep = np.asarray(deep)
        self.steps += 1
        self.batched_token_history.append(sum(len(j.hidden) for j in chosen))

        out = []
        for j in chosen:
            slot = self.kv.slot_of[j.req_id]
            d = deep[slot, : len(j.hidden)] if j.want_deep else None
            out.append(EngineResult(j.req_id, d, j.kind, offset=j.offset))
        return out

    def drain(self) -> List[EngineResult]:
        res = []
        while self.queue:
            res.extend(self.step())
        return res

    # ---------------------------------------------------- SSM slot rollback
    # Attention slots roll back *positionally* (the next job overwrites the
    # rejected cache rows), but recurrent layers (mamba2/mlstm/slstm) carry
    # state, not positions: speculative rollback needs the pre-verification
    # state back.  These two methods give the cloud side of the session
    # protocol a per-slot snapshot/restore, mirroring
    # core.speculative.{snapshot,restore}_states at batch granularity.

    def snapshot_slot(self, req_id: int):
        """Copy the recurrent-state pieces of one request's slot.

        State subtrees live under keys ``m2``/``ml``/``sl`` of each layer's
        cache piece, with shape [reps, n_slots, ...] — the slot's batch row
        sits on axis 1, after the scan-repetition axis."""
        slot = self.kv.slot_of[req_id]
        snap = []
        for g in self.cache["groups"]:
            snap.append({
                lk: {k: jax.tree.map(lambda a: a[:, slot], piece[k])
                     for k in SSM_STATE_KEYS if k in piece}
                for lk, piece in g.items()
            })
        return snap

    def restore_slot(self, req_id: int, snap) -> None:
        """Overwrite one slot's recurrent-state pieces from a snapshot."""
        slot = self.kv.slot_of[req_id]
        new_groups = []
        for g, sg in zip(self.cache["groups"], snap):
            ng = {}
            for lk, piece in g.items():
                np_ = dict(piece)
                for k, v in sg.get(lk, {}).items():
                    np_[k] = jax.tree.map(
                        lambda a, s: a.at[:, slot].set(s), np_[k], v
                    )
                ng[lk] = np_
            new_groups.append(ng)
        self.cache = {"groups": new_groups}
