from .backends import RealBackend
from .delay_models import CloudDelayModel, DeviceProfile, NetworkModel, make_fleet
from .engine import CloudEngine, EngineJob, EngineResult
from .kv_manager import KVBudget, SlotKVManager
from .medusa import init_medusa, medusa_logits, medusa_loss, medusa_param_count
from .request import FleetMetrics, Phase, Request
from .simulator import FRAMEWORKS, SimConfig, Simulator, StatisticalBackend, run_fleet

__all__ = [
    "RealBackend", "CloudDelayModel", "DeviceProfile", "NetworkModel",
    "make_fleet", "CloudEngine", "EngineJob", "EngineResult", "KVBudget",
    "SlotKVManager", "init_medusa", "medusa_logits", "medusa_loss",
    "medusa_param_count", "FleetMetrics", "Phase", "Request",
    "FRAMEWORKS", "SimConfig", "Simulator", "StatisticalBackend", "run_fleet",
]
