from .api import (
    CloudServer,
    DelayModelTransport,
    DeviceClient,
    EngineRuntime,
    LoopbackTransport,
    Runtime,
    ServeConfig,
    SimulatorRuntime,
    Transport,
    run_fleet,
)
from .backends import RealBackend
from .delay_models import CloudDelayModel, DeviceProfile, NetworkModel, make_fleet
from .engine import CloudEngine, EngineJob, EngineOverflowError, EngineResult
from .kv_manager import KVBudget, SlotKVManager
from .medusa import init_medusa, medusa_logits, medusa_loss, medusa_param_count
from .request import FleetMetrics, Phase, Request
from .simulator import FRAMEWORKS, SimConfig, Simulator, StatisticalBackend

__all__ = [
    "CloudServer", "DelayModelTransport", "DeviceClient", "EngineRuntime",
    "LoopbackTransport", "Runtime", "ServeConfig", "SimulatorRuntime",
    "Transport", "run_fleet",
    "RealBackend", "CloudDelayModel", "DeviceProfile", "NetworkModel",
    "make_fleet", "CloudEngine", "EngineJob", "EngineOverflowError",
    "EngineResult", "KVBudget", "SlotKVManager", "init_medusa",
    "medusa_logits", "medusa_loss", "medusa_param_count", "FleetMetrics",
    "Phase", "Request", "FRAMEWORKS", "SimConfig", "Simulator",
    "StatisticalBackend",
]
