"""Request lifecycle and per-request metrics (TTFT / TBT / SLA)."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

# phase order of the TTFT breakdown tables (repro.obs.PHASES re-exported
# here to keep this module import-light)
TTFT_PHASES = ("draft", "uplink", "queue", "cloud_step", "downlink")


class Phase(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    device_id: int
    arrival_s: float
    prompt_len: int
    max_new_tokens: int
    prompt: Optional[np.ndarray] = None

    phase: Phase = Phase.WAITING
    prefilled: int = 0                       # prompt tokens processed so far
    chunk_sizes: List[int] = field(default_factory=list)
    chunk_idx: int = 0
    generated: List[int] = field(default_factory=list)

    # --- timing ------------------------------------------------------------
    first_token_s: Optional[float] = None    # absolute time of first token
    token_times_s: List[float] = field(default_factory=list)
    done_s: Optional[float] = None
    # per-phase TTFT attribution (seconds), filled from the flight recorder
    # on traced runs: {draft, uplink, queue, cloud_step, downlink} -> s;
    # on the instrumented runtimes the values sum to ttft_s
    phase_ttft_s: Optional[Dict[str, float]] = None

    # --- speculative-decoding stats -----------------------------------------
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0

    # --- fault tolerance ----------------------------------------------------
    # the session was lost mid-stream (SessionLostError): `generated` holds
    # the partial token stream the device salvaged before giving up
    degraded: bool = False

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tbt_s(self) -> Optional[float]:
        """Mean time between consecutive output tokens."""
        if len(self.token_times_s) < 2:
            return None
        ts = np.asarray(self.token_times_s)
        return float(np.diff(ts).mean())

    @property
    def accept_length(self) -> Optional[float]:
        """Mean accepted draft tokens per verification round (Table 4)."""
        if self.rounds == 0:
            return None
        return self.accepted / self.rounds

    def emit_tokens(self, tokens: List[int], now: float) -> None:
        for t in tokens:
            if self.first_token_s is None:
                self.first_token_s = now
            self.token_times_s.append(now)
            self.generated.append(int(t))
        if len(self.generated) >= self.max_new_tokens:
            self.phase = Phase.DONE
            self.done_s = now


@dataclass
class FleetMetrics:
    """Aggregates over completed requests (paper Figs. 6–12)."""

    requests: List[Request] = field(default_factory=list)
    cloud_step_delays_s: List[float] = field(default_factory=list)
    # engine/cloud utilization: batched tokens of every cloud step (filled
    # by the simulator's batch loop and by EngineRuntime from the engine's
    # step history) + the engine's jit compile count (0 for the simulator)
    cloud_batch_tokens: List[int] = field(default_factory=list)
    engine_jit_compiles: int = 0
    # fault tolerance: connection recoveries observed by the transport(s)
    # that served these requests (0 on loopback / fault-free runs)
    reconnects: int = 0
    replayed_frames: int = 0

    def add(self, r: Request) -> None:
        self.requests.append(r)

    def record_transport(self, transport) -> None:
        """Fold a transport's fault counters in (no-op for transports
        without them, e.g. loopback)."""
        self.reconnects += int(getattr(transport, "reconnects", 0))
        self.replayed_frames += int(getattr(transport, "replayed_frames", 0))

    def ttft(self) -> np.ndarray:
        return np.asarray([r.ttft_s for r in self.requests if r.ttft_s is not None])

    def tbt(self) -> np.ndarray:
        return np.asarray([r.tbt_s for r in self.requests if r.tbt_s is not None])

    def accept_length(self) -> float:
        rounds = sum(r.rounds for r in self.requests)
        acc = sum(r.accepted for r in self.requests)
        return acc / max(rounds, 1)

    def prefill_sla_rate(self, sla_s_per_128: float) -> float:
        """Fraction of requests whose TTFT meets the per-128-prompt-token SLA."""
        ok = tot = 0
        for r in self.requests:
            if r.ttft_s is None:
                continue
            budget = sla_s_per_128 * max(r.prompt_len / 128.0, 1.0)
            ok += r.ttft_s <= budget
            tot += 1
        return ok / max(tot, 1)

    def decode_sla_rate(self, sla_s_per_10: float) -> float:
        """Fraction of requests generating every 10 tokens within the SLA."""
        ok = tot = 0
        for r in self.requests:
            ts = r.token_times_s
            if len(ts) < 11:
                continue
            spans = [ts[i + 10] - ts[i] for i in range(len(ts) - 10)]
            ok += max(spans) <= sla_s_per_10
            tot += 1
        return ok / max(tot, 1)

    def summary(self) -> dict:
        ttft, tbt = self.ttft(), self.tbt()
        out = {
            "n": len(self.requests),
            "ttft_mean_ms": float(ttft.mean() * 1e3) if len(ttft) else None,
            "ttft_p90_ms": float(np.percentile(ttft, 90) * 1e3) if len(ttft) else None,
            "tbt_mean_ms": float(tbt.mean() * 1e3) if len(tbt) else None,
            "tbt_p90_ms": float(np.percentile(tbt, 90) * 1e3) if len(tbt) else None,
            "accept_length": self.accept_length(),
        }
        # always present (0.0 when no cloud steps ran) so callers never need
        # defensive .get() fallbacks
        if self.cloud_step_delays_s:
            d = np.asarray(self.cloud_step_delays_s)
            out["cloud_delay_mean_ms"] = float(d.mean() * 1e3)
            out["cloud_delay_std_ms"] = float(d.std() * 1e3)
        else:
            out["cloud_delay_mean_ms"] = 0.0
            out["cloud_delay_std_ms"] = 0.0
        # batching efficiency, observable from every runtime: how many
        # tokens each cloud step actually carried, how many steps ran, and
        # how many step variants the engine had to compile (0 = simulator)
        bt = self.cloud_batch_tokens
        out["cloud_steps"] = len(bt)
        out["batch_tokens_per_step_mean"] = (
            float(np.mean(bt)) if bt else 0.0
        )
        out["engine_jit_compiles"] = int(self.engine_jit_compiles)
        # fault tolerance: always present (all zero on a fault-free run)
        out["reconnects"] = int(self.reconnects)
        out["replayed_frames"] = int(self.replayed_frames)
        out["requests_degraded"] = sum(1 for r in self.requests if r.degraded)
        # per-phase TTFT attribution: mean over traced requests, in ms,
        # keyed in pipeline order (only present when a flight recorder ran)
        traced = [r.phase_ttft_s for r in self.requests
                  if r.phase_ttft_s is not None]
        if traced:
            out["ttft_breakdown_ms"] = {
                p: float(np.mean([b.get(p, 0.0) for b in traced]) * 1e3)
                for p in TTFT_PHASES
            }
        return out
