"""Discrete-event device-cloud simulator (30 Jetson-class devices + cloud).

This is the testbed stand-in (DESIGN.md §3): all *algorithmic* components —
threshold drafting, verification/acceptance, Eq. 3 chunk sizing, Eq. 6
parallel drafting, EWMA monitoring, continuous batching with a token budget
— are the real repro.core implementations; wall-clock is advanced by the
calibrated delay models (delay_models.py), since the container has no
Jetson fleet or WiFi.  A ``Backend`` supplies token-level outcomes: the
``StatisticalBackend`` samples accept lengths (fleet-scale sweeps, Figs.
6–12); the ``RealBackend`` (backends.py) runs actual JAX models (Table 4/5).

Framework variants (paper baselines) are flag combinations:
    U-shape    : sd=False, pc=False, pd=False
    U-Sarathi  : sd=False, pc="server" (fixed chunks, no overlap)
    U-Medusa   : sd="medusa", pc=False, pd=False
    HAT        : sd="draft", pc="device" (dynamic chunks, overlap), pd=True
"""
from __future__ import annotations

import heapq
import itertools
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from ..core.chunking import chunk_prompt, plan_chunks
from ..core.monitor import StateMonitor
from ..core.parallel_draft import parallel_draft_steps
from ..obs import NULL_TRACER, TID_CLOUD, Tracer
from ..wire import get_codec
from .delay_models import CloudDelayModel, DeviceProfile, NetworkModel, make_fleet
from .request import FleetMetrics, Phase, Request
from .scheduling import budgeted_admission


# ---------------------------------------------------------------------------
# backends: token-level outcomes
# ---------------------------------------------------------------------------


class StatisticalBackend:
    """Samples draft/accept outcomes from calibrated distributions.

    Defaults tuned to reproduce Table 4: HAT accept ≈ 2.06 (incl. bonus),
    U-Medusa ≈ 1.89, with threshold drafting of mean ≈ 3 steps."""

    def __init__(self, rng: np.random.Generator, *, p_accept: float = 0.55,
                 medusa_p: float = 0.48, mean_draft: float = 3.0,
                 max_draft: int = 8, pd_hit: float = 0.55,
                 wire_penalty: float = 0.0):
        self.rng = rng
        self.p_accept = p_accept
        self.medusa_p = medusa_p
        self.mean_draft = mean_draft
        self.max_draft = max_draft
        self.pd_hit = pd_hit
        # lossy wire codecs perturb the verification logits: a calibrated
        # multiplicative hit on every accept draw (repro.wire.codec docs)
        self.wire_penalty = wire_penalty

    def set_wire_codec(self, codec) -> None:
        self.wire_penalty = codec.accept_penalty

    def first_token(self, req: Request) -> int:
        return 1000

    def draft(self, req: Request, max_draft: int) -> List[int]:
        # threshold stopping yields a geometric-ish draft length
        q = 1.0 / self.mean_draft
        k = 1 + int(self.rng.geometric(q)) - 1
        k = int(np.clip(k, 1, min(max_draft, self.max_draft)))
        return [1000 + i for i in range(k)]

    def verify(self, req: Request, draft: List[int]) -> Tuple[int, int]:
        p = self.p_accept * (1.0 - self.wire_penalty)
        n = 0
        while n < len(draft) and self.rng.random() < p:
            n += 1
        return n, 2000

    def medusa_tree(self, req: Request) -> int:
        return 8                                    # tree size (paper: 8)

    def medusa_verify(self, req: Request) -> Tuple[int, int]:
        p = self.medusa_p * (1.0 - self.wire_penalty)
        n = 0
        while n < 4 and self.rng.random() < p:
            n += 1
        return n, 2000

    def parallel_draft_hit(self, req: Request) -> bool:
        return self.rng.random() < self.pd_hit


# ---------------------------------------------------------------------------
# cloud jobs / batching
# ---------------------------------------------------------------------------


@dataclass
class Job:
    req: Request
    dev: DeviceProfile
    kind: str                  # "prefill" | "verify"
    tokens: int                # batched token size contribution
    on_done: Callable          # (finish_time) -> None
    on_stage: Optional[Callable] = None   # (stage_clear_time) -> None
    seq: int = 0
    t_enqueue: float = 0.0     # when the job entered the cloud queue


@dataclass
class SimConfig:
    sd: Optional[str] = "draft"        # None | "draft" | "medusa"
    pc: Optional[str] = "device"       # None | "device" (HAT) | "server" (Sarathi)
    pd: bool = True
    fixed_chunk: int = 128             # U-Sarathi chunk size
    dynamic_chunks: bool = True        # HAT: Eq. 3; else fixed_chunk
    eta: float = 0.6                   # draft threshold (Eq. 5)
    max_draft: int = 8
    topk: int = 4
    # --- wire transport -----------------------------------------------------
    # A = bytes/token on the wire is codec-derived: hidden_bytes_per_token
    # left at None resolves to get_codec(wire_codec).bytes_per_token(d_model)
    # (fp16 × 4096 = the paper's 8 KiB anchor); setting it explicitly
    # overrides the codec accounting (legacy knob).
    wire_codec: str = "fp16"
    d_model: int = 4096                # vicuna-7b
    hidden_bytes_per_token: Optional[float] = None
    token_bytes: float = 4.0
    # fixed link rates (bytes/s) for controlled codec × bandwidth sweeps
    uplink_bps: Optional[float] = None
    downlink_bps: Optional[float] = None
    # Cloud admission: Sarathi/HAT cap batched tokens; the naive baselines
    # (U-shape, U-Medusa) batch every pending job -> long prompts interfere
    # with decode (Fig. 1(c)); None = no budget.
    max_batch_tokens: Optional[int] = 512
    # Device uplink window (matches DeviceClient.pipeline_depth): 0 =
    # unbounded streaming (legacy behavior), 1 = strictly sequential
    # (each chunk waits for the previous chunk's cloud processing), D>1 =
    # at most D unprocessed chunks in flight.
    pipeline_depth: int = 0
    max_sim_s: float = 3600.0

    def __post_init__(self):
        if self.hidden_bytes_per_token is None:
            self.hidden_bytes_per_token = get_codec(
                self.wire_codec
            ).bytes_per_token(self.d_model)


class Simulator:
    def __init__(
        self,
        sim_cfg: SimConfig,
        cloud: CloudDelayModel,
        backend,
        rng: np.random.Generator,
        n_devices: int = 30,
        tracer: Optional[Tracer] = None,
    ):
        self.cfg = sim_cfg
        self.cloud = cloud
        self.backend = backend
        self.rng = rng
        # flight recorder (repro.obs).  The simulator feeds its monitor
        # directly (its zero-duration transfer convention predates the
        # StateMonitorBridge) — pass a tracer WITHOUT a monitor bridge here
        # or every hop would be counted twice.
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.fleet = {d.dev_id: d for d in make_fleet(rng, n_devices)}
        self.net = NetworkModel(rng, up_fixed=sim_cfg.uplink_bps,
                                down_fixed=sim_cfg.downlink_bps)
        self.monitor = StateMonitor(alpha=0.8)
        self.metrics = FleetMetrics()

        self._pq: List = []
        self._seq = itertools.count()
        self.now = 0.0

        # cloud state
        self.jobs: List[Job] = []
        self.cloud_free_at = 0.0
        self.cloud_scheduled = False
        # per-device link/compute availability
        self.up_free = {i: 0.0 for i in self.fleet}
        self.down_free = {i: 0.0 for i in self.fleet}
        self.dev_free = {i: 0.0 for i in self.fleet}
        # per-request in-flight chunk gating
        self._chunks_ready: Dict[int, int] = {}
        self._chunks_done: Dict[int, int] = {}
        self._chunks_computed: Dict[int, int] = {}
        self._chunks_sent: Dict[int, int] = {}

    # ------------------------------------------------------------ event core
    def at(self, t: float, fn: Callable) -> None:
        heapq.heappush(self._pq, (max(t, self.now), next(self._seq), fn))

    def run(self) -> FleetMetrics:
        while self._pq:
            t, _, fn = heapq.heappop(self._pq)
            self.now = t
            if t > self.cfg.max_sim_s:
                break
            fn()
        return self.metrics

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        self.at(req.arrival_s, lambda: self._start_request(req))

    def _start_request(self, req: Request) -> None:
        dev = self.fleet[req.device_id]
        dev.maybe_rotate_mode()
        req.phase = Phase.PREFILL
        A = self.cfg.hidden_bytes_per_token

        req.chunk_sizes = plan_chunks(
            req.prompt_len,
            pc=self.cfg.pc,
            dynamic_chunks=self.cfg.dynamic_chunks,
            fixed_chunk=self.cfg.fixed_chunk,
            hidden_bytes_per_token=A,
            beta_up=self.monitor.device(dev.dev_id).beta_up.get(7.5e6),
            g=self.monitor.g.predict,
            mu=self.monitor.mu.get(64.0),
            pipeline_len=self.cloud.pipeline_len,
            pipeline_depth=self.cfg.pipeline_depth,
        )
        self._chunks_done[req.req_id] = 0
        if self.cfg.pc == "device":
            self._chunks_ready[req.req_id] = 0
            self._chunks_computed[req.req_id] = 0
            self._chunks_sent[req.req_id] = 0
            self._device_compute_chunk(req, dev, 0)
        else:
            # pc="server" (Sarathi): whole prompt's hidden states uploaded
            # once, the CLOUD chunks them across inference steps (no
            # transmission overlap).  pc=None (plain U-shape): one bulk
            # upload, one bulk prefill job.
            self._chunks_ready[req.req_id] = len(req.chunk_sizes)
            comp = dev.shallow_delay(req.prompt_len)
            start = max(self.now, self.dev_free[dev.dev_id])
            t0 = start + comp
            self.dev_free[dev.dev_id] = t0
            self.tracer.add_span(
                "shallow", start, t0, tid=req.req_id, phase="draft",
                dev_id=dev.dev_id, tokens=req.prompt_len,
            )
            self._upload(req, dev, req.prompt_len * A, t0,
                         lambda ft: self._enqueue_next_chunk(req, dev))

    # --- HAT device-side pipelined chunk prefill -----------------------------
    def _device_compute_chunk(self, req: Request, dev: DeviceProfile, ci: int) -> None:
        size = req.chunk_sizes[ci]
        start = max(self.now, self.dev_free[dev.dev_id])
        done = start + dev.shallow_delay(size)
        self.dev_free[dev.dev_id] = done
        self.tracer.add_span(
            "shallow", start, done, tid=req.req_id, phase="draft",
            dev_id=dev.dev_id, tokens=size, chunk=ci,
        )

        def after_compute():
            self._chunks_computed[req.req_id] += 1
            self._pump_uplink(req, dev)
            if ci + 1 < len(req.chunk_sizes):
                self._device_compute_chunk(req, dev, ci + 1)  # overlap

        self.at(done, after_compute)

    def _pump_uplink(self, req: Request, dev: DeviceProfile) -> None:
        """Start uploads for computed chunks the in-flight window admits.

        With ``pipeline_depth=0`` every computed chunk uploads immediately
        (unbounded streaming — the legacy behavior); with depth D the
        sender holds chunk i until chunk i-D has been *processed*, the
        same bounded window ``DeviceClient`` enforces via frame acks."""
        A = self.cfg.hidden_bytes_per_token
        depth = self.cfg.pipeline_depth
        rid = req.req_id
        while self._chunks_sent[rid] < self._chunks_computed[rid]:
            if depth > 0 and self._chunks_sent[rid] - self._chunks_done[rid] >= depth:
                return        # window full; resumes when a chunk is processed
            size = req.chunk_sizes[self._chunks_sent[rid]]
            self._chunks_sent[rid] += 1
            self._upload(req, dev, size * A, self.now,
                         lambda ft: self._chunk_uploaded(req, dev))

    def _chunk_uploaded(self, req: Request, dev: DeviceProfile) -> None:
        self._chunks_ready[req.req_id] += 1
        self._enqueue_next_chunk(req, dev)

    def _enqueue_next_chunk(self, req: Request, dev: DeviceProfile) -> None:
        """Admit the next prefill chunk iff the previous one finished (chunks
        of one request are sequentially dependent through the KV cache)."""
        done = self._chunks_done[req.req_id]
        if done >= len(req.chunk_sizes):
            return
        if self._chunks_ready[req.req_id] <= done:
            return                                    # not uploaded yet
        if getattr(req, "_chunk_inflight", False):
            return
        req._chunk_inflight = True
        size = req.chunk_sizes[done]
        ci = done

        def on_stage(st):
            # pipeline-parallel cloud: the next chunk may enter stage 1 as
            # soon as this chunk clears it — the KV dependency is per-stage,
            # not end-to-end (this is what makes Eq. 3's /P overlap real)
            req._chunk_inflight = False
            self._chunks_done[req.req_id] += 1
            req.prefilled += size
            if self.cfg.pc == "device":
                self._pump_uplink(req, dev)   # release the uplink window
            if self._chunks_done[req.req_id] < len(req.chunk_sizes):
                self._enqueue_next_chunk(req, dev)

        def on_done(ft):
            if self._chunks_done[req.req_id] == len(req.chunk_sizes) and ci == len(req.chunk_sizes) - 1:
                self._finish_prefill(req, dev, ft)

        self._push_job(Job(req, dev, "prefill", size, on_done, on_stage))

    def _finish_prefill(self, req: Request, dev: DeviceProfile, t: float) -> None:
        """Last chunk computed in cloud: deep hidden of the final position
        returns to the device, head emits the first token."""
        A = self.cfg.hidden_bytes_per_token

        def after_down(ft):
            t1 = ft + dev.head_delay()
            self.tracer.add_span(
                "head", ft, t1, tid=req.req_id, phase="draft",
                dev_id=dev.dev_id,
            )

            def emit():
                tok = self.backend.first_token(req)
                req.emit_tokens([tok], self.now)
                req.phase = Phase.DECODE
                if req.phase != Phase.DONE and len(req.generated) < req.max_new_tokens:
                    self._decode_round(req, dev)
                else:
                    self._complete(req)

            self.at(t1, emit)

        self._download(req, dev, A, t, after_down)

    # ------------------------------------------------------------- decoding
    def _decode_round(self, req: Request, dev: DeviceProfile) -> None:
        cfg = self.cfg
        A = cfg.hidden_bytes_per_token

        if cfg.sd == "medusa":
            tree = self.backend.medusa_tree(req)
            comp = dev.shallow_delay(tree) + dev.head_delay() * 4
            start = max(self.now, self.dev_free[dev.dev_id])
            t0 = start + comp
            self.dev_free[dev.dev_id] = t0
            self.tracer.add_span(
                "device", start, t0, tid=req.req_id, phase="draft",
                dev_id=dev.dev_id, tokens=tree,
            )
            self._upload(req, dev, tree * A, t0,
                         lambda ft: self._verify_job(req, dev, tree, medusa=True))
            return

        if cfg.sd == "draft":
            draft = self.backend.draft(req, cfg.max_draft)
            k = len(draft)
            pd_hit = cfg.pd and req.rounds > 0 and self.backend.parallel_draft_hit(req)
            draft_time = 0.0 if pd_hit else dev.draft_delay(k)
            comp = draft_time + dev.shallow_delay(k + 1)
            start = max(self.now, self.dev_free[dev.dev_id])
            t0 = start + comp
            self.dev_free[dev.dev_id] = t0
            req._draft = draft
            self.tracer.add_span(
                "draft", start, t0, tid=req.req_id, phase="draft",
                dev_id=dev.dev_id, steps=k, pd_hit=pd_hit,
            )
            # report device state to the monitor (piggybacked, §3.2)
            self.monitor.record_device(dev.dev_id, gamma=dev.draft_delay(1))
            self._upload(req, dev, (k + 1) * A, t0,
                         lambda ft: self._verify_job(req, dev, k + 1, medusa=False))
            return

        # plain U-shape: verify exactly one token per round
        comp = dev.shallow_delay(1)
        start = max(self.now, self.dev_free[dev.dev_id])
        t0 = start + comp
        self.dev_free[dev.dev_id] = t0
        self.tracer.add_span(
            "device", start, t0, tid=req.req_id, phase="draft",
            dev_id=dev.dev_id, tokens=1,
        )
        self._upload(req, dev, A, t0,
                     lambda ft: self._verify_job(req, dev, 1, medusa=False))

    def _verify_job(self, req: Request, dev: DeviceProfile, tokens: int, medusa: bool):
        def on_done(ft):
            A = self.cfg.hidden_bytes_per_token

            def after_down(ft2):
                t1 = ft2 + dev.head_delay()
                self.tracer.add_span(
                    "head", ft2, t1, tid=req.req_id, phase="draft",
                    dev_id=dev.dev_id,
                )
                self.at(t1, lambda: self._accept(req, dev, medusa))

            self._download(req, dev, tokens * A, ft, after_down)

        self._push_job(Job(req, dev, "verify", tokens, on_done))

    def _accept(self, req: Request, dev: DeviceProfile, medusa: bool) -> None:
        # "accept length" (Table 4) counts tokens emitted per verification
        # round including the LLM's own (bonus) token -> U-shape == 1.00.
        if self.cfg.sd == "draft":
            draft = getattr(req, "_draft", [])
            n, bonus = self.backend.verify(req, draft)
            req.rounds += 1
            req.drafted += len(draft)
            emit = [*draft[:n], bonus]
        elif medusa:
            n, bonus = self.backend.medusa_verify(req)
            req.rounds += 1
            req.drafted += 4
            emit = [1000 + i for i in range(n)] + [bonus]
        else:
            req.rounds += 1
            emit = [self.backend.verify(req, [])[1]]
        req.accepted += len(emit)
        room = req.max_new_tokens - len(req.generated)
        req.emit_tokens(emit[:room], self.now)
        if req.phase == Phase.DONE:
            self._complete(req)
        else:
            self._decode_round(req, dev)

    def _complete(self, req: Request) -> None:
        req.phase = Phase.DONE
        req.done_s = self.now
        if self.tracer.enabled and req.first_token_s is not None:
            # phase attribution is approximate here: the simulator overlaps
            # chunk compute with uploads, so the phases can sum past TTFT
            # (overlap counted in both) — exact tiling is an EngineRuntime
            # guarantee, not a simulator one
            req.phase_ttft_s = self.tracer.phase_breakdown(
                req.req_id, until=req.first_token_s
            )
        self.metrics.add(req)
        # session-aware backends (the rebuilt RealBackend) hold per-request
        # device caches and a cloud engine slot — let them release both
        fin = getattr(self.backend, "finish_request", None)
        if fin is not None:
            fin(req.req_id)

    # ------------------------------------------------------------- transport
    def _upload(self, req, dev, nbytes, ready_t, cb) -> None:
        start = max(ready_t, self.up_free[dev.dev_id], self.now)
        dur = self.net.up_time(dev, nbytes)
        self.up_free[dev.dev_id] = start + dur
        self.monitor.record_device(dev.dev_id, beta_up=nbytes / dur if dur > 0 else 1e9)
        self.tracer.add_span(
            "uplink", start, start + dur, tid=req.req_id, phase="uplink",
            dev_id=dev.dev_id, nbytes=nbytes, dur_s=dur,
        )
        self.at(start + dur, lambda: cb(start + dur))

    def _download(self, req, dev, nbytes, ready_t, cb) -> None:
        start = max(ready_t, self.down_free[dev.dev_id], self.now)
        dur = self.net.down_time(dev, nbytes)
        self.down_free[dev.dev_id] = start + dur
        self.monitor.record_device(dev.dev_id, beta_down=nbytes / dur if dur > 0 else 1e9)
        self.tracer.add_span(
            "downlink", start, start + dur, tid=req.req_id, phase="downlink",
            dev_id=dev.dev_id, nbytes=nbytes, dur_s=dur,
        )
        self.at(start + dur, lambda: cb(start + dur))

    # ------------------------------------------------------------ cloud loop
    def _push_job(self, job: Job) -> None:
        job.t_enqueue = self.now
        self.jobs.append(job)
        self._maybe_run_batch()

    def _maybe_run_batch(self) -> None:
        if self.cloud_scheduled or not self.jobs:
            return
        self.cloud_scheduled = True
        start = max(self.now, self.cloud_free_at)
        self.at(start, self._run_batch)

    def _run_batch(self) -> None:
        self.cloud_scheduled = False
        if not self.jobs:
            return
        # Shared scheduler semantics (scheduling.py): with a token budget,
        # verifies (decode) first then prefill chunks fill the remainder
        # (Sarathi-style); an oversized job is admitted alone, not starved.
        # Without a budget, naive continuous batching (vLLM-style): long
        # prompts join decode batches and inflate every round in them
        # (Fig. 1(c) interference).  The real-tensor CloudEngine admits
        # through the same function.
        batch, self.jobs = budgeted_admission(
            self.jobs, self.cfg.max_batch_tokens, tokens_of=lambda j: j.tokens
        )

        tokens = sum(j.tokens for j in batch)
        full = self.cloud.delay(tokens)
        stage = self.cloud.stage_time(tokens)
        self.monitor.record_batch(tokens, full)
        self.metrics.cloud_step_delays_s.append(stage)
        self.metrics.cloud_batch_tokens.append(tokens)

        done_t = self.now + full
        self.tracer.add_span(
            "cloud_step", self.now, done_t, tid=TID_CLOUD,
            tokens=tokens, dur_s=full, jobs=len(batch),
        )
        for j in batch:
            if self.now > j.t_enqueue:
                self.tracer.add_span(
                    "queue_wait", j.t_enqueue, self.now,
                    tid=j.req.req_id, phase="queue", kind=j.kind,
                )
            self.tracer.add_span(
                "cloud_wait", self.now, done_t, tid=j.req.req_id,
                phase="cloud_step", kind=j.kind, tokens=j.tokens,
            )
        stage_t = self.now + stage
        # batch-level scheduling (naive baselines) cannot fully hide pipeline
        # bubbles: effective cadence ~2 stages (Sarathi-Serve's observation);
        # chunked/budgeted admission pipelines microbatches at 1-stage cadence
        bubble = 1.0 if self.cfg.max_batch_tokens is not None else 2.0
        self.cloud_free_at = self.now + min(bubble * stage, full)
        for j in batch:
            if j.on_stage is not None:
                self.at(stage_t, (lambda jj: (lambda: jj.on_stage(stage_t)))(j))
            self.at(done_t, (lambda jj: (lambda: jj.on_done(done_t)))(j))
        if self.jobs:
            self._maybe_run_batch()


# ---------------------------------------------------------------------------
# framework flag table (legacy)
# ---------------------------------------------------------------------------
#
# Kept as the canonical name list; the flag combinations themselves are now
# expressed by the typed ``ServeConfig`` constructors in ``serving.api``
# (``ServeConfig.hat()`` etc.), and ``run_fleet`` lives there as a thin
# deprecated wrapper.

FRAMEWORKS = {
    "u-shape": dict(sd=None, pc=None, pd=False, max_batch_tokens=None),
    "u-sarathi": dict(sd=None, pc="server", pd=False, dynamic_chunks=False),
    "u-medusa": dict(sd="medusa", pc=None, pd=False, max_batch_tokens=None),
    "hat": dict(sd="draft", pc="device", pd=True),
}
