"""Unified session API: DeviceClient / CloudServer / Transport.

HAT's core claim is a *protocol* — devices and cloud exchanging codec-framed
hidden states with chunked-prefill overlap — and this module is its single
front door, replacing the three ad-hoc serving paths (``run_fleet`` kwargs
soup, raw ``CloudEngine.submit``/``step`` with caller-side chunking, and
``RealBackend``'s inline re-implementation of the U path):

    DeviceClient ──frames──▶ Transport ──frames──▶ CloudServer ─▶ CloudEngine
        │  input submodel + Λ + head                  │  middle submodel,
        │  Eq. 3 chunked prefill,                     │  slot-batched steps,
        │  Eq. 5 threshold drafting,                  │  KV admission,
        │  greedy acceptance                          │  downlink encoding
        ◀──────────── deep-state frames ──────────────┘

* :class:`DeviceClient` owns the device-resident pieces (input submodel,
  adapter Λ, output head) and drives the whole decode loop as a
  **token-streaming generator**: ``client.generate(prompt)`` yields tokens.
  Every hidden-state hop is a serialized ``repro.wire`` frame — there is no
  bare-array side channel.
* :class:`CloudServer` wraps :class:`~repro.serving.engine.CloudEngine`
  behind frame ingress/egress plus a per-request downlink outbox, and
  exposes the SSM rollback control channel (slot snapshot/restore).
* :class:`Transport` is the small protocol between them.
  :class:`LoopbackTransport` is the in-process wire;
  :class:`DelayModelTransport` reuses ``delay_models.py`` so real-tensor
  runs get simulated wall-clock (link transfer times, cloud batch delays,
  device compute ticks).
* :class:`ServeConfig` is the typed run description with framework
  constructors (``ServeConfig.hat()``, ``.u_shape()``, ``.u_sarathi()``,
  ``.u_medusa()``) replacing the ``FRAMEWORKS`` dict + ``overrides`` kwargs.
  It resolves the wire codec vs. ``hidden_bytes_per_token`` precedence
  exactly once.
* :class:`Runtime` unifies the two execution engines behind
  ``serve(requests) -> FleetMetrics``: :class:`SimulatorRuntime` runs the
  discrete-event fleet simulator, :class:`EngineRuntime` runs real tensors
  through DeviceClient/CloudServer sessions.

``run_fleet`` remains as a thin deprecated wrapper over
``ServeConfig.from_framework`` + :class:`SimulatorRuntime`.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapter import DraftModel
from ..core.chunking import plan_chunks
from ..core.monitor import StateMonitor
from ..core.speculative import (
    accept_greedy_rows,
    draft_until_threshold,
    has_ssm_state,
    restore_states,
    snapshot_states,
)
from ..core.split import SplitModels
from ..wire import Frame, decode_hidden, encode_hidden, get_codec
from . import medusa as medusa_mod
from .delay_models import CloudDelayModel, DeviceProfile, NetworkModel, make_fleet
from .engine import CloudEngine, EngineOverflowError
from .request import FleetMetrics, Phase, Request
from .simulator import FRAMEWORKS, SimConfig, Simulator, StatisticalBackend

Params = Dict


# ---------------------------------------------------------------------------
# ServeConfig: the typed run description
# ---------------------------------------------------------------------------


@dataclass
class ServeConfig:
    """One serving run, fully described.

    Use the framework constructors — ``ServeConfig.hat()``,
    ``.u_shape()``, ``.u_sarathi()``, ``.u_medusa()`` — rather than spelling
    the flag combination by hand.  ``wire_codec=None`` means "nobody asked
    for a codec": byte accounting falls back to ``hidden_bytes_per_token``
    (or the fp16 default) and a backend's own codec configuration is left
    alone; a named codec switches accounting to codec-derived bytes and
    (re)configures the backend.
    """

    framework: str = "hat"
    # --- algorithm flags (simulator semantics) -----------------------------
    sd: Optional[str] = "draft"        # None | "draft" | "medusa"
    pc: Optional[str] = "device"       # None | "device" (HAT) | "server" (Sarathi)
    pd: bool = True
    fixed_chunk: int = 128
    dynamic_chunks: bool = True
    eta: float = 0.6
    max_draft: int = 8
    topk: int = 4
    # --- wire --------------------------------------------------------------
    wire_codec: Optional[str] = None   # None = legacy byte accounting
    d_model: int = 4096
    hidden_bytes_per_token: Optional[float] = None
    token_bytes: float = 4.0
    uplink_bps: Optional[float] = None
    downlink_bps: Optional[float] = None
    # --- cloud -------------------------------------------------------------
    max_batch_tokens: Optional[int] = 512
    pipeline_len: int = 4
    # --- fleet -------------------------------------------------------------
    n_devices: int = 30
    max_sim_s: float = 3600.0

    def __post_init__(self):
        if self.hidden_bytes_per_token is None:
            self.hidden_bytes_per_token = self.codec.bytes_per_token(self.d_model)

    # --------------------------------------------------------- codec facts
    @property
    def codec_name(self) -> str:
        return self.wire_codec or "fp16"

    @property
    def codec(self):
        return get_codec(self.codec_name)

    def configure_backend(self, backend) -> None:
        """Apply the run's wire codec to a backend — but only when a codec
        was actually requested.  A backend configured directly by its caller
        (``RealBackend(wire_codec=...)``, ``StatisticalBackend(
        wire_penalty=...)``) is never clobbered by the fp16 default."""
        if self.wire_codec is not None and hasattr(backend, "set_wire_codec"):
            backend.set_wire_codec(self.codec)

    def to_sim_config(self) -> SimConfig:
        return SimConfig(
            sd=self.sd, pc=self.pc, pd=self.pd,
            fixed_chunk=self.fixed_chunk, dynamic_chunks=self.dynamic_chunks,
            eta=self.eta, max_draft=self.max_draft, topk=self.topk,
            wire_codec=self.codec_name, d_model=self.d_model,
            hidden_bytes_per_token=self.hidden_bytes_per_token,
            token_bytes=self.token_bytes,
            uplink_bps=self.uplink_bps, downlink_bps=self.downlink_bps,
            max_batch_tokens=self.max_batch_tokens, max_sim_s=self.max_sim_s,
        )

    # --------------------------------------------- framework constructors
    @classmethod
    def _make(cls, name: str, defaults: dict, kw: dict) -> "ServeConfig":
        base = dict(defaults)
        base.update(kw)                    # explicit kwargs win (ablations)
        return cls(framework=name, **base)

    @classmethod
    def hat(cls, **kw) -> "ServeConfig":
        """HAT: threshold drafting + device-side dynamic chunking + parallel
        drafting + budgeted cloud batching."""
        return cls._make("hat", dict(sd="draft", pc="device", pd=True), kw)

    @classmethod
    def u_shape(cls, **kw) -> "ServeConfig":
        """Plain U-shaped inference: bulk upload, per-token decoding, naive
        (unbudgeted) cloud batching."""
        return cls._make(
            "u-shape", dict(sd=None, pc=None, pd=False, max_batch_tokens=None), kw
        )

    @classmethod
    def u_sarathi(cls, **kw) -> "ServeConfig":
        """U-shape + Sarathi-style server-side fixed chunks (no overlap)."""
        return cls._make(
            "u-sarathi",
            dict(sd=None, pc="server", pd=False, dynamic_chunks=False), kw,
        )

    @classmethod
    def u_medusa(cls, **kw) -> "ServeConfig":
        """U-shape + Medusa heads with tree verification."""
        return cls._make(
            "u-medusa",
            dict(sd="medusa", pc=None, pd=False, max_batch_tokens=None), kw,
        )

    @classmethod
    def from_framework(cls, name: str, **kw) -> "ServeConfig":
        ctor = {
            "hat": cls.hat, "u-shape": cls.u_shape,
            "u-sarathi": cls.u_sarathi, "u-medusa": cls.u_medusa,
        }.get(name)
        if ctor is None:
            raise KeyError(f"unknown framework {name!r}; known: {sorted(FRAMEWORKS)}")
        return ctor(**kw)


# ---------------------------------------------------------------------------
# CloudServer: the cloud side of the session protocol
# ---------------------------------------------------------------------------


class CloudServer:
    """Frame-speaking facade over :class:`CloudEngine`.

    Uplink frames enter through :meth:`handle_frame`; each :meth:`pump` runs
    one slot-batched engine step and routes the resulting deep-state frames
    into per-request outboxes for the transport to deliver.  The server also
    exposes the session lifecycle (open/close) and the SSM rollback control
    channel (:meth:`snapshot_session` / :meth:`restore_session`)."""

    def __init__(
        self,
        split: SplitModels,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        max_batch_tokens: int = 256,
        wire_codec: str = "fp16",
        kv_budget=None,
        memory: Optional[jax.Array] = None,
        auto_grow: bool = False,
    ):
        self.engine = CloudEngine(
            split, n_slots=n_slots, max_len=max_len,
            max_batch_tokens=max_batch_tokens, kv_budget=kv_budget,
            memory=memory, wire_codec=wire_codec, auto_grow=auto_grow,
        )
        self._outbox: Dict[int, deque] = {}

    @property
    def d_model(self) -> int:
        return self.engine.d_model

    # ------------------------------------------------------------ sessions
    def open_session(self, req_id: int, expected_tokens: int) -> bool:
        return self.engine.add_request(req_id, expected_tokens)

    def close_session(self, req_id: int) -> None:
        self._outbox.pop(req_id, None)
        self.engine.queue = [j for j in self.engine.queue if j.req_id != req_id]
        if req_id in self.engine.kv.slot_of:
            self.engine.finish_request(req_id)

    # -------------------------------------------------------------- frames
    def handle_frame(self, data: bytes) -> None:
        """Uplink ingress: decode + enqueue one chunk frame."""
        try:
            self.engine.submit_frame(data)
        except EngineOverflowError as e:
            self._outbox.pop(e.req_id, None)
            raise

    def pump(self) -> int:
        """One engine step; returns the batched token count (0 = idle).

        Deep-state results are encoded with the engine's downlink codec and
        parked in the owning request's outbox."""
        results = self.engine.step()
        if not results:
            return 0
        for r in results:
            if r.deep is not None:
                self._outbox.setdefault(r.req_id, deque()).append(
                    self.engine.encode_result(r)
                )
        return self.engine.batched_token_history[-1]

    def poll(self, req_id: int) -> Optional[bytes]:
        """Pop the next downlink frame for ``req_id`` (None = none pending)."""
        q = self._outbox.get(req_id)
        return q.popleft() if q else None

    # ----------------------------------------------------- control channel
    def snapshot_session(self, req_id: int):
        return self.engine.snapshot_slot(req_id)

    def restore_session(self, req_id: int, snap) -> None:
        self.engine.restore_slot(req_id, snap)


# ---------------------------------------------------------------------------
# Transport: the small device<->cloud protocol
# ---------------------------------------------------------------------------


class Transport:
    """The device's handle on the cloud.

    Data plane: ``send`` pushes an uplink chunk frame; ``recv`` blocks until
    the next downlink (deep-state) frame for the request is available.
    Session plane: ``open`` / ``close``.  Control plane: ``snapshot`` /
    ``restore`` implement speculative rollback of cloud-resident recurrent
    state (SSM middles; attention middles roll back positionally and never
    call these).  ``tick`` lets the device report local compute time to
    transports that keep a clock."""

    def open(self, req_id: int, expected_tokens: int) -> None:
        raise NotImplementedError

    def close(self, req_id: int) -> None:
        raise NotImplementedError

    def send(self, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, req_id: int) -> bytes:
        raise NotImplementedError

    def snapshot(self, req_id: int):
        raise NotImplementedError

    def restore(self, req_id: int, snap) -> None:
        raise NotImplementedError

    def tick(self, seconds: float) -> None:
        pass


class LoopbackTransport(Transport):
    """In-process wire: frames go straight into the server, ``recv`` pumps
    the engine until the request's downlink frame materializes.  Zero
    latency — the timing-free transport for parity tests and the rebuilt
    ``RealBackend`` (the simulator owns the clock there)."""

    def __init__(self, server: CloudServer):
        self.server = server
        self.bytes_up = 0
        self.bytes_down = 0

    def open(self, req_id: int, expected_tokens: int) -> None:
        if not self.server.open_session(req_id, expected_tokens):
            raise RuntimeError(
                f"cloud rejected session {req_id}: no free slot / KV budget"
            )

    def close(self, req_id: int) -> None:
        self.server.close_session(req_id)

    def send(self, data: bytes) -> None:
        self.bytes_up += len(data)
        self.server.handle_frame(data)

    def recv(self, req_id: int) -> bytes:
        while True:
            data = self.server.poll(req_id)
            if data is not None:
                self.bytes_down += len(data)
                self._on_downlink(data)
                return data
            if self._pump() == 0:
                raise RuntimeError(
                    f"downlink starved: no frame in flight for request {req_id}"
                )

    def snapshot(self, req_id: int):
        return self.server.snapshot_session(req_id)

    def restore(self, req_id: int, snap) -> None:
        self.server.restore_session(req_id, snap)

    # ------------------------------------------------- subclass timing hooks
    def _pump(self) -> int:
        return self.server.pump()

    def _on_downlink(self, data: bytes) -> None:
        pass


class DelayModelTransport(LoopbackTransport):
    """Loopback semantics + simulated wall-clock from ``delay_models.py``.

    Real tensors flow exactly as over :class:`LoopbackTransport`, but the
    transport keeps a clock: uplink/downlink transfers advance it by the
    :class:`NetworkModel` transfer time for the frame's byte size, each
    engine pump advances it by the :class:`CloudDelayModel` delay for the
    batched token count, and the device reports its local compute through
    :meth:`tick`.  A shared :class:`StateMonitor` (when given) sees the same
    observations the paper's cloud would — which is what warms up the Eq. 3
    chunk solver on real runs."""

    def __init__(
        self,
        server: CloudServer,
        *,
        device: DeviceProfile,
        net: Optional[NetworkModel] = None,
        cloud: Optional[CloudDelayModel] = None,
        monitor: Optional[StateMonitor] = None,
        start_s: float = 0.0,
        rng: Optional[np.random.Generator] = None,
    ):
        super().__init__(server)
        self.device = device
        self.net = net or NetworkModel(rng or np.random.default_rng(0))
        self.cloud = cloud or CloudDelayModel()
        self.monitor = monitor
        self.clock_s = float(start_s)
        self.cloud_step_delays_s: List[float] = []

    def tick(self, seconds: float) -> None:
        self.clock_s += seconds

    def send(self, data: bytes) -> None:
        dur = self.net.up_time(self.device, len(data))
        self.clock_s += dur
        if self.monitor is not None and dur > 0:
            self.monitor.record_device(
                self.device.dev_id, beta_up=len(data) / dur
            )
        super().send(data)

    def _pump(self) -> int:
        tokens = super()._pump()
        if tokens > 0:
            delay = self.cloud.delay(tokens)
            self.clock_s += delay
            self.cloud_step_delays_s.append(self.cloud.stage_time(tokens))
            if self.monitor is not None:
                self.monitor.record_batch(tokens, delay)
        return tokens

    def _on_downlink(self, data: bytes) -> None:
        dur = self.net.down_time(self.device, len(data))
        self.clock_s += dur
        if self.monitor is not None and dur > 0:
            self.monitor.record_device(
                self.device.dev_id, beta_down=len(data) / dur
            )


# ---------------------------------------------------------------------------
# DeviceClient: the device side of the session protocol
# ---------------------------------------------------------------------------


@dataclass
class _Session:
    req_id: int
    in_cache: Dict
    offset: int = 0
    draft_cache: Optional[Dict] = None
    draft_offset: int = 0
    last_token: int = -1
    last_bonus: int = -1
    topk_last: Optional[np.ndarray] = None
    deep_last: Optional[np.ndarray] = None
    draft_snap: Optional[Dict] = None
    paths: Optional[List[List[int]]] = None
    last_commit: List[int] = field(default_factory=list)
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0


class DeviceClient:
    """The device half of HAT: input submodel + adapter Λ + output head.

    Drives Eq. 3 chunked prefill, Eq. 5 threshold drafting and greedy
    acceptance as a token-streaming generator; every hidden-state hop is a
    serialized ``repro.wire`` frame pushed through the :class:`Transport`.

    ``sd`` picks the decode algorithm: ``"draft"`` (threshold speculative
    decoding — needs ``adapter_params``), ``"medusa"`` (tree verification —
    needs ``medusa_params``), or ``None`` (one verified token per round).
    The default ``"auto"`` infers it from which parameters are present.
    """

    def __init__(
        self,
        split: SplitModels,
        transport: Transport,
        *,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        sd: Optional[str] = "auto",
        pc: Optional[str] = "device",
        pd: bool = True,
        eta: float = 0.6,
        max_draft: int = 8,
        topk: int = 4,
        max_len: int = 512,
        wire_codec: str = "fp16",
        fixed_chunk: int = 128,
        dynamic_chunks: bool = True,
        pipeline_len: int = 1,
        monitor: Optional[StateMonitor] = None,
        profile: Optional[DeviceProfile] = None,
        memory: Optional[jax.Array] = None,
    ):
        self.split = split
        self.cfg = split.cfg
        self.transport = transport
        self.codec = get_codec(wire_codec)           # uplink codec
        self.draft_model = (
            DraftModel(split, adapter_params) if adapter_params is not None else None
        )
        self.medusa_params = medusa_params
        if sd == "auto":
            sd = ("draft" if adapter_params is not None
                  else "medusa" if medusa_params is not None else None)
        if sd == "draft" and self.draft_model is None:
            raise ValueError("sd='draft' needs adapter_params")
        if sd == "medusa" and medusa_params is None:
            raise ValueError("sd='medusa' needs medusa_params")
        self.sd = sd
        self.pc = pc
        self.pd = pd
        self.eta = eta
        self.max_draft = max_draft
        self.topk = topk
        self.max_len = max_len
        self.fixed_chunk = fixed_chunk
        self.dynamic_chunks = dynamic_chunks
        self.pipeline_len = pipeline_len
        self.monitor = monitor
        self.profile = profile
        self.memory = memory
        self.ssm = has_ssm_state(self.cfg)
        self.sessions: Dict[int, _Session] = {}
        self.finished_stats: Dict[int, Dict[str, float]] = {}
        self._auto_id = itertools.count()

    # --------------------------------------------------------- device clock
    def _tick(self, seconds: float) -> None:
        if self.profile is not None:
            self.transport.tick(seconds)

    # ------------------------------------------------------------- U round
    def _u_round(self, sess: _Session, tokens: np.ndarray, kind: str):
        """One wire round trip at ``sess.offset``: shallow-forward the
        tokens locally, frame + send the shallow states, receive the deep
        frame, run the head.  Returns (logits [T, V], deep [T, D])."""
        toks = jnp.asarray(tokens, jnp.int32)[None]
        shallow, sess.in_cache, _ = self.split.input_model.apply(
            self.split.input_params, toks, cache=sess.in_cache,
            offset=sess.offset, memory=self.memory, return_hidden=True,
        )
        if self.profile is not None:
            self._tick(self.profile.shallow_delay(len(tokens)))
        self.transport.send(encode_hidden(
            self.codec, np.asarray(shallow[0], np.float32),
            req_id=sess.req_id, offset=sess.offset, kind=kind, want_deep=True,
        ))
        deep = self._recv_deep(sess.req_id)
        logits = self.split.head_logits(jnp.asarray(deep)[None])
        if self.profile is not None:
            self._tick(self.profile.head_delay())
        return np.asarray(logits[0], np.float32), deep

    def _recv_deep(self, req_id: int) -> np.ndarray:
        frame = Frame.from_bytes(self.transport.recv(req_id))
        return decode_hidden(frame, self.cfg.d_model)

    # -------------------------------------------------------------- prefill
    def prefill(
        self,
        req_id: int,
        prompt: np.ndarray,
        *,
        expected_new_tokens: int = 128,
    ) -> int:
        """Chunked prefill (Eq. 3) for one session; returns the first token.

        Each chunk's shallow states cross as their own ``prefill`` frame —
        earlier chunks ask for no deep states back, the last one does and
        its deep frame feeds the on-device head."""
        if req_id in self.sessions:
            raise ValueError(f"session {req_id} already open")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit max_len={self.max_len}"
            )
        self.transport.open(
            req_id, min(len(prompt) + expected_new_tokens, self.max_len)
        )
        sess = _Session(
            req_id=req_id,
            in_cache=self.split.input_model.init_cache(
                self.split.input_params, 1, self.max_len, memory=self.memory
            ),
        )
        self.sessions[req_id] = sess

        dev_id = self.profile.dev_id if self.profile is not None else 0
        mon = self.monitor
        chunks = plan_chunks(
            len(prompt),
            pc=self.pc, dynamic_chunks=self.dynamic_chunks,
            fixed_chunk=self.fixed_chunk,
            hidden_bytes_per_token=self.codec.bytes_per_token(self.cfg.d_model),
            beta_up=mon.device(dev_id).beta_up.get(7.5e6) if mon else 7.5e6,
            g=mon.g.predict if mon else None,
            mu=mon.mu.get(64.0) if mon else 64.0,
            pipeline_len=self.pipeline_len,
        )
        off = 0
        for i, size in enumerate(chunks):
            toks = jnp.asarray(prompt[off:off + size], jnp.int32)[None]
            shallow, sess.in_cache, _ = self.split.input_model.apply(
                self.split.input_params, toks, cache=sess.in_cache,
                offset=off, memory=self.memory, return_hidden=True,
            )
            if self.profile is not None:
                self._tick(self.profile.shallow_delay(size))
            self.transport.send(encode_hidden(
                self.codec, np.asarray(shallow[0], np.float32),
                req_id=req_id, offset=off, kind="prefill",
                want_deep=(i == len(chunks) - 1),
            ))
            off += size
        deep = self._recv_deep(req_id)              # last chunk's deep states
        logits = self.split.head_logits(jnp.asarray(deep)[None])
        if self.profile is not None:
            self._tick(self.profile.head_delay())
        sess.offset = len(prompt)
        sess.deep_last = deep[-1]
        tok = int(np.asarray(logits[0], np.float32)[-1].argmax())
        sess.last_token = tok

        if self.draft_model is not None:
            sess.draft_cache = self.draft_model.init_cache(
                1, self.max_len, memory=self.memory
            )
            _, sess.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(prompt, jnp.int32)[None], cache=sess.draft_cache,
                offset=0, memory=self.memory,
            )
            sess.draft_offset = len(prompt)
        return tok

    # ------------------------------------------------------------- drafting
    def draft(self, req_id: int, max_draft: Optional[int] = None,
              *, charge_time: bool = True) -> List[int]:
        """Eq. 5 threshold drafting with the on-device draft model w_S."""
        sess = self.sessions[req_id]
        if self.draft_model is None:
            return []
        sess.draft_snap = (
            snapshot_states(sess.draft_cache["input"]) if self.ssm else None
        )
        # the verify strip is [last_token, *draft]: never draft past the
        # slot's remaining KV capacity
        room = max(self.max_len - sess.offset - 1, 0)
        budget = min(
            self.max_draft if max_draft is None else max_draft,
            self.max_draft, room,
        )
        if budget <= 0:
            return []
        res, sess.draft_cache, sess.draft_offset = draft_until_threshold(
            self.draft_model, sess.draft_cache,
            jnp.asarray([[sess.last_token]], jnp.int32),
            sess.draft_offset, eta=self.eta,
            max_draft=budget, topk=self.topk, memory=self.memory,
        )
        sess.topk_last = res.topk_last
        if self.profile is not None and charge_time:
            self._tick(self.profile.draft_delay(res.steps))
        return res.tokens.tolist()

    def parallel_draft_hit(self, req_id: int) -> bool:
        """Eq. 6: was the bonus token among the last draft step's top-k
        (i.e. the next round's draft was already computable in parallel)?"""
        sess = self.sessions.get(req_id)
        if sess is None or sess.topk_last is None:
            return False
        return int(sess.last_bonus) in set(np.asarray(sess.topk_last).tolist())

    # ---------------------------------------------------------- verification
    def verify(self, req_id: int, draft: List[int]) -> Tuple[int, int]:
        """U-shaped verification of ``draft``; returns (n_accepted, bonus).

        Attention caches roll back positionally (the next round's frames
        overwrite the rejected rows, device- and cloud-side alike).  SSM
        caches carry state: the device snapshots its local input cache and
        asks the cloud — over the transport's control channel — to snapshot
        the slot, then both restore + re-advance the accepted prefix."""
        sess = self.sessions[req_id]
        toks = np.asarray([sess.last_token] + list(draft), np.int32)
        in_snap = snapshot_states(sess.in_cache) if self.ssm else None
        cloud_snap = self.transport.snapshot(req_id) if self.ssm else None
        logits, deep = self._u_round(sess, toks, "verify")
        if draft:
            n, bonus = accept_greedy_rows(np.asarray(draft), logits)
        else:
            n, bonus = 0, int(logits[-1].argmax())
        accepted = 1 + n                     # last_token + accepted drafts
        if self.ssm and n < len(draft):
            sess.in_cache = restore_states(sess.in_cache, in_snap)
            self.transport.restore(req_id, cloud_snap)
            _, deep = self._u_round(sess, toks[:accepted], "verify")
        sess.offset += accepted
        sess.deep_last = deep[accepted - 1]
        if self.draft_model is not None:
            if self.ssm and sess.draft_snap is not None:
                sess.draft_cache["input"] = restore_states(
                    sess.draft_cache["input"], sess.draft_snap
                )
            _, sess.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(toks[:accepted], jnp.int32)[None],
                cache=sess.draft_cache, offset=sess.offset - accepted,
                memory=self.memory,
            )
            sess.draft_offset = sess.offset
        sess.last_bonus = bonus
        sess.last_token = bonus
        sess.rounds += 1
        sess.drafted += len(draft)
        sess.accepted += accepted          # accepted drafts + the bonus token
        sess.last_commit = [*list(draft)[:n], bonus]
        return n, bonus

    # --------------------------------------------------------------- medusa
    def medusa_tree(self, req_id: int) -> int:
        sess = self.sessions[req_id]
        sess.paths = medusa_mod.build_tree_paths(
            self.medusa_params, jnp.asarray(sess.deep_last), tree_size=8
        )
        return 8                       # tree size charged to the wire/cloud

    def medusa_verify(self, req_id: int) -> Tuple[int, int]:
        sess = self.sessions[req_id]
        paths = sess.paths or [[0]]
        in_snap = snapshot_states(sess.in_cache) if self.ssm else None
        cloud_snap = self.transport.snapshot(req_id) if self.ssm else None
        greedy_rows = []
        for path in paths:
            toks = np.asarray([sess.last_token] + list(path), np.int32)
            if self.ssm:
                sess.in_cache = restore_states(sess.in_cache, in_snap)
                self.transport.restore(req_id, cloud_snap)
            logits, _ = self._u_round(sess, toks, "verify")
            greedy_rows.append(logits.argmax(-1))
            # positional rollback: the next path overwrites the same offsets
        best_pi, n, bonus = medusa_mod.accept_best_path(paths, greedy_rows)
        commit = np.asarray(
            [sess.last_token] + list(paths[best_pi][:n]), np.int32
        )
        if self.ssm:
            sess.in_cache = restore_states(sess.in_cache, in_snap)
            self.transport.restore(req_id, cloud_snap)
        _, deep = self._u_round(sess, commit, "verify")
        sess.offset += len(commit)
        sess.deep_last = deep[-1]
        sess.rounds += 1
        sess.drafted += 4
        sess.accepted += n + 1
        sess.last_commit = [*list(paths[best_pi][:n]), bonus]
        sess.last_token = bonus
        return n, bonus

    # ------------------------------------------------------------ lifecycle
    def step_decode(self, req_id: int) -> List[int]:
        """One decode round under the configured algorithm; returns the
        emitted tokens (accepted drafts + bonus — always ≥ 1)."""
        if self.sd == "medusa":
            tree = self.medusa_tree(req_id)
            if self.profile is not None:
                self._tick(self.profile.head_delay() * 4)
            self.medusa_verify(req_id)
            return list(self.sessions[req_id].last_commit)
        if self.sd == "draft":
            sess = self.sessions[req_id]
            pd_hit = (
                self.pd and sess.rounds > 0 and self.parallel_draft_hit(req_id)
            )
            d = self.draft(req_id, charge_time=not pd_hit)
            n, bonus = self.verify(req_id, d)
            return list(self.sessions[req_id].last_commit)
        self.verify(req_id, [])
        return list(self.sessions[req_id].last_commit)

    def finish(self, req_id: int) -> None:
        """Close the session and release its cloud slot."""
        sess = self.sessions.pop(req_id, None)
        if sess is None:
            return
        self.finished_stats[req_id] = {
            "rounds": sess.rounds, "drafted": sess.drafted,
            "accepted": sess.accepted,
        }
        self.transport.close(req_id)

    def generate(
        self,
        prompt: np.ndarray,
        *,
        max_new_tokens: int = 128,
        req_id: Optional[int] = None,
    ) -> Iterator[int]:
        """The session API entry point: stream generated tokens.

        Opens a session, runs chunked prefill, then decode rounds until
        ``max_new_tokens`` tokens have been emitted — or the slot's KV
        capacity (``max_len``) is reached, which ends the stream early
        rather than overflowing the cache.  The session closes on
        exhaustion *and* on early generator close."""
        rid = next(self._auto_id) if req_id is None else req_id
        # a decode round needs cache rows for its verify strip: 1 for the
        # bonus-token round (draft capacity-caps itself), 1 + tree depth
        # for a medusa path commit
        need = 1 + medusa_mod.N_HEADS if self.sd == "medusa" else 1
        try:
            yield self.prefill(rid, prompt, expected_new_tokens=max_new_tokens)
            emitted = 1
            while emitted < max_new_tokens:
                if self.max_len - self.sessions[rid].offset < need:
                    break                      # KV capacity exhausted
                for tok in self.step_decode(rid):
                    yield tok
                    emitted += 1
                    if emitted >= max_new_tokens:
                        break
        finally:
            self.finish(rid)


# ---------------------------------------------------------------------------
# Runtime: one serve() surface over both execution engines
# ---------------------------------------------------------------------------


class Runtime(Protocol):
    """Anything that can serve a workload and report fleet metrics."""

    def serve(self, requests) -> FleetMetrics: ...


class SimulatorRuntime:
    """Discrete-event fleet runtime (statistical or real-model backend).

    All algorithmic components are the real repro.core implementations;
    wall-clock comes from the calibrated delay models.  This is the tool
    for fleet-scale contention studies (Figs. 6–12)."""

    def __init__(
        self,
        config: ServeConfig,
        *,
        backend=None,
        rng: Optional[np.random.Generator] = None,
        cloud: Optional[CloudDelayModel] = None,
    ):
        self.config = config
        self.rng = rng or np.random.default_rng(0)
        self.backend = backend or StatisticalBackend(self.rng)
        config.configure_backend(self.backend)
        self.cloud = cloud or CloudDelayModel(pipeline_len=config.pipeline_len)
        self.simulator = Simulator(
            config.to_sim_config(), self.cloud, self.backend, self.rng,
            n_devices=config.n_devices,
        )

    def serve(self, requests) -> FleetMetrics:
        for r in requests:
            self.simulator.submit(Request(
                req_id=r.req_id, device_id=r.device_id, arrival_s=r.arrival_s,
                prompt_len=r.prompt_len, max_new_tokens=r.max_new_tokens,
                prompt=getattr(r, "prompt", None),
            ))
        return self.simulator.run()


class EngineRuntime:
    """Real-tensor runtime: DeviceClient/CloudServer sessions over a
    :class:`DelayModelTransport`.

    Every token is really computed — shallow states on the device, codec
    frames on the wire, slot-batched middle steps in the engine — while the
    delay models supply simulated wall-clock.  Sessions run sequentially
    (each on its own clock starting at its arrival time), so cross-request
    queueing contention and the upload/compute overlap of chunked prefill
    are *not* modeled here; use :class:`SimulatorRuntime` for those.  A
    shared :class:`StateMonitor` accumulates across requests,
    so later prefills get warmed-up Eq. 3 chunk sizes."""

    def __init__(
        self,
        config: ServeConfig,
        split: SplitModels,
        *,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        rng: Optional[np.random.Generator] = None,
        n_slots: int = 8,
        max_len: int = 512,
        memory: Optional[jax.Array] = None,
    ):
        if config.sd == "draft" and adapter_params is None:
            raise ValueError(
                f"ServeConfig {config.framework!r} uses sd='draft': "
                "EngineRuntime needs adapter_params"
            )
        if config.sd == "medusa" and medusa_params is None:
            raise ValueError(
                f"ServeConfig {config.framework!r} uses sd='medusa': "
                "EngineRuntime needs medusa_params"
            )
        self.config = config
        self.split = split
        self.adapter_params = adapter_params
        self.medusa_params = medusa_params
        self.rng = rng or np.random.default_rng(0)
        self.n_slots = n_slots
        self.max_len = max_len
        self.memory = memory
        self.monitor = StateMonitor(alpha=0.8)
        self.server = CloudServer(
            split, n_slots=n_slots, max_len=max_len,
            max_batch_tokens=config.max_batch_tokens or 256,
            wire_codec=config.codec_name, memory=memory,
        )

    def serve(self, requests) -> FleetMetrics:
        cfg = self.config
        metrics = FleetMetrics()
        fleet = make_fleet(self.rng, cfg.n_devices)
        net = NetworkModel(
            self.rng, up_fixed=cfg.uplink_bps, down_fixed=cfg.downlink_bps
        )
        cloud = CloudDelayModel(pipeline_len=cfg.pipeline_len)
        sd = cfg.sd
        for spec in requests:
            dev = fleet[spec.device_id % len(fleet)]
            dev.maybe_rotate_mode()
            transport = DelayModelTransport(
                self.server, device=dev, net=net, cloud=cloud,
                monitor=self.monitor, start_s=spec.arrival_s,
            )
            client = DeviceClient(
                self.split, transport,
                adapter_params=self.adapter_params if sd == "draft" else None,
                medusa_params=self.medusa_params if sd == "medusa" else None,
                sd=sd, pc=cfg.pc, pd=cfg.pd, eta=cfg.eta,
                max_draft=cfg.max_draft,
                topk=cfg.topk, max_len=self.max_len,
                wire_codec=cfg.codec_name, fixed_chunk=cfg.fixed_chunk,
                dynamic_chunks=cfg.dynamic_chunks,
                pipeline_len=cfg.pipeline_len, monitor=self.monitor,
                profile=dev, memory=self.memory,
            )
            prompt = spec.prompt
            if prompt is None:
                prompt = self.rng.integers(
                    3, self.split.cfg.vocab_size, size=spec.prompt_len
                ).astype(np.int32)
            prompt = np.asarray(prompt, np.int32)[: self.max_len // 2]
            req = Request(
                req_id=spec.req_id, device_id=dev.dev_id,
                arrival_s=spec.arrival_s, prompt_len=len(prompt),
                max_new_tokens=spec.max_new_tokens, prompt=prompt,
            )
            req.phase = Phase.DECODE
            for tok in client.generate(
                prompt, max_new_tokens=spec.max_new_tokens, req_id=spec.req_id
            ):
                req.emit_tokens([tok], transport.clock_s)
            stats = client.finished_stats.get(spec.req_id, {})
            req.rounds = int(stats.get("rounds", 0))
            req.drafted = int(stats.get("drafted", 0))
            req.accepted = int(stats.get("accepted", 0))
            req.phase = Phase.DONE
            req.done_s = transport.clock_s
            metrics.cloud_step_delays_s.extend(transport.cloud_step_delays_s)
            metrics.add(req)
        return metrics


# ---------------------------------------------------------------------------
# legacy wrapper
# ---------------------------------------------------------------------------


def run_fleet(
    framework: str,
    requests,
    *,
    rng: Optional[np.random.Generator] = None,
    pipeline_len: int = 4,
    hidden_bytes: Optional[float] = 4096 * 2,
    backend=None,
    n_devices: int = 30,
    overrides: Optional[dict] = None,
    wire_codec: Optional[str] = None,
) -> FleetMetrics:
    """Deprecated: thin back-compat wrapper over
    ``ServeConfig.from_framework(...)`` + :class:`SimulatorRuntime`.

    New code should build a :class:`ServeConfig` (``ServeConfig.hat()`` and
    friends) and call ``SimulatorRuntime(config, backend=...).serve(reqs)``.
    Codec-vs-``hidden_bytes`` precedence is resolved once by ServeConfig: a
    requested codec switches byte accounting to codec-derived values and
    configures the backend; otherwise the explicit ``hidden_bytes`` applies
    and a backend-supplied codec is left untouched."""
    kw = dict(overrides or {})
    if wire_codec is not None:
        kw.setdefault("wire_codec", wire_codec)
    if (
        "hidden_bytes_per_token" not in kw
        and "wire_codec" not in kw
        and hidden_bytes is not None
    ):
        kw["hidden_bytes_per_token"] = hidden_bytes
    config = ServeConfig.from_framework(
        framework, pipeline_len=pipeline_len, n_devices=n_devices, **kw
    )
    return SimulatorRuntime(config, backend=backend, rng=rng).serve(requests)
