"""Unified session API: DeviceClient / CloudServer / Transport.

HAT's core claim is a *protocol* — devices and cloud exchanging codec-framed
hidden states with chunked-prefill overlap — and this module is its single
front door, replacing the three ad-hoc serving paths (``run_fleet`` kwargs
soup, raw ``CloudEngine.submit``/``step`` with caller-side chunking, and
``RealBackend``'s inline re-implementation of the U path):

    DeviceClient ──frames──▶ Transport ──frames──▶ CloudServer ─▶ CloudEngine
        │  input submodel + Λ + head                  │  middle submodel,
        │  Eq. 3 chunked prefill,                     │  slot-batched steps,
        │  Eq. 5 threshold drafting,                  │  KV admission,
        │  greedy acceptance                          │  downlink encoding
        ◀──────────── deep-state frames ──────────────┘

* :class:`DeviceClient` owns the device-resident pieces (input submodel,
  adapter Λ, output head) and drives the whole decode loop as a
  **token-streaming generator**: ``client.generate(prompt)`` yields tokens.
  Every hidden-state hop is a serialized ``repro.wire`` frame — there is no
  bare-array side channel.
* :class:`CloudServer` wraps :class:`~repro.serving.engine.CloudEngine`
  behind frame ingress/egress plus a per-request downlink outbox, and
  exposes the SSM rollback control channel (slot snapshot/restore).
* :class:`Transport` is the small protocol between them.
  :class:`LoopbackTransport` is the in-process wire;
  :class:`DelayModelTransport` reuses ``delay_models.py`` so real-tensor
  runs get simulated wall-clock (link transfer times, cloud batch delays,
  device compute ticks).
* :class:`ServeConfig` is the typed run description with framework
  constructors (``ServeConfig.hat()``, ``.u_shape()``, ``.u_sarathi()``,
  ``.u_medusa()``) replacing the ``FRAMEWORKS`` dict + ``overrides`` kwargs.
  It resolves the wire codec vs. ``hidden_bytes_per_token`` precedence
  exactly once.
* :class:`Runtime` unifies the two execution engines behind
  ``serve(requests) -> FleetMetrics``: :class:`SimulatorRuntime` runs the
  discrete-event fleet simulator, :class:`EngineRuntime` runs real tensors
  through DeviceClient/CloudServer sessions — by default *concurrently*:
  every session is a coroutine scheduled on a shared virtual clock, so the
  engine batches prefill chunks and verify strips across requests
  (continuous batching) and queueing contention is modeled on real-tensor
  runs.

``run_fleet`` remains as a thin deprecated wrapper over
``ServeConfig.from_framework`` + :class:`SimulatorRuntime`.
"""
from __future__ import annotations

import itertools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (
    Callable,
    Dict,
    Iterator,
    List,
    NamedTuple,
    Optional,
    Protocol,
    Tuple,
)

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adapter import DraftModel
from ..core.chunking import plan_chunks
from ..core.monitor import StateMonitor
from ..core.speculative import (
    accept_greedy_rows,
    draft_until_threshold,
    has_ssm_state,
    restore_states,
    snapshot_states,
)
from ..core.split import SplitModels
from ..net.errors import SessionLostError, TransportError, TransportTimeout
from ..net.policy import Deadline, RetryPolicy
from ..obs import NULL_TRACER, TID_CLOUD, Tracer, attach_monitor
from ..wire import (
    Frame,
    decode_hidden,
    encode_hidden,
    frame_req_id,
    get_codec,
    stamp_t_send,
)
from . import medusa as medusa_mod
from .delay_models import CloudDelayModel, DeviceProfile, NetworkModel, make_fleet
from .engine import CloudEngine, EngineOverflowError
from .request import FleetMetrics, Phase, Request
from .simulator import FRAMEWORKS, SimConfig, Simulator, StatisticalBackend

Params = Dict


# ---------------------------------------------------------------------------
# ServeConfig: the typed run description
# ---------------------------------------------------------------------------


@dataclass
class ServeConfig:
    """One serving run, fully described.

    Use the framework constructors — ``ServeConfig.hat()``,
    ``.u_shape()``, ``.u_sarathi()``, ``.u_medusa()`` — rather than spelling
    the flag combination by hand.  ``wire_codec=None`` means "nobody asked
    for a codec": byte accounting falls back to ``hidden_bytes_per_token``
    (or the fp16 default) and a backend's own codec configuration is left
    alone; a named codec switches accounting to codec-derived bytes and
    (re)configures the backend.
    """

    framework: str = "hat"
    # --- algorithm flags (simulator semantics) -----------------------------
    sd: Optional[str] = "draft"        # None | "draft" | "medusa"
    pc: Optional[str] = "device"       # None | "device" (HAT) | "server" (Sarathi)
    pd: bool = True
    fixed_chunk: int = 128
    dynamic_chunks: bool = True
    eta: float = 0.6
    max_draft: int = 8
    topk: int = 4
    # --- wire --------------------------------------------------------------
    wire_codec: Optional[str] = None   # None = legacy byte accounting
    d_model: int = 4096
    hidden_bytes_per_token: Optional[float] = None
    token_bytes: float = 4.0
    uplink_bps: Optional[float] = None
    downlink_bps: Optional[float] = None
    # --- cloud -------------------------------------------------------------
    max_batch_tokens: Optional[int] = 512
    pipeline_len: int = 4
    # uplink pipelining depth for chunked prefill: 0 = unbounded streaming
    # (legacy), 1 = strictly sequential (each chunk waits for the previous
    # chunk's processing ack), D>1 = at most D unprocessed chunks in flight
    pipeline_depth: int = 0
    # --- robustness --------------------------------------------------------
    # how hard a transport fights a dead connection, and how long one
    # blocking operation may take end to end (reconnects included) —
    # consumed by SocketTransport; loopback/delay-model transports have
    # no connection to lose and ignore them
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    deadline: Deadline = field(default_factory=Deadline)
    # --- fleet -------------------------------------------------------------
    n_devices: int = 30
    max_sim_s: float = 3600.0

    def __post_init__(self):
        if self.hidden_bytes_per_token is None:
            self.hidden_bytes_per_token = self.codec.bytes_per_token(self.d_model)

    # --------------------------------------------------------- codec facts
    @property
    def codec_name(self) -> str:
        """The effective wire-codec name (``fp16`` when unset)."""
        return self.wire_codec or "fp16"

    @property
    def codec(self):
        """The resolved :mod:`repro.wire` codec object."""
        return get_codec(self.codec_name)

    def configure_backend(self, backend) -> None:
        """Apply the run's wire codec to a backend — but only when a codec
        was actually requested.  A backend configured directly by its caller
        (``RealBackend(wire_codec=...)``, ``StatisticalBackend(
        wire_penalty=...)``) is never clobbered by the fp16 default."""
        if self.wire_codec is not None and hasattr(backend, "set_wire_codec"):
            backend.set_wire_codec(self.codec)

    def to_sim_config(self) -> SimConfig:
        """Project this run description onto the discrete-event simulator's
        config (same strategies, chunking, codec, link rates, pipeline
        depth); drops engine-only knobs like ``n_devices``."""
        return SimConfig(
            sd=self.sd, pc=self.pc, pd=self.pd,
            fixed_chunk=self.fixed_chunk, dynamic_chunks=self.dynamic_chunks,
            eta=self.eta, max_draft=self.max_draft, topk=self.topk,
            wire_codec=self.codec_name, d_model=self.d_model,
            hidden_bytes_per_token=self.hidden_bytes_per_token,
            token_bytes=self.token_bytes,
            uplink_bps=self.uplink_bps, downlink_bps=self.downlink_bps,
            max_batch_tokens=self.max_batch_tokens,
            pipeline_depth=self.pipeline_depth, max_sim_s=self.max_sim_s,
        )

    # --------------------------------------------- framework constructors
    @classmethod
    def _make(cls, name: str, defaults: dict, kw: dict) -> "ServeConfig":
        base = dict(defaults)
        base.update(kw)                    # explicit kwargs win (ablations)
        return cls(framework=name, **base)

    @classmethod
    def hat(cls, **kw) -> "ServeConfig":
        """HAT: threshold drafting + device-side dynamic chunking + parallel
        drafting + budgeted cloud batching."""
        return cls._make("hat", dict(sd="draft", pc="device", pd=True), kw)

    @classmethod
    def u_shape(cls, **kw) -> "ServeConfig":
        """Plain U-shaped inference: bulk upload, per-token decoding, naive
        (unbudgeted) cloud batching."""
        return cls._make(
            "u-shape", dict(sd=None, pc=None, pd=False, max_batch_tokens=None), kw
        )

    @classmethod
    def u_sarathi(cls, **kw) -> "ServeConfig":
        """U-shape + Sarathi-style server-side fixed chunks (no overlap)."""
        return cls._make(
            "u-sarathi",
            dict(sd=None, pc="server", pd=False, dynamic_chunks=False), kw,
        )

    @classmethod
    def u_medusa(cls, **kw) -> "ServeConfig":
        """U-shape + Medusa heads with tree verification."""
        return cls._make(
            "u-medusa",
            dict(sd="medusa", pc=None, pd=False, max_batch_tokens=None), kw,
        )

    @classmethod
    def from_framework(cls, name: str, **kw) -> "ServeConfig":
        """Look up a framework preset by paper name (``hat``, ``u-shape``,
        ``u-sarathi``, ``u-medusa``); raises :class:`KeyError` on an
        unknown name.  Explicit ``**kw`` override the preset (ablations)."""
        ctor = {
            "hat": cls.hat, "u-shape": cls.u_shape,
            "u-sarathi": cls.u_sarathi, "u-medusa": cls.u_medusa,
        }.get(name)
        if ctor is None:
            raise KeyError(f"unknown framework {name!r}; known: {sorted(FRAMEWORKS)}")
        return ctor(**kw)


# ---------------------------------------------------------------------------
# CloudServer: the cloud side of the session protocol
# ---------------------------------------------------------------------------


class CloudServer:
    """Frame-speaking facade over :class:`CloudEngine`.

    Uplink frames enter through :meth:`handle_frame`; each :meth:`pump` runs
    one slot-batched engine step and routes the resulting deep-state frames
    into per-request outboxes for the transport to deliver.  The server also
    exposes the session lifecycle (open/close) and the SSM rollback control
    channel (:meth:`snapshot_session` / :meth:`restore_session`)."""

    def __init__(
        self,
        split: SplitModels,
        *,
        n_slots: int = 8,
        max_len: int = 512,
        max_batch_tokens: Optional[int] = 256,
        wire_codec: str = "fp16",
        kv_budget=None,
        memory: Optional[jax.Array] = None,
        auto_grow: bool = False,
        tracer: Optional[Tracer] = None,
    ):
        self.engine = CloudEngine(
            split, n_slots=n_slots, max_len=max_len,
            max_batch_tokens=max_batch_tokens, kv_budget=kv_budget,
            memory=memory, wire_codec=wire_codec, auto_grow=auto_grow,
            tracer=tracer,
        )
        self._outbox: Dict[int, deque] = {}
        self._processed: Dict[int, int] = {}     # req_id -> frames stepped

    @property
    def d_model(self) -> int:
        """Hidden width of the middle submodel (wire-frame negotiation)."""
        return self.engine.d_model

    # ------------------------------------------------------------ sessions
    def open_session(self, req_id: int, expected_tokens: int) -> bool:
        """Admit a session: engine slot + KV budget for ``expected_tokens``.
        Returns False (no exception) when the cloud cannot admit it."""
        return self.engine.add_request(req_id, expected_tokens)

    def close_session(self, req_id: int) -> None:
        """Release the session: outbox, queued jobs, slot and KV."""
        self._outbox.pop(req_id, None)
        self._processed.pop(req_id, None)
        self.engine.queue = [j for j in self.engine.queue if j.req_id != req_id]
        if req_id in self.engine.kv.slot_of:
            self.engine.finish_request(req_id)

    # -------------------------------------------------------------- frames
    def handle_frame(self, data: bytes) -> None:
        """Uplink ingress: decode + enqueue one chunk frame."""
        try:
            self.engine.submit_frame(data)
        except EngineOverflowError as e:
            self._outbox.pop(e.req_id, None)
            raise

    def pump(self) -> int:
        """One engine step; returns the batched token count (0 = idle).

        Deep-state results are encoded with the engine's downlink codec and
        parked in the owning request's outbox."""
        results = self.engine.step()
        if not results:
            return 0
        for j in self.engine.last_step_info:
            self._processed[j["req_id"]] = (
                self._processed.get(j["req_id"], 0) + j.get("n_frames", 1)
            )
        for r in results:
            if r.deep is not None:
                self._outbox.setdefault(r.req_id, deque()).append(
                    self.engine.encode_result(r)
                )
        return self.engine.batched_token_history[-1]

    def poll(self, req_id: int) -> Optional[bytes]:
        """Pop the next downlink frame for ``req_id`` (None = none pending)."""
        q = self._outbox.get(req_id)
        return q.popleft() if q else None

    def pending(self, req_id: int) -> bool:
        """Is a downlink frame parked for ``req_id``?"""
        return bool(self._outbox.get(req_id))

    def processed_count(self, req_id: int) -> int:
        """Uplink frames of ``req_id`` the engine has stepped so far (the
        in-process counterpart of the wire's ``MSG_FRAME_ACK`` watermark)."""
        return self._processed.get(req_id, 0)

    # ----------------------------------------------------- control channel
    def snapshot_session(self, req_id: int):
        """Snapshot the slot's recurrent (SSM) state; returns an opaque
        cloud-held handle for :meth:`restore_session`."""
        return self.engine.snapshot_slot(req_id)

    def restore_session(self, req_id: int, snap) -> None:
        """Roll the slot's recurrent state back to a snapshot handle."""
        self.engine.restore_slot(req_id, snap)


# ---------------------------------------------------------------------------
# Transport: the small device<->cloud protocol
# ---------------------------------------------------------------------------


class Transport:
    """The device's handle on the cloud.

    Data plane: ``send`` pushes an uplink chunk frame; ``recv`` blocks until
    the next downlink (deep-state) frame for the request is available.
    Session plane: ``open`` / ``close``.  Control plane: ``snapshot`` /
    ``restore`` implement speculative rollback of cloud-resident recurrent
    state (SSM middles; attention middles roll back positionally and never
    call these).  ``tick`` lets the device report local compute time to
    transports that keep a clock; ``clock`` reads that clock back — wall
    time by default, virtual seconds on simulated transports — and is what
    stamps every uplink frame's ``t_send`` and timestamps trace spans, so
    hop attribution works identically over loopback, delay-model, and
    future socket transports."""

    def clock(self) -> float:
        """Seconds on this transport's clock (wall time by default)."""
        return time.perf_counter()

    def open(self, req_id: int, expected_tokens: int) -> None:
        """Open a session on the cloud (blocking control round trip).
        Raises a transport-specific error when the cloud rejects it."""
        raise NotImplementedError

    def close(self, req_id: int) -> None:
        """Release the session on the cloud.  Best-effort, non-blocking
        on socket transports."""
        raise NotImplementedError

    def send(self, data: bytes) -> None:
        """Push one uplink chunk frame (raw ``repro.wire`` bytes).  May
        block on connection-level backpressure, never on cloud compute."""
        raise NotImplementedError

    def recv(self, req_id: int, timeout: Optional[float] = None) -> bytes:
        """Block until the request's next downlink frame arrives.

        ``timeout`` bounds the wait in transport-clock seconds; on expiry
        the transport raises :class:`~repro.net.errors.TransportTimeout`
        (a :class:`~repro.net.errors.TransportError`) rather than hanging
        the session.  ``None`` means the transport's own default."""
        raise NotImplementedError

    def snapshot(self, req_id: int):
        """Blocking control round trip: snapshot the session's cloud-side
        recurrent state; returns an opaque handle for :meth:`restore`."""
        raise NotImplementedError

    def restore(self, req_id: int, snap) -> None:
        """Blocking control round trip: roll the session's cloud-side
        recurrent state back to ``snap``."""
        raise NotImplementedError

    def tick(self, seconds: float) -> None:
        """Report ``seconds`` of local device compute.  Transports that
        keep a virtual clock advance it; wall-clock transports ignore it.
        Never blocks."""
        pass

    # ------------------------------------------------- uplink progress acks
    def acked_count(self, req_id: int) -> int:
        """How many of the request's uplink frames the cloud has *processed*
        (a contiguous prefix count).  Non-blocking.

        Transports that cannot observe cloud progress return an effectively
        infinite count, which makes a pipelined sender's bounded window a
        no-op — the legacy unbounded-streaming behavior."""
        return 1 << 62

    def wait_acked(self, req_id: int, count: int,
                   timeout: Optional[float] = None) -> int:
        """Block until at least ``count`` uplink frames of ``req_id`` have
        been processed by the cloud; returns the processed count.

        ``timeout`` is in transport-clock seconds; on expiry transports
        raise :class:`~repro.net.errors.TransportTimeout`.  The default
        implementation never blocks (see :meth:`acked_count`)."""
        return self.acked_count(req_id)


class LoopbackTransport(Transport):
    """In-process wire: frames go straight into the server, ``recv`` pumps
    the engine until the request's downlink frame materializes.  Zero
    latency — the timing-free transport for parity tests and the rebuilt
    ``RealBackend`` (the simulator owns the clock there).

    Every uplink frame is stamped with the transport clock's ``t_send``
    here in the base class (subclasses only move the clock), so trace
    uplink spans and engine job ``ready_s`` values are well-defined on
    every transport."""

    def __init__(self, server: CloudServer, *, tracer: Optional[Tracer] = None):
        self.server = server
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.bytes_up = 0
        self.bytes_down = 0
        self._epoch = time.perf_counter()

    def clock(self) -> float:
        """Wall seconds since this transport was constructed."""
        return time.perf_counter() - self._epoch

    def open(self, req_id: int, expected_tokens: int) -> None:
        """Admit the session on the in-process server; raises
        :class:`RuntimeError` when no slot / KV budget is free."""
        if not self.server.open_session(req_id, expected_tokens):
            raise RuntimeError(
                f"cloud rejected session {req_id}: no free slot / KV budget"
            )

    def close(self, req_id: int) -> None:
        """Release the session on the in-process server.  Never blocks."""
        self.server.close_session(req_id)

    def send(self, data: bytes) -> None:
        """Hand the frame straight to the server (zero wire latency on
        plain loopback; timing subclasses advance their clock first)."""
        self.bytes_up += len(data)
        t0 = self.clock()
        attrs = self._on_uplink(data) or {}
        t1 = self.clock()
        self.tracer.add_span(
            "uplink", t0, t1, tid=frame_req_id(data), phase="uplink",
            nbytes=len(data), **attrs,
        )
        self.server.handle_frame(stamp_t_send(data, t1))

    def has_frame(self, req_id: int) -> bool:
        """Non-blocking: is the request's downlink frame already parked?"""
        return self.server.pending(req_id)

    def deliver(self, req_id: int) -> Optional[bytes]:
        """Non-blocking receive: pop the request's downlink frame (with the
        same byte/clock accounting as ``recv``) or return None.  The
        concurrent scheduler uses this — it owns the engine pump itself."""
        data = self.server.poll(req_id)
        if data is not None:
            self.bytes_down += len(data)
            t0 = self.clock()
            attrs = self._on_downlink(data) or {}
            self.tracer.add_span(
                "downlink", t0, self.clock(), tid=req_id, phase="downlink",
                nbytes=len(data), **attrs,
            )
        return data

    def recv(self, req_id: int, timeout: Optional[float] = None) -> bytes:
        """Pump the engine until the request's downlink frame materializes.

        ``timeout`` is in wall seconds; expiry raises
        :class:`~repro.net.errors.TransportTimeout`, and a pump that can
        never produce the frame raises
        :class:`~repro.net.errors.TransportError` (downlink starvation)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            data = self.deliver(req_id)
            if data is not None:
                return data
            if deadline is not None and time.monotonic() >= deadline:
                raise TransportTimeout("recv", timeout, req_id)
            if self._pump(req_id) == 0:
                raise TransportError(
                    f"downlink starved: no frame in flight for request {req_id}"
                )

    def snapshot(self, req_id: int):
        """Snapshot the session's cloud-side recurrent state (direct call,
        no wire)."""
        return self.server.snapshot_session(req_id)

    def restore(self, req_id: int, snap) -> None:
        """Restore the session's cloud-side recurrent state (direct call,
        no wire)."""
        self.server.restore_session(req_id, snap)

    def acked_count(self, req_id: int) -> int:
        """Real processed-frame count from the in-process server (the
        loopback transport *can* observe cloud progress, so a pipelined
        sender's window is enforced here too).  Non-blocking."""
        return self.server.processed_count(req_id)

    def wait_acked(self, req_id: int, count: int,
                   timeout: Optional[float] = None) -> int:
        """Pump the engine until ``count`` of the request's uplink frames
        have been stepped (timing subclasses advance their virtual clock
        per pump, so the wait costs simulated cloud time)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            acked = self.acked_count(req_id)
            if acked >= count:
                return acked
            if deadline is not None and time.monotonic() >= deadline:
                raise TransportTimeout("wait_acked", timeout, req_id)
            if self._pump(req_id) == 0:
                raise TransportError(
                    f"ack starved: request {req_id} waits for {count} "
                    f"processed frames but only {acked} were ever submitted"
                )
    def _pump(self, req_id: Optional[int] = None) -> int:
        return self.server.pump()

    def _on_uplink(self, data: bytes) -> Optional[Dict]:
        """Advance the clock for an uplink transfer; returns extra span
        attributes (``dev_id``, exact ``dur_s``) or None."""
        return None

    def _on_downlink(self, data: bytes) -> Optional[Dict]:
        return None


class DelayModelTransport(LoopbackTransport):
    """Loopback semantics + simulated wall-clock from ``delay_models.py``.

    Real tensors flow exactly as over :class:`LoopbackTransport`, but the
    transport keeps a clock: uplink/downlink transfers advance it by the
    :class:`NetworkModel` transfer time for the frame's byte size, each
    engine pump advances it by the :class:`CloudDelayModel` delay for the
    batched token count, and the device reports its local compute through
    :meth:`tick`.  A shared :class:`StateMonitor` (when given) sees the same
    observations the paper's cloud would — which is what warms up the Eq. 3
    chunk solver on real runs.  Monitor updates flow through the trace
    spans (``repro.obs.StateMonitorBridge``): pass a shared ``tracer`` that
    already carries a bridge (the runtimes do), or let the transport build
    a private disabled tracer + bridge for its own monitor."""

    def __init__(
        self,
        server: CloudServer,
        *,
        device: DeviceProfile,
        net: Optional[NetworkModel] = None,
        cloud: Optional[CloudDelayModel] = None,
        monitor: Optional[StateMonitor] = None,
        start_s: float = 0.0,
        rng: Optional[np.random.Generator] = None,
        tracer: Optional[Tracer] = None,
    ):
        if tracer is None and monitor is not None:
            tracer = Tracer(enabled=False)      # bridge-only instrumentation
        super().__init__(server, tracer=tracer)
        self.device = device
        self.net = net or NetworkModel(rng or np.random.default_rng(0))
        self.cloud = cloud or CloudDelayModel()
        self.monitor = monitor
        if monitor is not None:
            attach_monitor(self.tracer, monitor)
        self.clock_s = float(start_s)
        self.cloud_step_delays_s: List[float] = []

    def clock(self) -> float:
        """Virtual seconds: transfer times + cloud delays + device ticks."""
        return self.clock_s

    def tick(self, seconds: float) -> None:
        """Advance the virtual clock by ``seconds`` of device compute."""
        self.clock_s += seconds

    def _on_uplink(self, data: bytes) -> Dict:
        # advancing the clock before the base class stamps t_send makes the
        # stamp the frame's send-*complete* time — the cloud scheduler
        # reads it back as the job's ready time
        dur = self.net.up_time(self.device, len(data))
        self.clock_s += dur
        return {"dev_id": self.device.dev_id, "dur_s": dur}

    def _pump(self, req_id: Optional[int] = None) -> int:
        t0 = self.clock_s
        tokens = super()._pump(req_id)
        if tokens > 0:
            delay = self.cloud.delay(tokens)
            self.clock_s += delay
            self.cloud_step_delays_s.append(self.cloud.stage_time(tokens))
            # cloud-wide step span drives μ/η/g through the monitor bridge
            self.tracer.add_span(
                "cloud_step", t0, t0 + delay, tid=TID_CLOUD,
                tokens=tokens, dur_s=delay,
            )
            if req_id is not None:
                # a private pump serves exactly one blocked request: its
                # whole wait is cloud compute (no cross-session queueing)
                self.tracer.add_span(
                    "cloud_wait", t0, t0 + delay, tid=req_id,
                    phase="cloud_step", tokens=tokens,
                )
        return tokens

    def _on_downlink(self, data: bytes) -> Dict:
        dur = self.net.down_time(self.device, len(data))
        self.clock_s += dur
        return {"dev_id": self.device.dev_id, "dur_s": dur}


# ---------------------------------------------------------------------------
# DeviceClient: the device side of the session protocol
# ---------------------------------------------------------------------------


class _WaitFrame(NamedTuple):
    """Yielded by a session coroutine when it needs its next downlink frame.

    The driver answers with ``coro.send(frame_bytes)``.  The blocking
    wrappers answer from ``transport.recv``; the concurrent scheduler parks
    the session and answers after a shared engine pump."""

    req_id: int


class _WaitAck(NamedTuple):
    """Yielded by a pipelined prefill coroutine to bound its in-flight
    chunk window: the session may not send its next chunk until the cloud
    has processed at least ``count`` of its uplink frames.

    The driver answers with ``coro.send(acked_count)``.  Blocking wrappers
    answer from ``transport.wait_acked``; the concurrent scheduler parks
    the session until a shared pump advances the count."""

    req_id: int
    count: int


@dataclass
class _Session:
    req_id: int
    in_cache: Dict
    offset: int = 0
    draft_cache: Optional[Dict] = None
    draft_offset: int = 0
    last_token: int = -1
    last_bonus: int = -1
    topk_last: Optional[np.ndarray] = None
    deep_last: Optional[np.ndarray] = None
    draft_snap: Optional[Dict] = None
    paths: Optional[List[List[int]]] = None
    last_commit: List[int] = field(default_factory=list)
    rounds: int = 0
    drafted: int = 0
    accepted: int = 0


class DeviceClient:
    """The device half of HAT: input submodel + adapter Λ + output head.

    Drives Eq. 3 chunked prefill, Eq. 5 threshold drafting and greedy
    acceptance as a token-streaming generator; every hidden-state hop is a
    serialized ``repro.wire`` frame pushed through the :class:`Transport`.

    ``sd`` picks the decode algorithm: ``"draft"`` (threshold speculative
    decoding — needs ``adapter_params``), ``"medusa"`` (tree verification —
    needs ``medusa_params``), or ``None`` (one verified token per round).
    The default ``"auto"`` infers it from which parameters are present.
    """

    def __init__(
        self,
        split: SplitModels,
        transport: Transport,
        *,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        sd: Optional[str] = "auto",
        pc: Optional[str] = "device",
        pd: bool = True,
        eta: float = 0.6,
        max_draft: int = 8,
        topk: int = 4,
        max_len: int = 512,
        wire_codec: str = "fp16",
        fixed_chunk: int = 128,
        dynamic_chunks: bool = True,
        pipeline_len: int = 1,
        pipeline_depth: int = 0,
        monitor: Optional[StateMonitor] = None,
        profile: Optional[DeviceProfile] = None,
        memory: Optional[jax.Array] = None,
        tracer: Optional[Tracer] = None,
    ):
        self.split = split
        self.cfg = split.cfg
        self.transport = transport
        # default to the transport's tracer so one shared flight recorder
        # sees device compute, wire hops and cloud steps on the same clock
        self.tracer = (
            tracer if tracer is not None
            else getattr(transport, "tracer", None) or NULL_TRACER
        )
        self.codec = get_codec(wire_codec)           # uplink codec
        self.draft_model = (
            DraftModel(split, adapter_params) if adapter_params is not None else None
        )
        self.medusa_params = medusa_params
        if sd == "auto":
            sd = ("draft" if adapter_params is not None
                  else "medusa" if medusa_params is not None else None)
        if sd == "draft" and self.draft_model is None:
            raise ValueError("sd='draft' needs adapter_params")
        if sd == "medusa" and medusa_params is None:
            raise ValueError("sd='medusa' needs medusa_params")
        self.sd = sd
        self.pc = pc
        self.pd = pd
        self.eta = eta
        self.max_draft = max_draft
        self.topk = topk
        self.max_len = max_len
        self.fixed_chunk = fixed_chunk
        self.dynamic_chunks = dynamic_chunks
        self.pipeline_len = pipeline_len
        if pipeline_depth < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got {pipeline_depth}")
        # uplink pipelining (paper Eq. 3's parallel transmission+processing):
        #   0 = stream every chunk without waiting (legacy unbounded window)
        #   1 = wait for each chunk's processing ack before the next send
        #       (strictly sequential: the measured baseline)
        #   D>1 = at most D unprocessed chunks in flight — chunk k+1 leaves
        #       as soon as its shallow compute finishes while the cloud is
        #       still working on chunk k
        self.pipeline_depth = pipeline_depth
        self.monitor = monitor
        self.profile = profile
        self.memory = memory
        self.ssm = has_ssm_state(self.cfg)
        self.sessions: Dict[int, _Session] = {}
        self.finished_stats: Dict[int, Dict[str, float]] = {}
        self._auto_id = itertools.count()

    # --------------------------------------------------------- device clock
    def _tick(
        self, seconds: float, req_id: int = 0, name: str = "device", **attrs
    ) -> None:
        """Charge device compute time: advance the transport clock and
        record the interval as a ``phase="draft"`` span, so on-device work
        (shallow forward, drafting, head) shows up in the delay breakdown.
        The exact ``dur_s`` rides along for the monitor bridge (γ_i)."""
        if self.profile is None:
            return
        t0 = self.transport.clock()
        self.transport.tick(seconds)
        self.tracer.add_span(
            name, t0, t0 + seconds, tid=req_id, phase="draft",
            dev_id=self.profile.dev_id, dur_s=seconds, **attrs,
        )

    # ----------------------------------------------------- coroutine driver
    def _drive(self, coro):
        """Run a session coroutine to completion, answering every
        ``_WaitFrame`` with a blocking ``transport.recv`` and every
        ``_WaitAck`` with a blocking ``transport.wait_acked``.  This is the
        sequential execution mode; the concurrent scheduler drives the same
        coroutines itself so that many sessions interleave through one
        engine."""
        try:
            wait = next(coro)
            while True:
                wait = coro.send(self._answer(wait))
        except StopIteration as e:
            return e.value

    def _answer(self, wait):
        """Blocking answer for one coroutine yield (frame or ack wait)."""
        if isinstance(wait, _WaitAck):
            return self.transport.wait_acked(wait.req_id, wait.count)
        return self.transport.recv(wait.req_id)

    # ------------------------------------------------------------- U round
    def _u_round_gen(self, sess: _Session, tokens: np.ndarray, kind: str):
        """One wire round trip at ``sess.offset``: shallow-forward the
        tokens locally, frame + send the shallow states, yield for the deep
        frame, run the head.  Returns (logits [T, V], deep [T, D])."""
        toks = jnp.asarray(tokens, jnp.int32)[None]
        shallow, sess.in_cache, _ = self.split.input_model.apply(
            self.split.input_params, toks, cache=sess.in_cache,
            offset=sess.offset, memory=self.memory, return_hidden=True,
        )
        if self.profile is not None:
            self._tick(self.profile.shallow_delay(len(tokens)),
                       sess.req_id, "shallow", tokens=len(tokens))
        self.transport.send(encode_hidden(
            self.codec, np.asarray(shallow[0], np.float32),
            req_id=sess.req_id, offset=sess.offset, kind=kind, want_deep=True,
        ))
        data = yield _WaitFrame(sess.req_id)
        deep = decode_hidden(Frame.from_bytes(data), self.cfg.d_model)
        logits = self.split.head_logits(jnp.asarray(deep)[None])
        if self.profile is not None:
            self._tick(self.profile.head_delay(), sess.req_id, "head")
        return np.asarray(logits[0], np.float32), deep

    # -------------------------------------------------------------- prefill
    def _prefill_gen(
        self,
        req_id: int,
        prompt: np.ndarray,
        *,
        expected_new_tokens: int = 128,
    ):
        """Chunked prefill (Eq. 3) for one session; returns the first token.

        Each chunk's shallow states cross as their own ``prefill`` frame —
        earlier chunks ask for no deep states back, the last one does and
        its deep frame feeds the on-device head."""
        if req_id in self.sessions:
            raise ValueError(f"session {req_id} already open")
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + 1 > self.max_len:
            raise ValueError(
                f"prompt of {len(prompt)} tokens cannot fit max_len={self.max_len}"
            )
        self.transport.open(
            req_id, min(len(prompt) + expected_new_tokens, self.max_len)
        )
        sess = _Session(
            req_id=req_id,
            in_cache=self.split.input_model.init_cache(
                self.split.input_params, 1, self.max_len, memory=self.memory
            ),
        )
        self.sessions[req_id] = sess

        dev_id = self.profile.dev_id if self.profile is not None else 0
        mon = self.monitor
        chunks = plan_chunks(
            len(prompt),
            pc=self.pc, dynamic_chunks=self.dynamic_chunks,
            fixed_chunk=self.fixed_chunk,
            hidden_bytes_per_token=self.codec.bytes_per_token(self.cfg.d_model),
            beta_up=mon.device(dev_id).beta_up.get(7.5e6) if mon else 7.5e6,
            g=mon.g.predict if mon else None,
            mu=mon.mu.get(64.0) if mon else 64.0,
            pipeline_len=self.pipeline_len,
            pipeline_depth=self.pipeline_depth,
        )
        t_pf = self.transport.clock()
        depth = self.pipeline_depth
        off = 0
        for i, size in enumerate(chunks):
            toks = jnp.asarray(prompt[off:off + size], jnp.int32)[None]
            shallow, sess.in_cache, _ = self.split.input_model.apply(
                self.split.input_params, toks, cache=sess.in_cache,
                offset=off, memory=self.memory, return_hidden=True,
            )
            if self.profile is not None:
                self._tick(self.profile.shallow_delay(size),
                           req_id, "shallow", tokens=size)
            if depth > 0 and i >= depth:
                # bounded window: after this send at most ``depth`` chunks
                # are unprocessed cloud-side — wait for chunk i-depth's ack
                # (its shallow compute above already overlapped the wait)
                yield _WaitAck(req_id, i - depth + 1)
            self.transport.send(encode_hidden(
                self.codec, np.asarray(shallow[0], np.float32),
                req_id=req_id, offset=off, kind="prefill",
                want_deep=(i == len(chunks) - 1),
            ))
            off += size
        data = yield _WaitFrame(req_id)             # last chunk's deep states
        deep = decode_hidden(Frame.from_bytes(data), self.cfg.d_model)
        logits = self.split.head_logits(jnp.asarray(deep)[None])
        if self.profile is not None:
            self._tick(self.profile.head_delay(), req_id, "head")
        # annotation span (no phase attr): the whole prefill window
        self.tracer.add_span(
            "prefill", t_pf, self.transport.clock(), tid=req_id,
            prompt_len=len(prompt), n_chunks=len(chunks),
        )
        sess.offset = len(prompt)
        sess.deep_last = deep[-1]
        tok = int(np.asarray(logits[0], np.float32)[-1].argmax())
        sess.last_token = tok

        if self.draft_model is not None:
            sess.draft_cache = self.draft_model.init_cache(
                1, self.max_len, memory=self.memory
            )
            _, sess.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(prompt, jnp.int32)[None], cache=sess.draft_cache,
                offset=0, memory=self.memory,
            )
            sess.draft_offset = len(prompt)
        return tok

    def prefill(
        self,
        req_id: int,
        prompt: np.ndarray,
        *,
        expected_new_tokens: int = 128,
    ) -> int:
        """Blocking prefill (drives the coroutine over ``transport.recv``)."""
        return self._drive(self._prefill_gen(
            req_id, prompt, expected_new_tokens=expected_new_tokens
        ))

    # ------------------------------------------------------------- drafting
    def draft(self, req_id: int, max_draft: Optional[int] = None,
              *, charge_time: bool = True) -> List[int]:
        """Eq. 5 threshold drafting with the on-device draft model w_S."""
        sess = self.sessions[req_id]
        if self.draft_model is None:
            return []
        sess.draft_snap = (
            snapshot_states(sess.draft_cache["input"]) if self.ssm else None
        )
        # the verify strip is [last_token, *draft]: never draft past the
        # slot's remaining KV capacity
        room = max(self.max_len - sess.offset - 1, 0)
        budget = min(
            self.max_draft if max_draft is None else max_draft,
            self.max_draft, room,
        )
        if budget <= 0:
            return []
        res, sess.draft_cache, sess.draft_offset = draft_until_threshold(
            self.draft_model, sess.draft_cache,
            jnp.asarray([[sess.last_token]], jnp.int32),
            sess.draft_offset, eta=self.eta,
            max_draft=budget, topk=self.topk, memory=self.memory,
        )
        sess.topk_last = res.topk_last
        if self.profile is not None and charge_time:
            self._tick(self.profile.draft_delay(res.steps),
                       req_id, "draft", steps=res.steps)
        return res.tokens.tolist()

    def parallel_draft_hit(self, req_id: int) -> bool:
        """Eq. 6: was the bonus token among the last draft step's top-k
        (i.e. the next round's draft was already computable in parallel)?"""
        sess = self.sessions.get(req_id)
        if sess is None or sess.topk_last is None:
            return False
        return int(sess.last_bonus) in set(np.asarray(sess.topk_last).tolist())

    # ---------------------------------------------------------- verification
    def _verify_gen(self, req_id: int, draft: List[int]):
        """U-shaped verification of ``draft``; returns (n_accepted, bonus).

        Attention caches roll back positionally (the next round's frames
        overwrite the rejected rows, device- and cloud-side alike).  SSM
        caches carry state: the device snapshots its local input cache and
        asks the cloud — over the transport's control channel — to snapshot
        the slot, then both restore + re-advance the accepted prefix."""
        sess = self.sessions[req_id]
        toks = np.asarray([sess.last_token] + list(draft), np.int32)
        in_snap = snapshot_states(sess.in_cache) if self.ssm else None
        cloud_snap = self.transport.snapshot(req_id) if self.ssm else None
        logits, deep = yield from self._u_round_gen(sess, toks, "verify")
        if draft:
            n, bonus = accept_greedy_rows(np.asarray(draft), logits)
        else:
            n, bonus = 0, int(logits[-1].argmax())
        accepted = 1 + n                     # last_token + accepted drafts
        if self.ssm and n < len(draft):
            sess.in_cache = restore_states(sess.in_cache, in_snap)
            self.transport.restore(req_id, cloud_snap)
            _, deep = yield from self._u_round_gen(sess, toks[:accepted], "verify")
        sess.offset += accepted
        sess.deep_last = deep[accepted - 1]
        if self.draft_model is not None:
            if self.ssm and sess.draft_snap is not None:
                sess.draft_cache["input"] = restore_states(
                    sess.draft_cache["input"], sess.draft_snap
                )
            _, sess.draft_cache, _ = self.draft_model.forward(
                jnp.asarray(toks[:accepted], jnp.int32)[None],
                cache=sess.draft_cache, offset=sess.offset - accepted,
                memory=self.memory,
            )
            sess.draft_offset = sess.offset
        sess.last_bonus = bonus
        sess.last_token = bonus
        sess.rounds += 1
        sess.drafted += len(draft)
        sess.accepted += accepted          # accepted drafts + the bonus token
        sess.last_commit = [*list(draft)[:n], bonus]
        self.tracer.instant(
            "accept", self.transport.clock(), tid=req_id,
            accepted=n, drafted=len(draft),
        )
        return n, bonus

    def verify(self, req_id: int, draft: List[int]) -> Tuple[int, int]:
        """Blocking verification (drives the coroutine over recv)."""
        return self._drive(self._verify_gen(req_id, draft))

    # --------------------------------------------------------------- medusa
    def medusa_tree(self, req_id: int) -> int:
        """Build the session's Medusa candidate tree from its last deep
        state; returns the tree size charged to the wire/cloud."""
        sess = self.sessions[req_id]
        sess.paths = medusa_mod.build_tree_paths(
            self.medusa_params, jnp.asarray(sess.deep_last), tree_size=8
        )
        return 8                       # tree size charged to the wire/cloud

    def _medusa_verify_gen(self, req_id: int):
        sess = self.sessions[req_id]
        paths = sess.paths or [[0]]
        in_snap = snapshot_states(sess.in_cache) if self.ssm else None
        cloud_snap = self.transport.snapshot(req_id) if self.ssm else None
        greedy_rows = []
        for path in paths:
            toks = np.asarray([sess.last_token] + list(path), np.int32)
            if self.ssm:
                sess.in_cache = restore_states(sess.in_cache, in_snap)
                self.transport.restore(req_id, cloud_snap)
            logits, _ = yield from self._u_round_gen(sess, toks, "verify")
            greedy_rows.append(logits.argmax(-1))
            # positional rollback: the next path overwrites the same offsets
        best_pi, n, bonus = medusa_mod.accept_best_path(paths, greedy_rows)
        commit = np.asarray(
            [sess.last_token] + list(paths[best_pi][:n]), np.int32
        )
        if self.ssm:
            sess.in_cache = restore_states(sess.in_cache, in_snap)
            self.transport.restore(req_id, cloud_snap)
        _, deep = yield from self._u_round_gen(sess, commit, "verify")
        sess.offset += len(commit)
        sess.deep_last = deep[-1]
        sess.rounds += 1
        sess.drafted += 4
        sess.accepted += n + 1
        sess.last_commit = [*list(paths[best_pi][:n]), bonus]
        sess.last_token = bonus
        return n, bonus

    def medusa_verify(self, req_id: int) -> Tuple[int, int]:
        """Blocking medusa verification (drives the coroutine over recv)."""
        return self._drive(self._medusa_verify_gen(req_id))

    # ------------------------------------------------------------ lifecycle
    def _decode_round_gen(self, req_id: int):
        """One decode round under the configured algorithm; returns the
        emitted tokens (accepted drafts + bonus — always ≥ 1)."""
        if self.sd == "medusa":
            tree = self.medusa_tree(req_id)
            if self.profile is not None:
                self._tick(self.profile.head_delay() * 4, req_id, "medusa_heads")
            yield from self._medusa_verify_gen(req_id)
            return list(self.sessions[req_id].last_commit)
        if self.sd == "draft":
            sess = self.sessions[req_id]
            pd_hit = (
                self.pd and sess.rounds > 0 and self.parallel_draft_hit(req_id)
            )
            d = self.draft(req_id, charge_time=not pd_hit)
            n, bonus = yield from self._verify_gen(req_id, d)
            return list(self.sessions[req_id].last_commit)
        yield from self._verify_gen(req_id, [])
        return list(self.sessions[req_id].last_commit)

    def step_decode(self, req_id: int) -> List[int]:
        """Blocking decode round (drives the coroutine over recv)."""
        return self._drive(self._decode_round_gen(req_id))

    def finish(self, req_id: int) -> None:
        """Close the session and release its cloud slot."""
        sess = self.sessions.pop(req_id, None)
        if sess is None:
            return
        self.finished_stats[req_id] = {
            "rounds": sess.rounds, "drafted": sess.drafted,
            "accepted": sess.accepted,
        }
        self.transport.close(req_id)

    def session(
        self,
        prompt: np.ndarray,
        *,
        max_new_tokens: int = 128,
        req_id: Optional[int] = None,
        on_token: Optional[Callable[[int], None]] = None,
    ):
        """The full session as a coroutine: prefill + decode rounds.

        Yields :class:`_WaitFrame` whenever the device needs its next deep
        frame; emits tokens through ``on_token`` at the moment they are
        accepted (so the driver can timestamp them against the session's
        own clock).  Closes the session — releasing its cloud slot — on
        exhaustion, KV capacity, and early ``close()`` alike."""
        rid = next(self._auto_id) if req_id is None else req_id
        emit = on_token if on_token is not None else (lambda t: None)
        # a decode round needs cache rows for its verify strip: 1 for the
        # bonus-token round (draft capacity-caps itself), 1 + tree depth
        # for a medusa path commit
        need = 1 + medusa_mod.N_HEADS if self.sd == "medusa" else 1
        try:
            tok = yield from self._prefill_gen(
                rid, prompt, expected_new_tokens=max_new_tokens
            )
            emit(tok)
            emitted = 1
            while emitted < max_new_tokens:
                if self.max_len - self.sessions[rid].offset < need:
                    break                      # KV capacity exhausted
                for tok in (yield from self._decode_round_gen(rid)):
                    emit(tok)
                    emitted += 1
                    if emitted >= max_new_tokens:
                        break
        finally:
            self.finish(rid)

    def generate(
        self,
        prompt: np.ndarray,
        *,
        max_new_tokens: int = 128,
        req_id: Optional[int] = None,
    ) -> Iterator[int]:
        """The session API entry point: stream generated tokens.

        Opens a session, runs chunked prefill, then decode rounds until
        ``max_new_tokens`` tokens have been emitted — or the slot's KV
        capacity (``max_len``) is reached, which ends the stream early
        rather than overflowing the cache.  The session closes on
        exhaustion *and* on early generator close."""
        out: List[int] = []
        coro = self.session(
            prompt, max_new_tokens=max_new_tokens, req_id=req_id,
            on_token=out.append,
        )
        i = 0
        try:
            wait = next(coro)
            while True:
                while i < len(out):
                    yield out[i]
                    i += 1
                wait = coro.send(self._answer(wait))
        except StopIteration:
            while i < len(out):
                yield out[i]
                i += 1
        except SessionLostError as e:
            # graceful degradation: the transport gave up on the session
            # (grace expired / retries exhausted) — hand the caller every
            # token generated so far instead of losing the request
            if not e.partial_tokens:
                e.partial_tokens = list(out)
            raise
        finally:
            coro.close()


# ---------------------------------------------------------------------------
# Runtime: one serve() surface over both execution engines
# ---------------------------------------------------------------------------


class Runtime(Protocol):
    """Anything that can serve a workload and report fleet metrics."""

    def serve(self, requests) -> FleetMetrics:
        """Run the workload to completion; blocks until every request is
        done and returns the fleet-level metrics."""
        ...


class SimulatorRuntime:
    """Discrete-event fleet runtime (statistical or real-model backend).

    All algorithmic components are the real repro.core implementations;
    wall-clock comes from the calibrated delay models.  This is the tool
    for fleet-scale contention studies (Figs. 6–12)."""

    def __init__(
        self,
        config: ServeConfig,
        *,
        backend=None,
        rng: Optional[np.random.Generator] = None,
        cloud: Optional[CloudDelayModel] = None,
        tracer: Optional[Tracer] = None,
    ):
        self.config = config
        self.rng = rng or np.random.default_rng(0)
        self.backend = backend or StatisticalBackend(self.rng)
        config.configure_backend(self.backend)
        self.cloud = cloud or CloudDelayModel(pipeline_len=config.pipeline_len)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.simulator = Simulator(
            config.to_sim_config(), self.cloud, self.backend, self.rng,
            n_devices=config.n_devices, tracer=self.tracer,
        )

    def serve(self, requests) -> FleetMetrics:
        """Submit every spec and run the discrete-event loop to drain;
        blocking, returns when the virtual timeline is exhausted."""
        for r in requests:
            self.simulator.submit(Request(
                req_id=r.req_id, device_id=r.device_id, arrival_s=r.arrival_s,
                prompt_len=r.prompt_len, max_new_tokens=r.max_new_tokens,
                prompt=getattr(r, "prompt", None),
            ))
        return self.simulator.run()


@dataclass
class _EngineSession:
    """One DeviceClient session under the concurrent scheduler."""

    spec: object
    req: Request
    client: DeviceClient
    transport: DelayModelTransport
    coro: object = None
    # the pending yield: a _WaitFrame or _WaitAck (None = runnable)
    wait: Optional[NamedTuple] = None
    frame: Optional[bytes] = None       # delivered, not yet consumed
    started: bool = False
    done: bool = False

    @property
    def clock(self) -> float:
        return self.transport.clock_s

    def runnable(self) -> bool:
        """Can the coroutine advance right now?  Frame waits need their
        frame delivered; ack waits need the cloud's processed count to
        reach the window bound."""
        if self.done:
            return False
        if self.wait is None:
            return True
        if isinstance(self.wait, _WaitAck):
            return (self.transport.acked_count(self.wait.req_id)
                    >= self.wait.count)
        return self.frame is not None


class EngineRuntime:
    """Real-tensor runtime: DeviceClient/CloudServer sessions over a
    :class:`DelayModelTransport`.

    Every token is really computed — shallow states on the device, codec
    frames on the wire, slot-batched middle steps in the engine — while the
    delay models supply simulated wall-clock.

    Two execution modes share the same session coroutines (so they emit
    byte-identical token streams):

    * ``concurrent=True`` (default): an event-driven scheduler drives every
      session as a coroutine against a shared virtual clock.  Whenever all
      live sessions are blocked on a downlink frame, the scheduler runs one
      slot-batched engine step over *everything* queued — so prefill chunks
      and verify strips of different requests batch into one middle-submodel
      step (the paper's cross-device continuous batching), the shared cloud
      pipeline is modeled (batch k+1 may start a stage behind batch k), and
      queueing contention shows up in TTFT/TBT.  Sessions past the slot
      pool wait for a free slot (admission queue).
    * ``concurrent=False``: the legacy sequential mode — each session runs
      to completion on its own clock; engine steps only ever see one
      request.  Kept as the parity baseline.

    A shared :class:`StateMonitor` accumulates across requests, so later
    prefills get warmed-up Eq. 3 chunk sizes."""

    def __init__(
        self,
        config: ServeConfig,
        split: SplitModels,
        *,
        adapter_params: Optional[Params] = None,
        medusa_params: Optional[Params] = None,
        rng: Optional[np.random.Generator] = None,
        n_slots: int = 8,
        max_len: int = 512,
        memory: Optional[jax.Array] = None,
        concurrent: bool = True,
        tracer: Optional[Tracer] = None,
    ):
        if config.sd == "draft" and adapter_params is None:
            raise ValueError(
                f"ServeConfig {config.framework!r} uses sd='draft': "
                "EngineRuntime needs adapter_params"
            )
        if config.sd == "medusa" and medusa_params is None:
            raise ValueError(
                f"ServeConfig {config.framework!r} uses sd='medusa': "
                "EngineRuntime needs medusa_params"
            )
        self.config = config
        self.split = split
        self.adapter_params = adapter_params
        self.medusa_params = medusa_params
        self.rng = rng or np.random.default_rng(0)
        self.n_slots = n_slots
        self.max_len = max_len
        self.memory = memory
        self.concurrent = concurrent
        self.monitor = StateMonitor(alpha=0.8)
        # one shared flight recorder for the whole runtime: device ticks,
        # wire hops, scheduler waits and engine steps land in one trace;
        # a disabled private tracer (the default) still carries the
        # monitor bridge, so the §3.2 EWMAs work with tracing off
        self.tracer = tracer if tracer is not None else Tracer(enabled=False)
        attach_monitor(self.tracer, self.monitor)
        # max_batch_tokens=None passes through: u-shape/u-medusa run the
        # same naive unbudgeted admission on the engine as in the simulator
        # (scheduling.py is the shared policy — the two must not diverge)
        self.server = CloudServer(
            split, n_slots=n_slots, max_len=max_len,
            max_batch_tokens=config.max_batch_tokens,
            wire_codec=config.codec_name, memory=memory,
            tracer=self.tracer,
        )

    # ------------------------------------------------------------- sessions
    def _build_sessions(self, specs) -> List[_EngineSession]:
        """Per-spec DeviceClient sessions, created in spec order so both
        execution modes consume the runtime RNG identically (prompt draws
        and device-mode rotations happen here, before any link sampling)."""
        cfg = self.config
        fleet = make_fleet(self.rng, cfg.n_devices)
        net = NetworkModel(
            self.rng, up_fixed=cfg.uplink_bps, down_fixed=cfg.downlink_bps
        )
        cloud = CloudDelayModel(pipeline_len=cfg.pipeline_len)
        self._cloud_model = cloud
        sd = cfg.sd
        sessions = []
        for spec in specs:
            dev = fleet[spec.device_id % len(fleet)]
            dev.maybe_rotate_mode()
            transport = DelayModelTransport(
                self.server, device=dev, net=net, cloud=cloud,
                monitor=self.monitor, start_s=spec.arrival_s,
                tracer=self.tracer,
            )
            client = DeviceClient(
                self.split, transport,
                adapter_params=self.adapter_params if sd == "draft" else None,
                medusa_params=self.medusa_params if sd == "medusa" else None,
                sd=sd, pc=cfg.pc, pd=cfg.pd, eta=cfg.eta,
                max_draft=cfg.max_draft,
                topk=cfg.topk, max_len=self.max_len,
                wire_codec=cfg.codec_name, fixed_chunk=cfg.fixed_chunk,
                dynamic_chunks=cfg.dynamic_chunks,
                pipeline_len=cfg.pipeline_len,
                pipeline_depth=cfg.pipeline_depth, monitor=self.monitor,
                profile=dev, memory=self.memory,
            )
            prompt = spec.prompt
            if prompt is None:
                prompt = self.rng.integers(
                    3, self.split.cfg.vocab_size, size=spec.prompt_len
                ).astype(np.int32)
            prompt = np.asarray(prompt, np.int32)[: self.max_len // 2]
            req = Request(
                req_id=spec.req_id, device_id=dev.dev_id,
                arrival_s=spec.arrival_s, prompt_len=len(prompt),
                max_new_tokens=spec.max_new_tokens, prompt=prompt,
            )
            req.phase = Phase.DECODE
            sessions.append(_EngineSession(
                spec=spec, req=req, client=client, transport=transport,
            ))
        return sessions

    def _start(self, s: _EngineSession) -> None:
        tr = s.transport
        s.coro = s.client.session(
            s.req.prompt, max_new_tokens=s.spec.max_new_tokens,
            req_id=s.spec.req_id,
            on_token=lambda t: s.req.emit_tokens([t], tr.clock_s),
        )
        s.started = True

    def _finalize(self, s: _EngineSession, metrics: FleetMetrics) -> None:
        s.done = True
        stats = s.client.finished_stats.get(s.spec.req_id, {})
        s.req.rounds = int(stats.get("rounds", 0))
        s.req.drafted = int(stats.get("drafted", 0))
        s.req.accepted = int(stats.get("accepted", 0))
        s.req.phase = Phase.DONE
        s.req.done_s = s.transport.clock_s
        if self.tracer.enabled and s.req.first_token_s is not None:
            # the phase spans tile this session's clock, so the breakdown
            # sums to the measured TTFT (checked by CI's bench smoke)
            s.req.phase_ttft_s = self.tracer.phase_breakdown(
                s.spec.req_id, until=s.req.first_token_s
            )
        metrics.add(s.req)

    # ---------------------------------------------------------------- serve
    def serve(self, requests) -> FleetMetrics:
        """Run every request through real-tensor device/cloud submodels;
        blocking.  Sequential mode drives one session at a time; concurrent
        mode interleaves all sessions into shared slot-batched steps."""
        specs = list(requests)
        metrics = FleetMetrics()
        if not specs:
            return metrics
        steps0 = len(self.server.engine.batched_token_history)
        compiles0 = self.server.engine.jit_compiles
        sessions = self._build_sessions(specs)
        if self.concurrent:
            self._serve_concurrent(sessions, metrics)
        else:
            self._serve_sequential(sessions, metrics)
        metrics.cloud_batch_tokens.extend(
            self.server.engine.batched_token_history[steps0:]
        )
        # per-run delta, consistent with the step/token deltas above
        metrics.engine_jit_compiles = (
            self.server.engine.jit_compiles - compiles0
        )
        return metrics

    def _serve_sequential(self, sessions, metrics: FleetMetrics) -> None:
        for s in sessions:
            self._start(s)
            s.client._drive(s.coro)
            self._finalize(s, metrics)
            metrics.cloud_step_delays_s.extend(s.transport.cloud_step_delays_s)

    # ----------------------------------------------- concurrent scheduler
    def _serve_concurrent(self, sessions, metrics: FleetMetrics) -> None:
        """Event-driven virtual-time loop.

        Invariants: exactly one coroutine advances at a time (JAX stays
        single-threaded); a session is *runnable* when it is not blocked on
        a downlink frame (or its frame has been delivered); the engine is
        pumped when no session is runnable — at which point every queued
        frame has already "arrived" on the virtual clock, so one
        slot-batched step over the whole queue is causally sound — or as
        soon as the queue fills the step's token budget (a full batch gains
        nothing by waiting).  This is a *coalescing window*: the cloud
        trades a little first-frame latency for much fuller steps, which is
        exactly the continuous-batching regime the paper's TTFT/TBT wins
        are measured under.  The runnable session with the earliest clock
        goes first, which makes the interleaving — and therefore the
        RNG-draw order on the shared links — deterministic."""
        kv = self.server.engine.kv
        pending = deque(sorted(
            sessions, key=lambda s: (s.spec.arrival_s, s.spec.req_id)
        ))
        active: List[_EngineSession] = []
        reserved = 0                       # admitted, coroutine not yet begun
        cloud_free_s = 0.0

        def try_admit(now_s: float) -> None:
            nonlocal reserved
            while pending:
                s = pending[0]
                expected = min(
                    len(s.req.prompt) + s.spec.max_new_tokens, self.max_len
                )
                if len(kv.free_slots) - reserved < 1 or not kv.can_admit(expected):
                    break
                pending.popleft()
                s.transport.clock_s = max(s.spec.arrival_s, now_s)
                if s.transport.clock_s > s.spec.arrival_s:
                    # slot-pool admission wait: arrival -> admission
                    self.tracer.add_span(
                        "admission_wait", s.spec.arrival_s,
                        s.transport.clock_s, tid=s.spec.req_id, phase="queue",
                    )
                reserved += 1
                active.append(s)

        def advance(s: _EngineSession) -> None:
            nonlocal reserved
            first = not s.started
            try:
                if first:
                    self._start(s)
                    wait = next(s.coro)          # opens the session (slot held)
                elif isinstance(s.wait, _WaitAck):
                    wait = s.coro.send(
                        s.transport.acked_count(s.wait.req_id)
                    )
                else:
                    data, s.frame = s.frame, None
                    wait = s.coro.send(data)
                s.wait = wait
                # belt-and-braces: a frame can never be parked before the
                # session starts waiting (pumps only run when everyone
                # waits), but delivering here keeps that a local invariant
                if (isinstance(wait, _WaitFrame)
                        and s.transport.has_frame(wait.req_id)):
                    s.frame = s.transport.deliver(wait.req_id)
            except StopIteration:
                s.wait = None
                self._finalize(s, metrics)
                try_admit(s.transport.clock_s)
            finally:
                if first:
                    reserved -= 1                # slot reservation consumed

        try_admit(0.0)
        engine = self.server.engine
        while active or pending:
            runnable = [s for s in active if s.runnable()]
            if runnable:
                # coalescing window: while some device still has compute in
                # flight, the cloud holds its step so that device's frames
                # can join the batch — except when the queue already fills
                # the step's token budget, where waiting buys nothing (an
                # unbudgeted engine never short-circuits: naive batching
                # coalesces everything)
                queued = sum(len(j.hidden) for j in engine.queue)
                waiting_now = [
                    a for a in active if not a.done and a.wait is not None
                ]
                if (waiting_now and engine.max_batch_tokens is not None
                        and queued >= engine.max_batch_tokens):
                    cloud_free_s = self._pump_shared(
                        waiting_now, cloud_free_s, metrics
                    )
                    continue
                s = min(runnable, key=lambda s: (s.clock, s.spec.req_id))
                advance(s)
                active = [a for a in active if not a.done]
                continue
            waiting = [s for s in active if not s.done and s.wait is not None]
            if not waiting:
                if pending:         # all active finished; admit the queue
                    n_before = len(pending)
                    try_admit(cloud_free_s)
                    if len(pending) == n_before:
                        raise RuntimeError(
                            f"admission stalled: {n_before} sessions pending "
                            "but no active session holds a slot (KV budget "
                            "too small for any request?)"
                        )
                    continue
                break
            cloud_free_s = self._pump_shared(waiting, cloud_free_s, metrics)

    def _pump_shared(
        self, waiting, cloud_free_s: float, metrics: FleetMetrics
    ) -> float:
        """One shared engine step + virtual-clock accounting.

        The batch cannot start before its jobs' frames arrived
        (``ready_s``, stamped by the transports) nor while the cloud
        pipeline is busy; successive steps overlap at one pipeline-stage
        cadence (Sarathi-style budgeted admission pipelines microbatches —
        same rule the simulator applies)."""
        engine = self.server.engine
        if not engine.queue:
            starving = sorted(s.spec.req_id for s in waiting)
            raise TransportError(
                f"downlink starved: sessions {starving} wait on frames but "
                "the engine queue is empty"
            )
        tokens = self.server.pump()
        if tokens == 0:
            raise RuntimeError("engine pump made no progress")
        info = engine.last_step_info
        cloud = self._cloud_model
        ready_s = max(j["ready_s"] for j in info)
        start_s = max(cloud_free_s, ready_s)
        full = cloud.delay(tokens)
        stage = cloud.stage_time(tokens)
        done_s = start_s + full
        # cloud-wide step span: drives μ/η/g through the monitor bridge
        # (the exact dur_s keeps EWMA samples identical to sequential mode)
        self.tracer.add_span(
            "cloud_step", start_s, done_s, tid=TID_CLOUD,
            tokens=tokens, dur_s=full, jobs=len(info),
        )
        metrics.cloud_step_delays_s.append(stage)
        def charge_wait(s: _EngineSession, rid: int) -> None:
            # the blocked session's clock jumps to the step's end; split
            # the wait into queue time (before the step ran) and cloud
            # compute so the two parts tile the clock jump exactly
            t_wait = s.transport.clock_s
            jump = max(done_s - t_wait, 0.0)
            cloud_part = min(jump, full)
            queue_part = jump - cloud_part
            if queue_part > 0:
                self.tracer.add_span(
                    "queue_wait", t_wait, t_wait + queue_part,
                    tid=rid, phase="queue", dur_s=queue_part,
                )
            if cloud_part > 0:
                self.tracer.add_span(
                    "cloud_wait", done_s - cloud_part, done_s,
                    tid=rid, phase="cloud_step", dur_s=cloud_part,
                )
            s.transport.clock_s = max(t_wait, done_s)

        for s in waiting:
            if isinstance(s.wait, _WaitAck):
                # window wait: this step may have advanced the session's
                # processed count — charge the blocked time the same way
                # as a frame wait so the phase spans still tile the clock
                if (s.transport.acked_count(s.wait.req_id)
                        >= s.wait.count):
                    charge_wait(s, s.wait.req_id)
                continue
            if s.frame is None and s.transport.has_frame(s.wait.req_id):
                charge_wait(s, s.wait.req_id)
                s.frame = s.transport.deliver(s.wait.req_id)
        # budgeted admission pipelines microbatches at one-stage cadence;
        # naive (unbudgeted) batch-level scheduling can't fully hide the
        # pipeline bubble — the same cadence rule the simulator applies
        bubble = 1.0 if self.server.engine.max_batch_tokens is not None else 2.0
        return start_s + min(bubble * stage, full)


# ---------------------------------------------------------------------------
# legacy wrapper
# ---------------------------------------------------------------------------


def run_fleet(
    framework: str,
    requests,
    *,
    rng: Optional[np.random.Generator] = None,
    pipeline_len: int = 4,
    hidden_bytes: Optional[float] = 4096 * 2,
    backend=None,
    n_devices: int = 30,
    overrides: Optional[dict] = None,
    wire_codec: Optional[str] = None,
) -> FleetMetrics:
    """Deprecated: thin back-compat wrapper over
    ``ServeConfig.from_framework(...)`` + :class:`SimulatorRuntime`.

    New code should build a :class:`ServeConfig` (``ServeConfig.hat()`` and
    friends) and call ``SimulatorRuntime(config, backend=...).serve(reqs)``.
    Codec-vs-``hidden_bytes`` precedence is resolved once by ServeConfig: a
    requested codec switches byte accounting to codec-derived values and
    configures the backend; otherwise the explicit ``hidden_bytes`` applies
    and a backend-supplied codec is left untouched."""
    kw = dict(overrides or {})
    if wire_codec is not None:
        kw.setdefault("wire_codec", wire_codec)
    if (
        "hidden_bytes_per_token" not in kw
        and "wire_codec" not in kw
        and hidden_bytes is not None
    ):
        kw["hidden_bytes_per_token"] = hidden_bytes
    config = ServeConfig.from_framework(
        framework, pipeline_len=pipeline_len, n_devices=n_devices, **kw
    )
    return SimulatorRuntime(config, backend=backend, rng=rng).serve(requests)
