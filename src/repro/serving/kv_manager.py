"""KV-cache capacity management for the batched cloud engine.

TPU adaptation note (DESIGN.md §3): vLLM's PagedAttention block tables are a
GPU pointer idiom; XLA wants static shapes.  The TPU-idiomatic equivalent
(cf. JetStream) is a fixed pool of *slots* with dense per-slot caches plus
block-granular *accounting* for admission control: a request is admitted
only when enough cache blocks are free, blocks are charged as the sequence
grows and released on completion.  This keeps HBM bounded and admission
honest while the physical layout stays static for XLA.

Accounting violations raise typed :class:`KVError` subclasses — never bare
``assert`` — so denial stays loud under ``python -O`` and callers can
distinguish admission pressure (:class:`KVAdmissionError`, retryable) from
accounting corruption (:class:`KVAccountingError`, a bug or a bad restore).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


class KVError(RuntimeError):
    """Base class for slot/KV accounting errors."""


class KVAdmissionError(KVError):
    """Admission denied: no free slot or not enough free blocks.

    Callers that checked :meth:`SlotKVManager.can_admit` first never see
    this; it guards direct ``admit`` calls (and ``-O`` runs, where the old
    bare assert silently vanished and corrupted the budget).
    """


class KVAccountingError(KVError):
    """The accounting books are inconsistent (unknown request, shrinking
    slot pool, or a restored state that does not add up)."""


@dataclass
class KVBudget:
    block_tokens: int = 128          # accounting granularity
    total_blocks: int = 1024         # pool capacity (HBM budget / block size)
    used_blocks: int = 0


class SlotKVManager:
    """Slot allocator + block accountant."""

    def __init__(self, n_slots: int, max_len: int, budget: Optional[KVBudget] = None):
        self.n_slots = n_slots
        self.max_len = max_len
        self.budget = budget or KVBudget()
        self.free_slots: List[int] = list(range(n_slots))
        self.slot_of: Dict[int, int] = {}          # req_id -> slot
        self.blocks_of: Dict[int, int] = {}        # req_id -> charged blocks
        self.len_of: Dict[int, int] = {}           # req_id -> current length
        self.peak_active: int = 0                  # max concurrent sessions seen

    # ----------------------------------------------------------- admission
    def _blocks_for(self, tokens: int) -> int:
        bt = self.budget.block_tokens
        return (tokens + bt - 1) // bt

    def can_admit(self, expected_tokens: int) -> bool:
        if not self.free_slots:
            return False
        need = self._blocks_for(min(expected_tokens, self.max_len))
        return self.budget.used_blocks + need <= self.budget.total_blocks

    def admit(self, req_id: int, expected_tokens: int) -> int:
        if req_id in self.slot_of:
            raise KVAccountingError(f"request {req_id} already admitted")
        if not self.can_admit(expected_tokens):
            raise KVAdmissionError(
                f"admission denied for request {req_id}: "
                f"{len(self.free_slots)} free slots, "
                f"{self.budget.total_blocks - self.budget.used_blocks} free blocks "
                f"(need {self._blocks_for(min(expected_tokens, self.max_len))})")
        slot = self.free_slots.pop(0)
        self.slot_of[req_id] = slot
        need = self._blocks_for(min(expected_tokens, self.max_len))
        self.blocks_of[req_id] = need
        self.budget.used_blocks += need
        self.len_of[req_id] = 0
        self.peak_active = max(self.peak_active, self.active)
        return slot

    # ------------------------------------------------------------- growth
    def extend(self, req_id: int, new_len: int) -> bool:
        """Charge blocks as the sequence grows; False if out of budget."""
        if req_id not in self.blocks_of:
            raise KVAccountingError(f"extend for unadmitted request {req_id}")
        need = self._blocks_for(min(new_len, self.max_len))
        have = self.blocks_of[req_id]
        if need > have:
            delta = need - have
            if self.budget.used_blocks + delta > self.budget.total_blocks:
                return False
            self.budget.used_blocks += delta
            self.blocks_of[req_id] = need
        self.len_of[req_id] = new_len
        return True

    def grow(self, new_n_slots: int) -> None:
        """Enlarge the slot pool (engine auto-grow); block budget unchanged."""
        if new_n_slots < self.n_slots:
            raise KVAccountingError(
                f"cannot shrink slot pool {self.n_slots} -> {new_n_slots}")
        self.free_slots.extend(range(self.n_slots, new_n_slots))
        self.n_slots = new_n_slots

    def release(self, req_id: int) -> None:
        if req_id not in self.slot_of:
            raise KVAccountingError(f"release of unadmitted request {req_id}")
        slot = self.slot_of.pop(req_id)
        self.budget.used_blocks -= self.blocks_of.pop(req_id)
        self.len_of.pop(req_id, None)
        self.free_slots.append(slot)

    @property
    def active(self) -> int:
        return self.n_slots - len(self.free_slots)

    # -------------------------------------------------------- serialization
    def state_dict(self) -> Dict[str, Any]:
        """Plain-data snapshot of the whole accounting state (for the engine
        checkpoint); restore with :meth:`load_state_dict`."""
        return {
            "n_slots": int(self.n_slots),
            "max_len": int(self.max_len),
            "block_tokens": int(self.budget.block_tokens),
            "total_blocks": int(self.budget.total_blocks),
            "used_blocks": int(self.budget.used_blocks),
            "free_slots": [int(s) for s in self.free_slots],
            "slot_of": {int(k): int(v) for k, v in self.slot_of.items()},
            "blocks_of": {int(k): int(v) for k, v in self.blocks_of.items()},
            "len_of": {int(k): int(v) for k, v in self.len_of.items()},
            "peak_active": int(self.peak_active),
        }

    def load_state_dict(self, state: Dict[str, Any]) -> None:
        """Restore accounting from :meth:`state_dict` output, validating the
        books first (raises :class:`KVAccountingError` on inconsistency)."""
        slot_of = {int(k): int(v) for k, v in state["slot_of"].items()}
        blocks_of = {int(k): int(v) for k, v in state["blocks_of"].items()}
        free = [int(s) for s in state["free_slots"]]
        n_slots = int(state["n_slots"])
        used = int(state["used_blocks"])
        if set(blocks_of) != set(slot_of):
            raise KVAccountingError("restored slot_of/blocks_of disagree")
        if used != sum(blocks_of.values()):
            raise KVAccountingError(
                f"restored used_blocks={used} but charges sum to "
                f"{sum(blocks_of.values())}")
        occupied = sorted(slot_of.values())
        if len(set(occupied)) != len(occupied):
            raise KVAccountingError("restored state double-books a slot")
        if sorted(free + occupied) != list(range(n_slots)):
            raise KVAccountingError(
                "restored free/occupied slots do not partition the pool")
        self.n_slots = n_slots
        self.max_len = int(state["max_len"])
        self.budget = KVBudget(
            block_tokens=int(state["block_tokens"]),
            total_blocks=int(state["total_blocks"]),
            used_blocks=used)
        self.free_slots = free
        self.slot_of = slot_of
        self.blocks_of = blocks_of
        self.len_of = {int(k): int(v) for k, v in state["len_of"].items()}
        self.peak_active = int(state["peak_active"])
