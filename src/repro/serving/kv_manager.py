"""KV-cache capacity management for the batched cloud engine.

TPU adaptation note (DESIGN.md §3): vLLM's PagedAttention block tables are a
GPU pointer idiom; XLA wants static shapes.  The TPU-idiomatic equivalent
(cf. JetStream) is a fixed pool of *slots* with dense per-slot caches plus
block-granular *accounting* for admission control: a request is admitted
only when enough cache blocks are free, blocks are charged as the sequence
grows and released on completion.  This keeps HBM bounded and admission
honest while the physical layout stays static for XLA.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class KVBudget:
    block_tokens: int = 128          # accounting granularity
    total_blocks: int = 1024         # pool capacity (HBM budget / block size)
    used_blocks: int = 0


class SlotKVManager:
    """Slot allocator + block accountant."""

    def __init__(self, n_slots: int, max_len: int, budget: Optional[KVBudget] = None):
        self.n_slots = n_slots
        self.max_len = max_len
        self.budget = budget or KVBudget()
        self.free_slots: List[int] = list(range(n_slots))
        self.slot_of: Dict[int, int] = {}          # req_id -> slot
        self.blocks_of: Dict[int, int] = {}        # req_id -> charged blocks
        self.len_of: Dict[int, int] = {}           # req_id -> current length
        self.peak_active: int = 0                  # max concurrent sessions seen

    # ----------------------------------------------------------- admission
    def _blocks_for(self, tokens: int) -> int:
        bt = self.budget.block_tokens
        return (tokens + bt - 1) // bt

    def can_admit(self, expected_tokens: int) -> bool:
        if not self.free_slots:
            return False
        need = self._blocks_for(min(expected_tokens, self.max_len))
        return self.budget.used_blocks + need <= self.budget.total_blocks

    def admit(self, req_id: int, expected_tokens: int) -> int:
        assert self.can_admit(expected_tokens), "admission denied"
        slot = self.free_slots.pop(0)
        self.slot_of[req_id] = slot
        need = self._blocks_for(min(expected_tokens, self.max_len))
        self.blocks_of[req_id] = need
        self.budget.used_blocks += need
        self.len_of[req_id] = 0
        self.peak_active = max(self.peak_active, self.active)
        return slot

    # ------------------------------------------------------------- growth
    def extend(self, req_id: int, new_len: int) -> bool:
        """Charge blocks as the sequence grows; False if out of budget."""
        need = self._blocks_for(min(new_len, self.max_len))
        have = self.blocks_of[req_id]
        if need > have:
            delta = need - have
            if self.budget.used_blocks + delta > self.budget.total_blocks:
                return False
            self.budget.used_blocks += delta
            self.blocks_of[req_id] = need
        self.len_of[req_id] = new_len
        return True

    def grow(self, new_n_slots: int) -> None:
        """Enlarge the slot pool (engine auto-grow); block budget unchanged."""
        assert new_n_slots >= self.n_slots
        self.free_slots.extend(range(self.n_slots, new_n_slots))
        self.n_slots = new_n_slots

    def release(self, req_id: int) -> None:
        slot = self.slot_of.pop(req_id)
        self.budget.used_blocks -= self.blocks_of.pop(req_id)
        self.len_of.pop(req_id, None)
        self.free_slots.append(slot)

    @property
    def active(self) -> int:
        return self.n_slots - len(self.free_slots)
