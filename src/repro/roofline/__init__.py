from .analysis import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    RooflineTerms,
    analyze,
    collective_bytes,
    model_flops,
)

__all__ = [
    "HBM_BW", "ICI_BW", "PEAK_FLOPS_BF16", "RooflineTerms", "analyze",
    "collective_bytes", "model_flops",
]
