"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), TPU v5e constants:

  compute    = FLOPs_per_chip / 197e12
  memory     = HBM_bytes_per_chip / 819e9
  collective = collective_bytes_per_chip / 50e9   (per-link ICI)

FLOPs/bytes come from ``compiled.cost_analysis()`` of the SPMD-partitioned
module — these are *per-partition* (per-chip) quantities, so no further
division by chip count (equivalent to the global-HLO/(chips·peak) form).
Collective bytes are not in cost_analysis: we parse the optimized HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (again per-partition).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %x = bf16[16,128,4096]{2,1,0} all-gather(...)
#       %y = (f32[8,4]{1,0}, f32[8,4]{1,0}) all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[^=]*?\)?)\s+(" + "|".join(_COLLECTIVES) + r")(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes (per partition) from optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue                      # avoid double counting async pairs
        out[kind] += _shape_bytes(type_str)
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    kind: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0          # 6·N·D (train) or 2·N·D (inference)
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def compute_s(self) -> float:
        return self.flops_per_chip / self.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes_per_chip / self.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_chip / self.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO flops summed over chips): catches remat and
        redundant compute (≈1/3 under full remat of a train step)."""
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            useful_flops_ratio=self.useful_flops_ratio,
        )
        return d


def model_flops(cfg, shape, kind: str = None) -> float:
    """Analytic 'useful' FLOPs for the step (instructions: 6·N·D / 6·N_act·D)."""
    n_act = cfg.active_param_count()
    kind = kind or shape.kind
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    if kind == "hat_verify":
        m = cfg.hat_shallow_layers
        frac = 1.0 - m / cfg.n_layers      # middle submodel share
        return 2.0 * n_act * frac * shape.global_batch * 8
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def analyze(
    *, cfg, shape, mesh_name: str, n_chips: int,
    cost: Dict, hlo_text: str, kind: str = None,
) -> RooflineTerms:
    """Loop-corrected accounting from the optimized HLO (hlo_parse):
    XLA's cost_analysis counts scan bodies once, so flops/bytes/collectives
    are re-derived with while-trip multipliers; ``cost`` is kept only as a
    cross-check in the JSON record."""
    from .hlo_parse import analyze_hlo

    c = analyze_hlo(hlo_text)
    return RooflineTerms(
        arch=cfg.name,
        shape=shape.name,
        kind=kind or shape.kind,
        mesh=mesh_name,
        n_chips=n_chips,
        flops_per_chip=c.flops,
        hbm_bytes_per_chip=c.hbm_bytes,
        coll_bytes_per_chip=c.collective_bytes,
        coll_breakdown={k: int(v) for k, v in c.collective_breakdown.items()},
        model_flops=model_flops(cfg, shape, kind=kind),
    )
