"""Optimized-HLO analyzer with loop-aware accounting.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — under a
layer-stacked ``lax.scan`` that undercounts flops/bytes by n_layers.  This
module parses the post-SPMD optimized HLO text, builds the computation call
graph (while bodies x trip count from ``backend_config known_trip_count``,
fusion bodies inline, conditionals x1), and produces loop-corrected
per-chip totals:

  * flops       — dot contractions (the MXU term) wherever they appear,
                  weighted by their computation's execution multiplier;
  * hbm_bytes   — result + operand bytes of *top-level* instructions
                  (ENTRY + while/conditional bodies).  Post-fusion these are
                  the HBM-visible boundaries; fusion internals stay in VMEM;
  * collectives — result bytes of all-gather / all-reduce / reduce-scatter /
                  all-to-all / collective-permute, weighted likewise.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INS_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_DOT_DIMS = {
    "lc": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "rc": re.compile(r"rhs_contracting_dims=\{([0-9,]*)\}"),
    "lb": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
    "rb": re.compile(r"rhs_batch_dims=\{([0-9,]*)\}"),
}


def _shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


# CPU lowering upcasts bf16 compute to f32 (no native bf16 on host).  For
# TPU-roofline byte accounting we count floating tensors at native bf16
# width; deliberate-f32 stats (softmax/optimizer moments) are then counted
# at 2 B too — a mild, documented underestimate (EXPERIMENTS.md §Roofline).
_NATIVE_BYTES = dict(_DTYPE_BYTES)
_NATIVE_BYTES.update({"f32": 2, "f64": 4})


def _bytes_of(type_str: str, native_bf16: bool = False) -> float:
    table = _NATIVE_BYTES if native_bf16 else _DTYPE_BYTES
    total = 0
    for dt, dims in _shapes(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * table[dt]
    return float(total)


@dataclass
class Instruction:
    name: str
    type_str: str
    opcode: str
    args: str                 # text inside the top-level call parens
    line: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    is_entry: bool = False


def _split_call_args(line: str, opcode: str) -> str:
    i = line.find(opcode + "(")
    if i < 0:
        return ""
    j = i + len(opcode) + 1
    depth, out = 1, []
    while j < len(line) and depth:
        ch = line[j]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out.append(ch)
        j += 1
    return "".join(out)


def parse_computations(hlo: str):
    comps: Dict[str, Computation] = {}
    types: Dict[str, str] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        if raw and not raw[0].isspace() and "->" in raw and raw.rstrip().endswith("{"):
            hdr = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", raw)
            if hdr:
                cur = Computation(hdr.group(2), is_entry=bool(hdr.group(1)))
                comps[cur.name] = cur
            continue
        m = _INS_RE.match(raw)
        if m and cur is not None:
            name, type_str, opcode = m.groups()
            args = _split_call_args(raw, opcode)
            ins = Instruction(name, type_str, opcode, args, raw)
            cur.instructions.append(ins)
            types[name] = type_str
    return comps, types


def _trip_count(line: str, comps, cond_name: Optional[str]) -> int:
    m = _TRIP_RE.search(line)
    if m:
        return int(m.group(1))
    if cond_name and cond_name in comps:
        best = 1
        for ins in comps[cond_name].instructions:
            for c in re.finditer(r"constant\((\d+)\)", ins.line):
                best = max(best, int(c.group(1)))
        return best
    return 1


def _multipliers(comps) -> Dict[str, Tuple[float, bool]]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    result: Dict[str, Tuple[float, bool]] = {}

    def visit(name: str, m: float, fused: bool):
        if name not in comps:
            return
        prev = result.get(name)
        if prev is not None and prev[0] >= m:
            return
        result[name] = (m, fused if prev is None else (prev[1] and fused))
        for ins in comps[name].instructions:
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                tc = _trip_count(ins.line, comps, cm.group(1) if cm else None)
                if bm:
                    visit(bm.group(1), m * tc, fused)
                if cm:
                    visit(cm.group(1), m * tc, fused)
            elif ins.opcode == "conditional":
                b = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
                if b:
                    for br in re.findall(r"%?([\w\.\-]+)", b.group(1)):
                        visit(br, m, fused)
            elif ins.opcode == "fusion":
                c = re.search(r"calls=%?([\w\.\-]+)", ins.line)
                if c:
                    visit(c.group(1), m, True)
            else:
                for attr in ("to_apply", "calls"):
                    c = re.search(attr + r"=%?([\w\.\-]+)", ins.line)
                    if c:
                        visit(c.group(1), m, True)

    if entry is not None:
        visit(entry.name, 1.0, False)
    return result


def _dot_flops(ins: Instruction, types: Dict[str, str]) -> float:
    """2 x (output elements) x (contracted extent) from operand shapes."""
    ops = _OPERAND_RE.findall(ins.args)
    if not ops:
        return 0.0
    lhs_t = types.get(ops[0], "")
    lhs_shapes = _shapes(lhs_t)
    if not lhs_shapes:
        return 0.0
    lhs_dims = lhs_shapes[0][1]
    lc = _DOT_DIMS["lc"].search(ins.line)
    k_prod = 1
    if lc:
        for d in [int(x) for x in lc.group(1).split(",") if x]:
            if d < len(lhs_dims):
                k_prod *= lhs_dims[d]
    out_prod = 1
    out_shapes = _shapes(ins.type_str)
    if out_shapes:
        for d in out_shapes[0][1]:
            out_prod *= d
    return 2.0 * out_prod * k_prod


@dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": dict(self.collective_breakdown),
            "n_while": self.n_while, "max_trip": self.max_trip,
        }


_NO_HBM = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
}


def analyze_hlo(hlo: str, native_bf16: bool = True) -> HloCosts:
    comps, types = parse_computations(hlo)
    mult = _multipliers(comps)
    out = HloCosts(collective_breakdown={k: 0.0 for k in _COLLECTIVES})
    for name, comp in comps.items():
        m, fused = mult.get(name, (0.0, True))
        if m == 0.0:
            continue
        for ins in comp.instructions:
            op = ins.opcode
            if op in ("dot", "dot-general"):
                out.flops += m * _dot_flops(ins, types)
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                b = _bytes_of(ins.type_str, native_bf16)
                out.collective_bytes += m * b
                out.collective_breakdown[base] += m * b
            if op == "while":
                out.n_while += 1
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                out.max_trip = max(
                    out.max_trip,
                    _trip_count(ins.line, comps, cm.group(1) if cm else None),
                )
            if not fused and op not in _NO_HBM:
                if _is_pure_convert(ins, comps):
                    continue
                out.hbm_bytes += m * _hbm_bytes_of(ins, types, comps, native_bf16)
    return out


_CONVERT_ONLY = {"parameter", "convert", "bitcast", "copy"}


def _fusion_body(ins: Instruction, comps):
    c = re.search(r"calls=%?([\w\.\-]+)", ins.line)
    if c and c.group(1) in comps:
        return comps[c.group(1)].instructions
    return []


def _fusion_root_opcode(ins: Instruction, comps) -> str:
    body = _fusion_body(ins, comps)
    for b in body:
        if "ROOT" in b.line.split("=")[0]:
            return b.opcode
    return body[-1].opcode if body else ""


def _is_pure_convert(ins: Instruction, comps) -> bool:
    """Fusions that only change dtype — CPU-backend artifacts of bf16
    emulation; identity on TPU, so excluded from HBM accounting."""
    if ins.opcode == "convert":
        return True
    if ins.opcode != "fusion":
        return False
    body = _fusion_body(ins, comps)
    return bool(body) and all(b.opcode in _CONVERT_ONLY for b in body)


def _hbm_bytes_of(ins: Instruction, types, comps, native_bf16: bool = True) -> float:
    """Physical HBM traffic of one top-level instruction.

    Slicing ops touch only the slice, not the sliced buffer; in-place
    dynamic-update-slice (bare or as a fusion root — the layer-scan cache
    write) touches only the update region.  Everything else: result write +
    operand reads at fusion boundaries."""
    op = ins.opcode
    operands = _OPERAND_RE.findall(ins.args)
    if op == "dynamic-slice" or op == "slice":
        return 2.0 * _bytes_of(ins.type_str, native_bf16)   # read + write slice
    if op == "dynamic-update-slice":
        upd = types.get(operands[1], "") if len(operands) > 1 else ""
        return 2.0 * _bytes_of(upd, native_bf16)
    if op == "fusion":
        root = _fusion_root_opcode(ins, comps)
        if root == "dynamic-update-slice":
            # in-place cache write: the physical traffic is the update
            # region ~= the smallest operand (read update + write region)
            small = [
                _bytes_of(types.get(o, ""), native_bf16)
                for o in operands if types.get(o, "")
            ]
            return 2.0 * min(small) if small else 0.0
        if root in ("dynamic-slice", "slice"):
            return 2.0 * _bytes_of(ins.type_str, native_bf16)
        if root == "convert":
            # dtype-sandwich fusions around the cache: pure CPU-backend
            # bf16-emulation churn; the real reads are counted at the
            # consumers (dots/fusions that use the converted buffer)
            return 0.0
        body = _fusion_body(ins, comps)
        ds_bytes = sum(
            _bytes_of(b.type_str, native_bf16)
            for b in body
            if b.opcode in ("dynamic-slice", "slice")
        )
        if ds_bytes:
            # the fusion reads SLICES of its big operands (scan-over-time
            # bodies slicing loop-invariant activations): cap each operand's
            # contribution at result + total sliced bytes
            res = _bytes_of(ins.type_str, native_bf16)
            cap = res + ds_bytes
            b = res
            for o in operands:
                b += min(_bytes_of(types.get(o, ""), native_bf16), cap)
            return b
    b = _bytes_of(ins.type_str, native_bf16)
    for o in operands:
        b += _bytes_of(types.get(o, ""), native_bf16)
    return b
