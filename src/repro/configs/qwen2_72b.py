"""Qwen2-72B — dense GQA with QKV bias [arXiv:2407.10671].

Assigned spec: 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    qkv_bias=True,
    pattern=(LayerDef("attn"),),
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    hat_shallow_layers=2,
    source="arXiv:2407.10671 (Qwen2)",
)
