"""InternLM2-1.8B — dense GQA [arXiv:2403.17297].

Assigned spec: 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92_544,
    pattern=(LayerDef("attn"),),
    rope_theta=1_000_000.0,
    max_seq_len=32_768,
    hat_shallow_layers=2,
    source="arXiv:2403.17297 (InternLM2)",
)
