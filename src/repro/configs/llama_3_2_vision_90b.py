"""Llama-3.2-Vision-90B backbone — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment].

Assigned spec: 100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256.
Cross-attention layers are interleaved every 5th layer (Llama-3.2-Vision
convention): 80 self-attn + 20 cross-attn layers.  The vision frontend
(ViT + projector) is a STUB — ``input_specs`` feeds precomputed patch
embeddings (see DESIGN.md: modality-frontend carve-out).
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28_672,
    vocab_size=128_256,
    pattern=(
        LayerDef("attn"), LayerDef("attn"), LayerDef("attn"), LayerDef("attn"),
        LayerDef("cross_attn"),
    ),
    rope_theta=500_000.0,
    frontend="vision",
    n_frontend_tokens=1601,   # 1 tile x (40x40 patches + cls), 11B-Vision card
    max_seq_len=131_072,
    hat_shallow_layers=2,
    source="hf:meta-llama/Llama-3.2-11B-Vision (backbone scaled to 90B spec)",
)
