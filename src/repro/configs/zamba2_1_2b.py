"""Zamba2-1.2B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

Assigned spec: 38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000,
ssm_state=64.  Zamba2 runs a Mamba2 trunk with a single *shared* (one
parameter set) transformer block invoked periodically; we apply the shared
attention block every 6th layer (6 invocations over 38 layers), matching the
Zamba2 design of reusing one attention block.
"""
from .base import LayerDef, ModelConfig

_PERIOD = (
    LayerDef("mamba2"), LayerDef("mamba2"), LayerDef("mamba2"),
    LayerDef("mamba2"), LayerDef("mamba2"), LayerDef("mamba2", shared_attn=True),
)

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,               # shared attn block's MLP width
    vocab_size=32_000,
    pattern=_PERIOD,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    tie_embeddings=True,
    max_seq_len=1_048_576,
    hat_shallow_layers=2,
    source="arXiv:2411.15242 (Zamba2)",
)
