"""DBRX-132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base].

Assigned spec: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16e top-4.
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    pattern=(LayerDef("moe"),),
    n_experts=16,
    experts_per_token=4,
    d_ff_expert=10_752,
    rope_theta=500_000.0,
    max_seq_len=32_768,
    hat_shallow_layers=2,
    source="hf:databricks/dbrx-base",
)
