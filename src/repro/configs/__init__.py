"""Config registry: ``get_config(arch_id)`` + the assigned shape table."""
from __future__ import annotations

from typing import Dict

from .base import InputShape, LayerDef, ModelConfig, SHAPES, shape_applicable

from .kimi_k2_1t_a32b import CONFIG as _kimi
from .qwen2_72b import CONFIG as _qwen2
from .xlstm_350m import CONFIG as _xlstm
from .llama_3_2_vision_90b import CONFIG as _llama_vis
from .internlm2_1_8b import CONFIG as _internlm2
from .zamba2_1_2b import CONFIG as _zamba2
from .dbrx_132b import CONFIG as _dbrx
from .phi4_mini_3_8b import CONFIG as _phi4
from .gemma3_12b import CONFIG as _gemma3
from .seamless_m4t_large_v2 import CONFIG as _seamless
from .vicuna import VICUNA_7B, VICUNA_13B

CONFIGS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _kimi, _qwen2, _xlstm, _llama_vis, _internlm2,
        _zamba2, _dbrx, _phi4, _gemma3, _seamless,
        VICUNA_7B, VICUNA_13B,
    ]
}

ASSIGNED = [
    "kimi-k2-1t-a32b", "qwen2-72b", "xlstm-350m", "llama-3.2-vision-90b",
    "internlm2-1.8b", "zamba2-1.2b", "dbrx-132b", "phi4-mini-3.8b",
    "gemma3-12b", "seamless-m4t-large-v2",
]


def get_config(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(CONFIGS)}"
        ) from None


__all__ = [
    "ModelConfig", "LayerDef", "InputShape", "SHAPES", "CONFIGS", "ASSIGNED",
    "get_config", "shape_applicable",
]
