"""Gemma3-12B — 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt family card, scaled per assignment].

Assigned spec: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
Pattern: 5 sliding-window (1024) layers then 1 global layer, repeated.
This is the dense arch that qualifies for long_500k: 40/48 layers are
windowed (sub-quadratic), only 8 global layers keep a full KV cache.
"""
from .base import LayerDef, ModelConfig

_W = 1024

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,            # gemma3 uses head_dim 256 (> d_model/n_heads)
    d_ff=15_360,
    vocab_size=262_144,
    pattern=(
        LayerDef("attn", window=_W), LayerDef("attn", window=_W),
        LayerDef("attn", window=_W), LayerDef("attn", window=_W),
        LayerDef("attn", window=_W), LayerDef("attn"),
    ),
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    max_seq_len=1_048_576,   # windowed locals make long-context viable
    hat_shallow_layers=2,
    source="hf:google/gemma-3-1b-pt (gemma3 family)",
)
