"""xLSTM-350M — sLSTM + mLSTM blocks [arXiv:2405.04517].

Assigned spec: 24L d_model=1024 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own up/down projections, there is no
separate FFN sublayer.  Pattern follows the paper's mostly-mLSTM mix with
periodic sLSTM blocks (1 sLSTM per 4-layer period).
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    pattern=(
        LayerDef("mlstm"), LayerDef("mlstm"), LayerDef("mlstm"), LayerDef("slstm"),
    ),
    ssm_expand=2,
    tie_embeddings=True,
    max_seq_len=1_048_576,   # recurrent: O(1) state, unbounded context
    hat_shallow_layers=2,
    source="arXiv:2405.04517 (xLSTM)",
)
