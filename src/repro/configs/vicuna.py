"""Vicuna-7B / Vicuna-13B — the paper's own evaluation models
[Chiang et al. 2023; LLaMA architecture].

Paper §4.1: Vicuna-7B = 32 decoder layers, 32 heads, hidden 4096
(SpecBench); Vicuna-13B = 40 layers, 40 heads, hidden 5120 (CNN/DM).
HAT deploys the first 2 (7B) / 3 (13B) layers + head on-device.
"""
from .base import LayerDef, ModelConfig

VICUNA_7B = ModelConfig(
    name="vicuna-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11_008,
    vocab_size=32_000,
    pattern=(LayerDef("attn"),),
    max_seq_len=4096,
    hat_shallow_layers=2,
    source="Vicuna (LLaMA-7B arch); HAT paper §4.1",
)

VICUNA_13B = ModelConfig(
    name="vicuna-13b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=13_824,
    vocab_size=32_000,
    pattern=(LayerDef("attn"),),
    max_seq_len=4096,
    hat_shallow_layers=3,
    source="Vicuna (LLaMA-13B arch); HAT paper §4.1",
)
