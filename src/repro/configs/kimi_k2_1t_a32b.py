"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2].

Assigned spec: 61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840,
MoE 384 experts top-8.  Layer 0 is dense (DeepSeek-V3/K2 convention); the
remaining 60 layers are MoE with one always-on shared expert.  The assigned
spec's GQA (kv=8) is used verbatim (the released model uses MLA; the
assignment overrides — noted in DESIGN.md).
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=112,
    d_ff=18_432,              # the single dense layer's FFN width (K2 card);
                              # the assigned d_ff=2048 is the per-expert width

    vocab_size=163_840,
    pattern=tuple([LayerDef("attn")] + [LayerDef("moe")] * 60),
    n_experts=384,
    experts_per_token=8,
    d_ff_expert=2048,
    n_shared_experts=1,
    rope_theta=50_000.0,
    max_seq_len=131_072,
    hat_shallow_layers=2,
    source="arXiv:2501.kimi2 (Kimi K2)",
)
