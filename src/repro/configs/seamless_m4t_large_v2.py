"""SeamlessM4T-large-v2 — encoder-decoder, multimodal [arXiv:2308.11596].

Assigned spec: 24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
24 encoder + 24 decoder layers (per the model card, each stack is 24L).
The audio frontend (mel-spectrogram + conv feature extractor) is a STUB —
``input_specs`` feeds precomputed frame embeddings to the encoder (see
DESIGN.md: modality-frontend carve-out).  Decode shapes exercise the text
decoder with cross-attention into a fixed encoder memory.
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                      # decoder stack
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256_206,
    pattern=(LayerDef("cross_attn"),),  # every decoder layer cross-attends
    is_encoder_decoder=True,
    n_encoder_layers=24,
    frontend="audio",
    n_frontend_tokens=1024,           # ~20s of speech at 50 fps
    max_seq_len=8_192,
    hat_shallow_layers=2,
    source="arXiv:2308.11596 (SeamlessM4T v2)",
)
