"""Configuration dataclasses for all model families.

A model is described by a *periodic pattern* of layer blocks (``LayerDef``)
repeated to ``n_layers``.  Grouping identical consecutive layers lets the
model implementation stack their parameters and ``lax.scan`` over them, so
HLO size (and compile time) is O(pattern period), not O(n_layers) — this is
what makes the 61–100 layer production configs lowerable on a laptop-class
container.

Every assigned architecture cites its source in its config module.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer blocks
# ---------------------------------------------------------------------------

# Block kinds understood by repro.models:
#   "attn"        self-attention (+ MLP unless d_ff == 0)
#   "moe"         self-attention + mixture-of-experts FFN
#   "mlstm"       xLSTM matrix-memory block (has its own up/down projection)
#   "slstm"       xLSTM scalar-memory block
#   "mamba2"      Mamba-2 (SSD) block
#   "cross_attn"  self-attention + cross-attention (to frontend memory) + MLP
ATTN_KINDS = ("attn", "moe", "cross_attn")
SSM_KINDS = ("mlstm", "slstm", "mamba2")
VALID_KINDS = ATTN_KINDS + SSM_KINDS


@dataclass(frozen=True)
class LayerDef:
    """One layer of the repeating pattern."""

    kind: str = "attn"
    # Sliding-window size for self-attention (None = full/global attention).
    window: Optional[int] = None
    # Zamba2-style: apply the *shared* (single-parameter-set) attention block
    # after this layer.
    shared_attn: bool = False

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(f"unknown layer kind {self.kind!r}")


def repeat_pattern(pattern: Tuple[LayerDef, ...], n_layers: int) -> Tuple[LayerDef, ...]:
    """Tile ``pattern`` out to exactly ``n_layers`` layers."""
    if n_layers % len(pattern) != 0:
        # allow truncation for odd totals (e.g. 61-layer Kimi = 1 dense + 60 moe)
        reps = n_layers // len(pattern) + 1
        return tuple((pattern * reps)[:n_layers])
    return tuple(pattern * (n_layers // len(pattern)))


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    # --- attention details -------------------------------------------------
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False           # Qwen2 uses QKV bias
    rope_theta: float = 10_000.0
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 32_768

    # --- layer pattern ------------------------------------------------------
    # The period pattern, tiled to n_layers.  Default: all-dense attention.
    pattern: Tuple[LayerDef, ...] = (LayerDef("attn"),)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0             # per-expert FFN width
    n_shared_experts: int = 0        # Kimi-K2/DeepSeek style always-on experts
    router_aux_coef: float = 0.01    # load-balance aux loss weight
    moe_capacity_factor: float = 1.25

    # --- SSM ----------------------------------------------------------------
    ssm_state: int = 0               # Mamba2 state size per head
    ssm_conv: int = 4                # Mamba2 depthwise conv width
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64           # Mamba2 head dim

    # --- encoder-decoder ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # --- modality frontend (STUB: precomputed embeddings in; see DESIGN.md) -
    frontend: Optional[str] = None   # None | "vision" | "audio"
    n_frontend_tokens: int = 0       # patches / frames fed as memory

    # --- submodel construction (U-shaped split derives these) ---------------
    include_embed: bool = True       # token embedding present
    include_head: bool = True        # final norm + LM head present

    # --- HAT (paper) --------------------------------------------------------
    hat_shallow_layers: int = 2      # m: decoder layers on-device
    adapter_layers: int = 1          # depth of adapter network Λ

    # --- provenance ---------------------------------------------------------
    source: str = ""                 # citation for the config

    # ------------------------------------------------------------------ API
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def layers(self) -> Tuple[LayerDef, ...]:
        return repeat_pattern(self.pattern, self.n_layers)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a multiple of 128 so the vocab dim
        shards evenly on the model axis (standard production practice; only
        seamless's 256206 is affected among the assigned archs).  Padded
        logit columns are masked to -inf in the forward."""
        return ((self.vocab_size + 127) // 128) * 128

    @property
    def uses_attention(self) -> bool:
        return any(l.kind in ATTN_KINDS or l.shared_attn for l in self.layers)

    @property
    def full_attention(self) -> bool:
        """True if any layer performs *unwindowed* self-attention."""
        return any(
            l.kind in ATTN_KINDS and l.window is None for l in self.layers
        )

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md §4)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # dense archs qualify only with a sliding-window variant where the
        # vast majority of layers are windowed (gemma3's 5:1 local:global).
        layers = self.layers
        windowed = sum(1 for l in layers if l.kind in ATTN_KINDS and l.window)
        return windowed >= 0.75 * len(layers)

    # --- parameter counting (analytic; used by roofline + reports) ----------
    def param_count(self) -> int:
        d, hd, nh, nkv = self.d_model, self.head_dim, self.n_heads, self.n_kv_heads
        total = self.vocab_size * d                       # embedding
        if not self.tie_embeddings:
            total += d * self.vocab_size                  # lm head
        total += d                                        # final norm

        def attn_params(bias: bool) -> int:
            p = d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d
            if bias:
                p += nh * hd + 2 * nkv * hd
            return p

        def mlp_params(ff: int) -> int:
            return 3 * d * ff                              # SwiGLU

        shared_attn_counted = False
        for l in self.layers:
            total += 2 * d                                 # 2 pre-norms
            if l.kind == "attn":
                total += attn_params(self.qkv_bias)
                if self.d_ff:
                    total += mlp_params(self.d_ff)
            elif l.kind == "cross_attn":
                total += 2 * attn_params(self.qkv_bias) + mlp_params(self.d_ff) + d
            elif l.kind == "moe":
                total += attn_params(self.qkv_bias)
                total += d * self.n_experts                # router
                total += self.n_experts * mlp_params(self.d_ff_expert) // 1
                total += self.n_shared_experts * mlp_params(self.d_ff_expert)
            elif l.kind == "mamba2":
                d_in = self.ssm_expand * d
                nh_ssm = d_in // self.ssm_head_dim
                total += d * (2 * d_in + 2 * self.ssm_state + nh_ssm)
                total += self.ssm_conv * (d_in + 2 * self.ssm_state)
                total += nh_ssm * 2 + d_in                 # A, D, gate norm
                total += d_in * d
            elif l.kind == "mlstm":
                d_in = self.ssm_expand * d
                nh_x = self.n_heads
                total += 2 * d * d_in + d_in * d           # up (x, z-gate) / down
                total += 3 * d_in * d_in                   # q, k, v
                total += 2 * (d_in * nh_x + nh_x)          # i/f gate proj + bias
                total += d_in                              # out norm
            elif l.kind == "slstm":
                nh_x = self.n_heads
                hd_x = d // nh_x
                total += 4 * d * d + 4 * d                 # i,f,z,o input proj
                total += 4 * nh_x * hd_x * hd_x            # head-wise recurrent
                total += d                                 # out norm
            if l.shared_attn and not shared_attn_counted:
                total += attn_params(False) + 2 * d
                shared_attn_counted = True
        if self.is_encoder_decoder:
            # encoder: attn + mlp per layer (non-causal), own final norm
            total += self.n_encoder_layers * (attn_params(False) + mlp_params(self.d_ff) + 2 * d)
            total += d
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed experts)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        per_expert = 3 * self.d_model * self.d_ff_expert
        n_moe_layers = sum(1 for l in self.layers if l.kind == "moe")
        inactive = n_moe_layers * (self.n_experts - self.experts_per_token) * per_expert
        return full - inactive

    # --- reduced variant for CPU smoke tests --------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant: ≤2 layers, d_model≤512, ≤4 experts."""
        d = min(self.d_model, 256)
        nh = max(2, min(self.n_heads, 4))
        nkv = max(1, min(self.n_kv_heads, nh))
        # keep the pattern's *kinds* but only one period, at most 2 layers
        pat = self.pattern[: max(1, min(len(self.pattern), 2))]
        pat = tuple(
            dataclasses.replace(l, window=min(l.window, 16) if l.window else None)
            for l in pat
        )
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, len(pat)),  # >=2 so the U-shaped split applies
            d_model=d,
            n_heads=nh,
            n_kv_heads=nkv,
            head_dim=d // nh,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            pattern=pat,
            n_experts=min(self.n_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            d_ff_expert=min(self.d_ff_expert, 128) if self.d_ff_expert else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_capacity_factor=8.0,   # tiny token counts: avoid drops
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state or self.family == "ssm" else self.ssm_head_dim,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_frontend_tokens=min(self.n_frontend_tokens, 8) if self.n_frontend_tokens else 0,
            max_seq_len=512,
            hat_shallow_layers=1,
        )


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (cfg, shape) should be dry-run; (ok, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §4)"
    return True, ""
