"""Phi-4-mini-3.8B — dense, RoPE + SwiGLU + GQA [arXiv:2412.08905].

Assigned spec: 32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
from .base import LayerDef, ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=200_064,
    pattern=(LayerDef("attn"),),
    tie_embeddings=True,
    rope_theta=10_000.0,
    max_seq_len=131_072,
    hat_shallow_layers=2,
    source="arXiv:2412.08905 (Phi-4 family)",
)
