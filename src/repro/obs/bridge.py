"""StateMonitor <- trace spans: one observation point for the §3.2 EWMAs.

Before the flight recorder, every transport/runtime updated the
:class:`~repro.core.monitor.StateMonitor` from its own ad-hoc call sites.
Now the instrumented hops emit spans and this bridge turns them into the
paper's observations, so tracing and monitoring can never disagree about
what happened on a hop:

    ``uplink`` span    (dev_id, nbytes)  -> β_up  = nbytes / duration
    ``downlink`` span  (dev_id, nbytes)  -> β_down = nbytes / duration
    ``cloud_step`` span (tokens)         -> μ, η, g  (record_batch)
    ``draft`` span     (dev_id, steps)   -> γ_i   = duration / steps

Durations prefer the exact ``dur_s`` attribute over ``t1 - t0``: virtual
clocks place spans at ``t0 + dur``, and the float round-trip
``(t0 + dur) - t0`` can differ from ``dur`` in the last ulp — enough to
perturb EWMA state and break the sequential-vs-concurrent token-parity
guarantee that both modes feed the monitor identical samples.

The bridge fires even when the tracer's ring buffer is disabled (observers
always run), so monitoring works with tracing off.  Note the discrete-event
``Simulator`` intentionally does *not* use the bridge — it feeds its
monitor directly (its zero-duration transfer convention differs) — so do
not attach one to a tracer you pass to ``SimulatorRuntime``.
"""
from __future__ import annotations

from ..core.monitor import StateMonitor
from .tracer import Tracer, TraceEvent


class StateMonitorBridge:
    """Trace observer mapping hop spans onto StateMonitor updates."""

    def __init__(self, monitor: StateMonitor):
        self.monitor = monitor

    def __call__(self, ev: TraceEvent) -> None:
        if ev.ph != "X":
            return
        a = ev.attrs
        dur = a.get("dur_s", ev.t1_s - ev.t0_s)
        if ev.name == "uplink":
            if dur > 0 and "dev_id" in a and "nbytes" in a:
                self.monitor.record_device(
                    a["dev_id"], beta_up=a["nbytes"] / dur
                )
        elif ev.name == "downlink":
            if dur > 0 and "dev_id" in a and "nbytes" in a:
                self.monitor.record_device(
                    a["dev_id"], beta_down=a["nbytes"] / dur
                )
        elif ev.name == "cloud_step":
            if "tokens" in a:
                self.monitor.record_batch(int(a["tokens"]), dur)
        elif ev.name == "draft":
            steps = a.get("steps", 0)
            if dur > 0 and steps and "dev_id" in a:
                self.monitor.record_device(a["dev_id"], gamma=dur / steps)


def attach_monitor(tracer: Tracer, monitor: StateMonitor) -> StateMonitorBridge:
    """Idempotently subscribe a bridge for ``monitor`` on ``tracer``.

    Several components sharing one tracer (a runtime plus its per-session
    transports) each ensure their monitor is bridged; only the first
    subscription sticks, so one hop never produces duplicate EWMA samples."""
    for obs in tracer.observers:
        if isinstance(obs, StateMonitorBridge) and obs.monitor is monitor:
            return obs
    bridge = StateMonitorBridge(monitor)
    tracer.subscribe(bridge)
    return bridge
