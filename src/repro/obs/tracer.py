"""Hop-level flight recorder: span tracing for the device-cloud request path.

HAT's whole argument is a delay budget — TTFT/TBT decompose into draft,
uplink, cloud queue, cloud step, downlink and accept phases (paper Eq. 3,
Figs. 6–12) — so the serving stack records *where* every second went, not
just end-of-run aggregates.  The :class:`Tracer` is a low-overhead ring
buffer of spans/instants/counters that both real wall clocks and the
runtimes' virtual clocks write into, giving one trace format for simulated
and real time:

* ``tracer.add_span(name, t0, t1, tid=req_id, phase="uplink", ...)`` —
  explicit-timestamp spans, used by everything that runs on a *virtual*
  clock (``DelayModelTransport``, the concurrent ``EngineRuntime``
  scheduler, the discrete-event ``Simulator``).
* ``with tracer.span(name): ...`` — wall-clock spans for host-side work
  (``CloudEngine.step``'s batch-build / jit-step / gather phases).
* ``tracer.counter`` / ``tracer.record_hist`` — time series and
  distributions (batched tokens per step, slot occupancy).

Spans carrying a ``phase`` attribute are *delay attribution*: on the
instrumented request path they tile the session's clock exactly (every
clock advance is covered by exactly one phase span), so
:meth:`phase_breakdown` summed over phases equals the request's measured
TTFT/latency — the property ``FleetMetrics.summary``'s
``ttft_breakdown_ms`` table and the CI smoke assertion rely on.

A disabled tracer (``Tracer(enabled=False)``) records nothing but still
notifies subscribed observers — that is how ``StateMonitorBridge`` keeps
feeding the §3.2 EWMAs when tracing is off.  :data:`NULL_TRACER` is the
shared do-nothing default for components constructed without a tracer.
"""
from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np

# Chrome-trace process ids: virtual-time spans (transports, schedulers,
# simulator) and host wall-time spans (engine internals) are different time
# domains — they never share a pid, and the exporter normalizes each pid to
# its own epoch.
PID_VIRTUAL = 1
PID_HOST = 2

# thread id for cloud-wide events (engine steps) in the virtual domain;
# request spans use tid=req_id, so keep this far out of the req_id range
TID_CLOUD = 1_000_000

# the delay-attribution phases of the HAT request path (Eq. 3 terms).
# "draft" covers all on-device compute: shallow forward, drafting, head.
PHASES = ("draft", "uplink", "queue", "cloud_step", "downlink")


@dataclass
class TraceEvent:
    """One recorded event.  ``ph`` follows the Chrome trace phase codes:
    ``"X"`` complete span, ``"i"`` instant, ``"C"`` counter."""

    name: str
    ph: str
    t0_s: float
    t1_s: float
    pid: int
    tid: int
    attrs: Dict = field(default_factory=dict)

    @property
    def dur_s(self) -> float:
        return self.t1_s - self.t0_s

    @property
    def phase(self) -> Optional[str]:
        return self.attrs.get("phase")


class Histogram:
    """Value distribution with percentile summary (trace registry)."""

    def __init__(self):
        self.values: List[float] = []

    def record(self, value: float) -> None:
        self.values.append(float(value))

    def summary(self) -> Dict[str, float]:
        if not self.values:
            return {"count": 0}
        v = np.asarray(self.values)
        return {
            "count": int(len(v)),
            "mean": float(v.mean()),
            "p50": float(np.percentile(v, 50)),
            "p90": float(np.percentile(v, 90)),
            "max": float(v.max()),
        }


class Tracer:
    """Ring-buffered span/event recorder.

    ``capacity`` bounds memory: the oldest events are evicted first and
    counted in :attr:`dropped` (a breakdown computed after eviction of its
    spans would silently under-attribute — check ``dropped == 0`` before
    trusting exact sums).  ``enabled=False`` skips all recording but still
    notifies observers, making the disabled path one attribute check when
    no observers are subscribed.
    """

    def __init__(
        self,
        capacity: int = 1 << 16,
        *,
        enabled: bool = True,
        clock: Callable[[], float] = time.perf_counter,
    ):
        self.enabled = enabled
        self.events: deque = deque(maxlen=capacity)
        self.hists: Dict[str, Histogram] = {}
        self._clock = clock
        self._observers: List[Callable[[TraceEvent], None]] = []
        self._appended = 0

    # ------------------------------------------------------------- recording
    @property
    def dropped(self) -> int:
        """Events evicted from the ring buffer so far."""
        return self._appended - len(self.events)

    def _emit(self, ev: TraceEvent) -> None:
        if self.enabled:
            self.events.append(ev)
            self._appended += 1
        for fn in self._observers:
            fn(ev)

    def add_span(
        self, name: str, t0_s: float, t1_s: float,
        *, tid: int = 0, pid: int = PID_VIRTUAL, **attrs,
    ) -> None:
        """Record a completed span with explicit timestamps (virtual or
        wall clocks alike — the caller owns the time domain via ``pid``)."""
        if not (self.enabled or self._observers):
            return
        self._emit(TraceEvent(name, "X", float(t0_s), float(t1_s),
                              pid, tid, attrs))

    def instant(
        self, name: str, t_s: float,
        *, tid: int = 0, pid: int = PID_VIRTUAL, **attrs,
    ) -> None:
        if not (self.enabled or self._observers):
            return
        self._emit(TraceEvent(name, "i", float(t_s), float(t_s),
                              pid, tid, attrs))

    def counter(
        self, name: str, value: float, t_s: Optional[float] = None,
        *, tid: int = 0, pid: int = PID_HOST,
    ) -> None:
        if not (self.enabled or self._observers):
            return
        t = self._clock() if t_s is None else float(t_s)
        self._emit(TraceEvent(name, "C", t, t, pid, tid,
                              {"value": float(value)}))

    @contextmanager
    def span(self, name: str, *, tid: int = 0, pid: int = PID_HOST, **attrs):
        """Wall-clock span context manager; yields the attrs dict so the
        body can attach results (``a["tokens"] = n``) before close."""
        if not (self.enabled or self._observers):
            yield attrs
            return
        t0 = self._clock()
        try:
            yield attrs
        finally:
            self.add_span(name, t0, self._clock(), tid=tid, pid=pid, **attrs)

    def record_hist(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.hists.setdefault(name, Histogram()).record(value)

    # ------------------------------------------------------------- observers
    def subscribe(self, fn: Callable[[TraceEvent], None]) -> None:
        """Register an observer called on every event (even when recording
        is disabled) — the hook ``StateMonitorBridge`` uses to drive the
        §3.2 EWMAs from the same spans the flight recorder sees."""
        self._observers.append(fn)

    @property
    def observers(self) -> tuple:
        return tuple(self._observers)

    # --------------------------------------------------------------- queries
    def spans(
        self, *, name: Optional[str] = None, tid: Optional[int] = None,
        pid: Optional[int] = None,
    ) -> Iterator[TraceEvent]:
        for ev in self.events:
            if ev.ph != "X":
                continue
            if name is not None and ev.name != name:
                continue
            if tid is not None and ev.tid != tid:
                continue
            if pid is not None and ev.pid != pid:
                continue
            yield ev

    def phase_breakdown(
        self, tid: int, *, until: Optional[float] = None,
    ) -> Dict[str, float]:
        """Per-phase wall-clock attribution for one request (seconds).

        Sums the durations of this tid's phase-attributed spans, clipping
        at ``until`` (pass the request's ``first_token_s`` for the TTFT
        breakdown).  On the instrumented runtimes the phase spans tile or
        *cover* the session clock; overlapping spans — pipelined uplink
        under an in-flight cloud step (``pipeline_depth`` > 1) — are
        attributed once, to the earliest-starting span, so the values
        still sum to the measured latency.  Exact for non-overlapping
        (tiling) spans."""
        marked: List[TraceEvent] = []
        for ev in self.events:
            if ev.ph != "X" or ev.tid != tid:
                continue
            if ev.attrs.get("phase") is None:
                continue
            if until is not None and ev.t0_s >= until:
                continue
            marked.append(ev)
        marked.sort(key=lambda ev: (ev.t0_s, ev.t1_s))
        out: Dict[str, float] = {}
        cover_end = float("-inf")
        for ev in marked:
            t0, t1 = ev.t0_s, ev.t1_s
            if until is not None:
                t1 = min(t1, until)
            contrib = max(0.0, t1 - max(t0, cover_end))
            phase = ev.attrs["phase"]
            out[phase] = out.get(phase, 0.0) + contrib
            cover_end = max(cover_end, t1)
        return out

    # --------------------------------------------------------------- export
    def to_chrome_trace(self) -> dict:
        from .export import to_chrome_trace

        return to_chrome_trace(self)

    def dump(self, path: str) -> None:
        import json

        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f, indent=1)


class NullTracer(Tracer):
    """Do-nothing tracer: the shared default for components constructed
    without one.  Refuses observers — a subscription on the shared
    singleton would silently leak across unrelated runtimes; subscribe to
    a private ``Tracer(enabled=False)`` instead."""

    def __init__(self):
        super().__init__(capacity=1, enabled=False)

    def add_span(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass

    def instant(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass

    def counter(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass

    def record_hist(self, *a, **kw) -> None:  # pragma: no cover - trivial
        pass

    @contextmanager
    def span(self, name: str, **kw):
        yield kw

    def subscribe(self, fn) -> None:
        raise ValueError(
            "NULL_TRACER takes no observers; use a private "
            "Tracer(enabled=False) to bridge without recording"
        )


NULL_TRACER = NullTracer()
