"""Chrome-trace / Perfetto JSON export for the flight recorder.

The output follows the Chrome Trace Event Format (the ``traceEvents``
array form), so a dump opens directly in ``chrome://tracing`` or
https://ui.perfetto.dev.  Layout:

* pid :data:`~repro.obs.tracer.PID_VIRTUAL` — the fleet on virtual time:
  one thread row per request (tid = req_id) carrying its phase spans, plus
  a ``cloud`` row (tid = :data:`~repro.obs.tracer.TID_CLOUD`) with the
  batched engine steps.
* pid :data:`~repro.obs.tracer.PID_HOST` — host wall time: the engine's
  batch-build / jit-step / gather spans and counters.

The two pids are different time domains (a virtual second is not a wall
second), so timestamps are normalized to each pid's own epoch; rows within
a pid are mutually comparable, rows across pids are not.

``schemaVersion`` is the trace format contract: consumers
(``scripts/render_trace.py``, the CI smoke assertion) check it before
reading anything else and must be bumped together with layout changes.
"""
from __future__ import annotations

from typing import Dict, List

from .tracer import PID_HOST, PID_VIRTUAL, TID_CLOUD, Tracer

TRACE_SCHEMA_VERSION = 1

PROCESS_NAMES = {
    PID_VIRTUAL: "fleet (virtual time)",
    PID_HOST: "engine host (wall time)",
}


def _jsonable(v):
    """Chrome trace args must be plain JSON — collapse numpy scalars."""
    if hasattr(v, "item"):
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def _thread_name(pid: int, tid: int) -> str:
    if tid == TID_CLOUD:
        return "cloud"
    if pid == PID_VIRTUAL:
        return f"req {tid}"
    return "engine" if tid == 0 else f"host {tid}"


def to_chrome_trace(tracer: Tracer) -> dict:
    events = list(tracer.events)
    epoch: Dict[int, float] = {}
    for ev in events:
        epoch[ev.pid] = min(epoch.get(ev.pid, ev.t0_s), ev.t0_s)

    trace_events: List[dict] = []
    for pid, name in sorted(PROCESS_NAMES.items()):
        if pid in epoch:
            trace_events.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": name},
            })
    for pid, tid in sorted({(ev.pid, ev.tid) for ev in events}):
        trace_events.append({
            "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": _thread_name(pid, tid)},
        })

    for ev in events:
        ts_us = (ev.t0_s - epoch[ev.pid]) * 1e6
        args = {k: _jsonable(v) for k, v in ev.attrs.items()}
        rec = {
            "name": ev.name, "ph": ev.ph, "ts": ts_us,
            "pid": ev.pid, "tid": ev.tid,
        }
        if ev.ph == "X":
            rec["dur"] = max(ev.t1_s - ev.t0_s, 0.0) * 1e6
            rec["cat"] = args.get("phase", "span")
            rec["args"] = args
        elif ev.ph == "i":
            rec["s"] = "t"                      # thread-scoped instant
            rec["args"] = args
        else:                                   # "C" counter
            rec["args"] = {ev.name: args.get("value", 0.0)}
        trace_events.append(rec)

    return {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": trace_events,
        "otherData": {
            "droppedEvents": tracer.dropped,
            "histograms": {k: h.summary() for k, h in tracer.hists.items()},
        },
    }


def validate_chrome_trace(obj: dict) -> None:
    """Cheap structural check used by tests and the render script; raises
    ``ValueError`` on format drift."""
    if obj.get("schemaVersion") != TRACE_SCHEMA_VERSION:
        raise ValueError(
            f"trace schemaVersion {obj.get('schemaVersion')!r} != "
            f"{TRACE_SCHEMA_VERSION} (format drift?)"
        )
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        raise ValueError("traceEvents missing or not a list")
    for ev in evs:
        if "ph" not in ev or "pid" not in ev or "tid" not in ev:
            raise ValueError(f"malformed trace event: {ev!r}")
        if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
            raise ValueError(f"span event missing ts/dur: {ev!r}")
