"""Chrome-trace / Perfetto JSON export for the flight recorder.

The output follows the Chrome Trace Event Format (the ``traceEvents``
array form), so a dump opens directly in ``chrome://tracing`` or
https://ui.perfetto.dev.  Layout:

* pid :data:`~repro.obs.tracer.PID_VIRTUAL` — the fleet on virtual time:
  one thread row per request (tid = req_id) carrying its phase spans, plus
  a ``cloud`` row (tid = :data:`~repro.obs.tracer.TID_CLOUD`) with the
  batched engine steps.
* pid :data:`~repro.obs.tracer.PID_HOST` — host wall time: the engine's
  batch-build / jit-step / gather spans and counters.

The two pids are different time domains (a virtual second is not a wall
second), so timestamps are normalized to each pid's own epoch; rows within
a pid are mutually comparable, rows across pids are not.

``schemaVersion`` is the trace format contract: consumers
(``scripts/render_trace.py``, the CI smoke assertion) check it before
reading anything else and must be bumped together with layout changes.
"""
from __future__ import annotations

from typing import Dict, List

from .tracer import PID_HOST, PID_VIRTUAL, TID_CLOUD, Tracer

TRACE_SCHEMA_VERSION = 1

# pid spacing used by merge_chrome_traces: input k keeps its internal pid
# layout shifted by k * stride, so `pid % MERGE_PID_STRIDE` recovers the
# original pid role (virtual/host) in a merged trace
MERGE_PID_STRIDE = 10

PROCESS_NAMES = {
    PID_VIRTUAL: "fleet (virtual time)",
    PID_HOST: "engine host (wall time)",
}


def _jsonable(v):
    """Chrome trace args must be plain JSON — collapse numpy scalars."""
    if hasattr(v, "item"):
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return v


def _thread_name(pid: int, tid: int) -> str:
    if tid == TID_CLOUD:
        return "cloud"
    if pid == PID_VIRTUAL:
        return f"req {tid}"
    return "engine" if tid == 0 else f"host {tid}"


def to_chrome_trace(tracer: Tracer) -> dict:
    events = list(tracer.events)
    epoch: Dict[int, float] = {}
    for ev in events:
        epoch[ev.pid] = min(epoch.get(ev.pid, ev.t0_s), ev.t0_s)

    trace_events: List[dict] = []
    for pid, name in sorted(PROCESS_NAMES.items()):
        if pid in epoch:
            trace_events.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": name},
            })
    for pid, tid in sorted({(ev.pid, ev.tid) for ev in events}):
        trace_events.append({
            "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": _thread_name(pid, tid)},
        })

    for ev in events:
        ts_us = (ev.t0_s - epoch[ev.pid]) * 1e6
        args = {k: _jsonable(v) for k, v in ev.attrs.items()}
        rec = {
            "name": ev.name, "ph": ev.ph, "ts": ts_us,
            "pid": ev.pid, "tid": ev.tid,
        }
        if ev.ph == "X":
            rec["dur"] = max(ev.t1_s - ev.t0_s, 0.0) * 1e6
            rec["cat"] = args.get("phase", "span")
            rec["args"] = args
        elif ev.ph == "i":
            rec["s"] = "t"                      # thread-scoped instant
            rec["args"] = args
        else:                                   # "C" counter
            rec["args"] = {ev.name: args.get("value", 0.0)}
        trace_events.append(rec)

    return {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": trace_events,
        "otherData": {
            "droppedEvents": tracer.dropped,
            "histograms": {k: h.summary() for k, h in tracer.hists.items()},
        },
    }


def merge_chrome_traces(objs, labels=None) -> dict:
    """Merge per-process trace dumps into one Chrome trace.

    Real multi-process serving writes one trace per process (cloud service,
    each device worker), and every process uses the same small pid space
    (:data:`PID_VIRTUAL`, :data:`PID_HOST`) — concatenating them naively
    would interleave unrelated processes in one lane.  This remaps each
    input's pids onto a disjoint range (input k keeps its internal pid
    layout, shifted to ``k * stride``), prefixes process names with the
    input's label, namespaces histograms, and sums dropped-event counts.

    Every input must already pass :func:`validate_chrome_trace`; the merged
    object does too (same ``schemaVersion`` — merging relabels, it does not
    reshape events)."""
    objs = list(objs)
    if labels is None:
        labels = [f"proc{k}" for k in range(len(objs))]
    if len(labels) != len(objs):
        raise ValueError(f"{len(objs)} traces but {len(labels)} labels")
    stride = MERGE_PID_STRIDE
    events: List[dict] = []
    dropped = 0
    hists: Dict[str, dict] = {}
    for k, (obj, label) in enumerate(zip(objs, labels)):
        validate_chrome_trace(obj)
        base = k * stride
        for ev in obj["traceEvents"]:
            if ev["pid"] >= stride:
                raise ValueError(
                    f"trace {label!r} uses pid {ev['pid']} >= stride {stride}"
                )
            ev = dict(ev)
            ev["pid"] += base
            if ev.get("ph") == "M" and ev.get("name") == "process_name":
                ev["args"] = {"name": f"{label}: {ev['args']['name']}"}
            events.append(ev)
        other = obj.get("otherData", {})
        dropped += other.get("droppedEvents", 0)
        for name, h in other.get("histograms", {}).items():
            hists[f"{label}/{name}"] = h
    return {
        "schemaVersion": TRACE_SCHEMA_VERSION,
        "displayTimeUnit": "ms",
        "traceEvents": events,
        "otherData": {"droppedEvents": dropped, "histograms": hists},
    }


def validate_chrome_trace(obj: dict) -> None:
    """Cheap structural check used by tests and the render script; raises
    ``ValueError`` on format drift."""
    if obj.get("schemaVersion") != TRACE_SCHEMA_VERSION:
        raise ValueError(
            f"trace schemaVersion {obj.get('schemaVersion')!r} != "
            f"{TRACE_SCHEMA_VERSION} (format drift?)"
        )
    evs = obj.get("traceEvents")
    if not isinstance(evs, list):
        raise ValueError("traceEvents missing or not a list")
    for ev in evs:
        if "ph" not in ev or "pid" not in ev or "tid" not in ev:
            raise ValueError(f"malformed trace event: {ev!r}")
        if ev["ph"] == "X" and ("ts" not in ev or "dur" not in ev):
            raise ValueError(f"span event missing ts/dur: {ev!r}")
