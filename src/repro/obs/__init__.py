from .bridge import StateMonitorBridge, attach_monitor
from .export import (
    MERGE_PID_STRIDE,
    PROCESS_NAMES,
    TRACE_SCHEMA_VERSION,
    merge_chrome_traces,
    to_chrome_trace,
    validate_chrome_trace,
)
from .tracer import (
    NULL_TRACER,
    PHASES,
    PID_HOST,
    PID_VIRTUAL,
    TID_CLOUD,
    Histogram,
    NullTracer,
    TraceEvent,
    Tracer,
)

__all__ = [
    "StateMonitorBridge", "attach_monitor",
    "MERGE_PID_STRIDE", "PROCESS_NAMES", "TRACE_SCHEMA_VERSION",
    "merge_chrome_traces", "to_chrome_trace", "validate_chrome_trace",
    "NULL_TRACER", "PHASES", "PID_HOST", "PID_VIRTUAL", "TID_CLOUD",
    "Histogram", "NullTracer", "TraceEvent", "Tracer",
]
