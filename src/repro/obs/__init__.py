from .bridge import StateMonitorBridge, attach_monitor
from .export import (
    PROCESS_NAMES,
    TRACE_SCHEMA_VERSION,
    to_chrome_trace,
    validate_chrome_trace,
)
from .tracer import (
    NULL_TRACER,
    PHASES,
    PID_HOST,
    PID_VIRTUAL,
    TID_CLOUD,
    Histogram,
    NullTracer,
    TraceEvent,
    Tracer,
)

__all__ = [
    "StateMonitorBridge", "attach_monitor",
    "PROCESS_NAMES", "TRACE_SCHEMA_VERSION", "to_chrome_trace",
    "validate_chrome_trace",
    "NULL_TRACER", "PHASES", "PID_HOST", "PID_VIRTUAL", "TID_CLOUD",
    "Histogram", "NullTracer", "TraceEvent", "Tracer",
]
