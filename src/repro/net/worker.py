"""Device worker: one real device process running DeviceClient over TCP.

The worker builds the same split model as the cloud process (same arch +
seed => bit-identical params), connects a :class:`SocketTransport`, and
streams its share of the workload through ``DeviceClient.generate`` —
every hidden-state hop a codec frame over a real socket.  TTFT/TBT are
**measured wall clock** (``time.time()`` deltas around really-arriving
frames), not delay-model output.

    PYTHONPATH=src python -m repro.net.worker --host 127.0.0.1 --port 5555 \
        --device-index 0 --requests 2 --out dev0.json

Results land in ``--out`` as JSON (per-request token streams + timings) so
the launcher can aggregate across device processes and assert token parity
against an in-process loopback run; ``--trace-out`` dumps the device-side
flight-recorder trace for the cross-process merge.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List, Optional

import numpy as np

from ..obs import Tracer
from ..serving.api import DeviceClient, Transport
from ..serving.request import Request
from .errors import SessionLostError


def device_specs(cfg, device_index: int, *, n_requests: int, prompt_len: int,
                 new_tokens: int, seed: int = 0) -> List:
    """The worker's deterministic slice of the workload.

    Prompts derive only from (seed, device_index, request index) — the
    loopback parity baseline regenerates the identical specs in-process
    without any cross-process coordination.  req_ids are partitioned per
    device (device k owns [1000k+1, 1000k+n]) so concurrent devices never
    collide on the shared engine."""
    from ..data import RequestSpec

    rng = np.random.default_rng(10_000 * (seed + 1) + device_index)
    return [
        RequestSpec(
            req_id=1000 * device_index + i + 1, device_id=device_index,
            arrival_s=0.0, prompt_len=prompt_len, max_new_tokens=new_tokens,
            prompt=rng.integers(3, cfg.vocab_size, prompt_len).astype(np.int32),
        )
        for i in range(n_requests)
    ]


def run_device_workload(client: DeviceClient, transport: Transport,
                        specs) -> List[Request]:
    """Stream every spec through the client; timestamps come from the
    transport clock, so the same driver measures real wall time over
    sockets and zero time over loopback."""
    out: List[Request] = []
    for spec in specs:
        req = Request(
            req_id=spec.req_id, device_id=spec.device_id,
            arrival_s=transport.clock(), prompt_len=len(spec.prompt),
            max_new_tokens=spec.max_new_tokens, prompt=spec.prompt,
        )
        try:
            for tok in client.generate(spec.prompt,
                                       max_new_tokens=spec.max_new_tokens,
                                       req_id=spec.req_id):
                req.emit_tokens([tok], transport.clock())
        except SessionLostError as e:
            # graceful degradation: keep the tokens the session produced
            # before the cloud gave up on it and move on to the next spec
            req.degraded = True
            extra = e.partial_tokens[len(req.generated):]
            if extra:
                req.emit_tokens(extra, transport.clock())
        req.done_s = transport.clock()
        out.append(req)
    return out


def build_client(arch: str, transport: Transport, *, max_len: int,
                 wire_codec: str, draft: bool, seed: int = 0,
                 pipeline_depth: int = 0,
                 tracer: Optional[Tracer] = None) -> DeviceClient:
    """Deterministic device-side build, mirroring the cloud's
    ``build_server`` (same arch + seed => the same split params)."""
    import jax

    from ..configs import get_config
    from ..core import init_adapter, split_model
    from ..models import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    split = split_model(cfg, params)
    adapter = None
    if draft:
        adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    return DeviceClient(
        split, transport,
        adapter_params=adapter, sd="draft" if draft else None,
        max_len=max_len, wire_codec=wire_codec,
        fixed_chunk=16, dynamic_chunks=False,
        pipeline_depth=pipeline_depth,
        tracer=tracer,
    )


def main(argv=None) -> int:
    # SIGUSR1 dumps every thread's stack to stderr (the worker log) — the
    # first tool to reach for when a storm run wedges on a loaded host
    import faulthandler
    import signal as _signal
    faulthandler.register(_signal.SIGUSR1, all_threads=True)

    ap = argparse.ArgumentParser(description="repro.net device worker process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--device-index", type=int, default=0)
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--wire-codec", default="fp16")
    ap.add_argument("--draft", action="store_true",
                    help="threshold speculative decoding (adapter drafting)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="uplink prefill window: 0 = unbounded streaming, "
                         "1 = sequential (ack per chunk), D>1 = at most D "
                         "unprocessed chunks in flight")
    ap.add_argument("--connect-timeout", type=float, default=60.0)
    ap.add_argument("--recv-timeout", type=float, default=120.0,
                    help="per-frame downlink deadline (covers cold-start "
                         "jit compiles in the cloud process)")
    ap.add_argument("--retry-attempts", type=int, default=6,
                    help="reconnect attempts per disconnect (0 = first "
                         "drop is fatal)")
    ap.add_argument("--retry-base-s", type=float, default=0.05,
                    help="base backoff before the first reconnect attempt")
    ap.add_argument("--retry-seed", type=int, default=0,
                    help="jitter seed (same seed => same backoff schedule)")
    ap.add_argument("--out", default=None, help="result JSON path")
    ap.add_argument("--trace-out", default=None,
                    help="dump this device's Chrome trace")
    args = ap.parse_args(argv)

    from ..configs import get_config
    from .policy import Deadline, RetryPolicy
    from .transport import SocketTransport

    cfg = get_config(args.arch).reduced()
    tracer = Tracer(clock=time.time) if args.trace_out else None
    transport = SocketTransport(
        args.host, args.port, d_model=cfg.d_model,
        connect_timeout_s=args.connect_timeout,
        recv_timeout_s=args.recv_timeout,
        retry=RetryPolicy(max_attempts=args.retry_attempts,
                          base_s=args.retry_base_s, seed=args.retry_seed),
        deadline=Deadline(op_timeout_s=args.recv_timeout),
        tracer=tracer,
    )
    client = build_client(
        args.arch, transport, max_len=args.max_len,
        wire_codec=args.wire_codec, draft=args.draft, seed=args.seed,
        pipeline_depth=args.pipeline_depth,
        tracer=tracer,
    )
    specs = device_specs(
        cfg, args.device_index, n_requests=args.requests,
        prompt_len=args.prompt_len, new_tokens=args.new_tokens,
        seed=args.seed,
    )
    t0 = time.time()
    requests = run_device_workload(client, transport, specs)
    wall_s = time.time() - t0
    transport.shutdown()

    result = {
        "device_index": args.device_index,
        "arch": args.arch,
        "wire_codec": args.wire_codec,
        "pipeline_depth": args.pipeline_depth,
        "wall_s": wall_s,
        "bytes_up": transport.bytes_up,
        "bytes_down": transport.bytes_down,
        "reconnects": transport.reconnects,
        "replayed_frames": transport.replayed_frames,
        "dup_frames_dropped": transport.dup_frames_dropped,
        "busy_signals": transport.busy_signals,
        "cloud_restarts_seen": transport.cloud_restarts_seen,
        "requests_degraded": sum(1 for r in requests if r.degraded),
        "requests": [
            {
                "req_id": r.req_id,
                "prompt_len": r.prompt_len,
                "tokens": list(r.generated),
                "ttft_s": r.ttft_s,
                "tbt_s": r.tbt_s,
                "token_times_s": list(r.token_times_s),
                "degraded": r.degraded,
            }
            for r in requests
        ],
    }
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    if tracer is not None:
        tracer.dump(args.trace_out)
    ttfts = [r.ttft_s for r in requests if r.ttft_s is not None]
    print(f"NET_WORKER {args.device_index} done: {len(requests)} requests, "
          f"mean TTFT {1e3 * float(np.mean(ttfts)):.1f}ms, "
          f"{transport.bytes_up} B up / {transport.bytes_down} B down, "
          f"{transport.reconnects} reconnects / "
          f"{transport.replayed_frames} replayed frames",
          flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
