"""Deterministic fault injection for the device-cloud network path.

Two layers, both driven by explicit, seedable fault schedules so a
"flaky network" run is exactly reproducible:

* :class:`ChaosProxy` — a TCP proxy that sits between device processes
  and a :class:`~repro.net.service.CloudService`.  It speaks the
  ``repro.net.protocol`` stream (decode → re-encode canonically per
  message), counts ``MSG_FRAME`` hops per direction per connection, and
  applies :class:`FaultEvent`\\ s at exact hop indices: **drop** the
  connection, **delay** a frame, **duplicate** it, or **truncate** it
  mid-message and kill the link.  Because faults land on message
  boundaries counted from connection start, the same schedule produces
  the same failure at the same point in the same request every run.
  It can also carry the **cloud-kill** trigger (``on_cloud_kill`` +
  seeded ``kill_after_open_oks``/``kill_after_up_frames`` thresholds,
  see :func:`seeded_kill_after_frames`): once the fleet has opened
  enough sessions and pushed enough uplink frames, the callback fires
  exactly once — the launcher uses it to SIGKILL and checkpoint-restore
  the cloud process mid-run.  ``upstream_retry_s`` > 0 makes the proxy
  retry refused upstream connects, so devices reconnecting during the
  restart window wait inside one handshake instead of burning retries.
* :class:`FaultyTransport` — an in-process wrapper around any
  :class:`~repro.serving.api.Transport` that raises
  :class:`~repro.net.errors.TransportClosed` / sleeps at exact
  ``send``/``recv`` call counts, for unit tests that don't want sockets.

Every applied fault is appended to ``.faults`` (and emitted as a
``fault`` instant through the tracer), so tests can assert the schedule
actually fired — a chaos test that silently injects nothing is worse
than no test.

Standalone (the CI chaos-smoke job uses this through the launcher)::

    python -m repro.net.chaos --upstream 127.0.0.1:5555 --port 0 \\
        --seed 7 --drops 2
"""
from __future__ import annotations

import argparse
import queue
import random
import socket
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from ..obs import NULL_TRACER, Tracer
from . import protocol as P
from .errors import TransportClosed

_ACCEPT_POLL_S = 0.2

KIND_DROP = "drop"
KIND_DELAY = "delay"
KIND_DUP = "dup"
KIND_TRUNCATE = "truncate"
KIND_CLOUD_KILL = "cloud_kill"


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fires on the ``at_hop``-th ``MSG_FRAME``
    (0-based) flowing in ``direction`` ("up" = device→cloud)."""

    kind: str                    # drop | delay | dup | truncate
    at_hop: int
    direction: str = "up"
    delay_s: float = 0.0


def seeded_schedule(
    seed: int,
    *,
    connections: int = 1,
    drops_per_conn: int = 1,
    max_hop: int = 3,
    direction: str = "up",
) -> Dict[int, List[FaultEvent]]:
    """Deterministic drop schedule: for each *initial* connection index,
    ``drops_per_conn`` connection drops at seeded hops in [0, max_hop].

    Only the first ``connections`` connection indices get faults —
    reconnects land on later indices and pass clean, so a finite retry
    policy always converges."""
    rng = random.Random(seed)
    schedule: Dict[int, List[FaultEvent]] = {}
    for conn in range(connections):
        hops = sorted(rng.randint(0, max_hop) for _ in range(drops_per_conn))
        # a dropped connection restarts hop counting on reconnect; only
        # the first scheduled drop per connection index can ever fire,
        # so spread multi-drop schedules across the reconnect indices
        events = [FaultEvent(KIND_DROP, at_hop=h, direction=direction)
                  for h in hops[:1]]
        for extra, h in enumerate(hops[1:]):
            idx = conn + connections * (extra + 1)
            schedule.setdefault(idx, []).append(
                FaultEvent(KIND_DROP, at_hop=h, direction=direction))
        schedule.setdefault(conn, []).extend(events)
    return schedule


def seeded_kill_after_frames(seed: int, n_devices: int = 1,
                             lo: int = 1, hi: int = 3) -> int:
    """Deterministic uplink-frame threshold for the cloud-kill trigger:
    between ``lo`` and ``hi`` frames *per device*, drawn from ``seed`` —
    mid-run for any fleet size, same hop for the same seed every run."""
    per_dev = random.Random(seed).randint(lo, hi)
    return per_dev * max(n_devices, 1)


class _Pair:
    """A proxied connection: client socket + upstream socket + state."""

    def __init__(self, index: int, client: socket.socket,
                 upstream: socket.socket, events: List[FaultEvent]):
        self.index = index
        self.client = client
        self.upstream = upstream
        self.events = list(events)
        self.lock = threading.Lock()
        self.closed = False

    def kill(self) -> None:
        with self.lock:
            if self.closed:
                return
            self.closed = True
        for sock in (self.client, self.upstream):
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass


class _DelayedSender:
    """Order-preserving delayed delivery for one proxied direction.

    Each queued message is sent no earlier than its deadline; deadlines
    are forced monotonic so the byte order of the TCP stream is
    preserved.  Because the forwarding thread keeps parsing while
    earlier messages wait here, many frames can be "in flight" at once —
    propagation delay, not serialization."""

    def __init__(self, pair: _Pair, dst: socket.socket):
        self.pair = pair
        self.dst = dst
        self._q: "queue.Queue" = queue.Queue()
        self._last_deadline = 0.0
        self.thread = threading.Thread(target=self._run, daemon=True,
                                       name="chaos-delay-send")
        self.thread.start()

    def put(self, data: bytes, delay_s: float) -> None:
        deadline = time.monotonic() + delay_s
        self._last_deadline = max(self._last_deadline, deadline)
        self._q.put((self._last_deadline, data))

    def kill_after_drain(self) -> None:
        """Deliver everything queued so far, then kill the pair."""
        self._q.put((self._last_deadline, None))

    def _run(self) -> None:
        while True:
            deadline, data = self._q.get()
            if data is None:
                self.pair.kill()
                return
            wait = deadline - time.monotonic()
            if wait > 0:
                time.sleep(wait)
            try:
                self.dst.sendall(data)
            except OSError:
                self.pair.kill()
                return


class ChaosProxy:
    """Fault-injecting TCP proxy in front of a ``CloudService``.

    ``schedule`` maps *connection index* (0-based, in accept order) to
    the fault events for that connection.  Reconnects get fresh indices,
    so a schedule like ``{0: [drop@hop 1]}`` drops the first connection
    once and lets the resumed connection run clean."""

    def __init__(
        self,
        upstream_host: str,
        upstream_port: int,
        *,
        schedule: Optional[Dict[int, List[FaultEvent]]] = None,
        up_frame_delay_s: float = 0.0,
        down_frame_delay_s: float = 0.0,
        host: str = "127.0.0.1",
        port: int = 0,
        tracer: Optional[Tracer] = None,
        kill_after_open_oks: int = 0,
        kill_after_up_frames: int = 0,
        on_cloud_kill: Optional[Callable[[], None]] = None,
        upstream_retry_s: float = 0.0,
    ):
        self.upstream_host = upstream_host
        self.upstream_port = upstream_port
        # link shaping: a constant per-MSG_FRAME propagation delay per
        # direction (seconds).  Each frame is *delivered* delay_s after it
        # arrives at the proxy, but many frames can be in flight at once
        # (an order-preserving delivery queue, not a sleep in the
        # forwarding thread) — so this models WAN latency, which a
        # pipelined sender can hide, not link bandwidth, which it cannot.
        # Control messages (acks, busy/ready, pings) are never delayed,
        # though stream order is always preserved.
        self.up_frame_delay_s = up_frame_delay_s
        self.down_frame_delay_s = down_frame_delay_s
        self.schedule = {k: list(v) for k, v in (schedule or {}).items()}
        self.host = host
        self.port = port
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.faults: List[dict] = []     # applied events, in firing order
        self.connections = 0
        self._listener: Optional[socket.socket] = None
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self._pairs: List[_Pair] = []
        self._lock = threading.Lock()
        # cloud-kill trigger: fire on_cloud_kill once, after the fleet has
        # opened kill_after_open_oks sessions (MSG_OPEN_OK observed on the
        # downlink — the cloud provably registered them) AND has pushed
        # kill_after_up_frames uplink MSG_FRAMEs total.  Seeded thresholds
        # (seeded_kill_after_frames) make the kill land at the same point
        # in the same run every time.
        self.kill_after_open_oks = kill_after_open_oks
        self.kill_after_up_frames = kill_after_up_frames
        self.on_cloud_kill = on_cloud_kill
        # how long to keep retrying a refused upstream connect before
        # giving up on the client: > 0 lets reconnecting devices sit in
        # their handshake wait while a killed cloud's successor boots
        self.upstream_retry_s = upstream_retry_s
        self.open_oks_seen = 0
        self.up_frames_seen = 0
        self._kill_fired = False

    # ------------------------------------------------------------ lifecycle
    def start(self) -> Tuple[str, int]:
        """Bind + start accepting; returns the (host, port) devices should
        connect to (ephemeral port resolved)."""
        ls = socket.create_server((self.host, self.port))
        ls.settimeout(_ACCEPT_POLL_S)
        self._listener = ls
        self.port = ls.getsockname()[1]
        t = threading.Thread(target=self._accept_loop, daemon=True,
                             name="chaos-accept")
        t.start()
        self._threads.append(t)
        return self.host, self.port

    def stop(self) -> None:
        """Kill every proxied connection and join the forwarding threads
        (blocks up to ~5 s per thread)."""
        self._stop.set()
        if self._listener is not None:
            self._listener.close()
        for pair in list(self._pairs):
            pair.kill()
        for t in self._threads:
            t.join(timeout=5.0)

    # ----------------------------------------------------------- forwarding
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                client, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            index = self.connections
            self.connections += 1
            # upstream connect (and its retry window, when a killed cloud's
            # successor is still booting) must not block the accept loop:
            # other reconnecting devices need their pairs set up in parallel
            t = threading.Thread(
                target=self._setup_pair, args=(client, index),
                daemon=True, name=f"chaos-setup-{index}",
            )
            t.start()
            self._threads.append(t)

    def _connect_upstream(self) -> socket.socket:
        deadline = time.monotonic() + self.upstream_retry_s
        while True:
            try:
                return socket.create_connection(
                    (self.upstream_host, self.upstream_port), timeout=10.0
                )
            except OSError:
                if (self._stop.is_set()
                        or time.monotonic() >= deadline):
                    raise
                time.sleep(0.1)

    def _setup_pair(self, client: socket.socket, index: int) -> None:
        try:
            upstream = self._connect_upstream()
        except OSError:
            client.close()
            return
        for sock in (client, upstream):
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        pair = _Pair(index, client, upstream,
                     self.schedule.get(index, []))
        with self._lock:
            self._pairs.append(pair)
        for direction, src, dst in (("up", client, upstream),
                                    ("down", upstream, client)):
            t = threading.Thread(
                target=self._forward, args=(pair, direction, src, dst),
                daemon=True, name=f"chaos-{index}-{direction}",
            )
            t.start()
            self._threads.append(t)

    def _forward(self, pair: _Pair, direction: str,
                 src: socket.socket, dst: socket.socket) -> None:
        decoder = P.StreamDecoder()
        hop = 0
        base = (self.up_frame_delay_s if direction == "up"
                else self.down_frame_delay_s)
        sender = _DelayedSender(pair, dst) if base > 0.0 else None

        def emit(data: bytes, delay_s: float) -> None:
            if sender is not None:
                sender.put(data, delay_s)
            elif delay_s > 0.0:
                time.sleep(delay_s)
                dst.sendall(data)
            else:
                dst.sendall(data)

        def kill() -> None:
            if sender is not None:
                sender.kill_after_drain()     # in-flight frames deliver
            else:
                pair.kill()

        src.settimeout(_ACCEPT_POLL_S)
        try:
            while not self._stop.is_set() and not pair.closed:
                try:
                    chunk = src.recv(1 << 20)
                except socket.timeout:
                    continue
                except OSError:
                    break
                if not chunk:
                    break
                for mtype, payload in decoder.feed(chunk):
                    data = P.encode_msg(mtype, payload)
                    if mtype != P.MSG_FRAME:
                        emit(data, 0.0)       # order kept, never delayed
                        if direction == "down" and mtype == P.MSG_OPEN_OK:
                            with self._lock:
                                self.open_oks_seen += 1
                            self._maybe_fire_kill()
                        continue
                    if direction == "up":
                        with self._lock:
                            self.up_frames_seen += 1
                        self._maybe_fire_kill()
                    event = self._pop_event(pair, direction, hop)
                    hop += 1
                    if event is None:
                        emit(data, base)
                    elif event.kind == KIND_DELAY:
                        emit(data, base + event.delay_s)
                    elif event.kind == KIND_DUP:
                        emit(data, base)
                        emit(data, base)
                    elif event.kind == KIND_TRUNCATE:
                        emit(data[: max(len(data) // 2, 1)], base)
                        kill()
                        return
                    elif event.kind == KIND_DROP:
                        kill()
                        return
                    else:
                        emit(data, base)
        except OSError:
            pass
        finally:
            kill()

    def _maybe_fire_kill(self) -> None:
        """Fire the (single) cloud-kill trigger once both seeded
        thresholds are met; the callback runs on the forwarding thread —
        it must only *schedule* the kill (the launcher's supervisor
        restarts the cloud on its own thread)."""
        if self.on_cloud_kill is None:
            return
        with self._lock:
            if self._kill_fired:
                return
            if self.open_oks_seen < self.kill_after_open_oks:
                return
            if self.up_frames_seen < self.kill_after_up_frames:
                return
            self._kill_fired = True
            record = {"kind": KIND_CLOUD_KILL,
                      "open_oks": self.open_oks_seen,
                      "up_frames": self.up_frames_seen}
            self.faults.append(record)
        self.tracer.instant(
            "fault", time.time(), tid=0, kind=KIND_CLOUD_KILL,
            open_oks=record["open_oks"], up_frames=record["up_frames"],
        )
        self.on_cloud_kill()

    def _pop_event(self, pair: _Pair, direction: str,
                   hop: int) -> Optional[FaultEvent]:
        with pair.lock:
            for i, ev in enumerate(pair.events):
                if ev.direction == direction and ev.at_hop == hop:
                    del pair.events[i]
                    break
            else:
                return None
        record = {"conn": pair.index, "direction": direction,
                  "hop": hop, "kind": ev.kind}
        self.faults.append(record)
        self.tracer.instant(
            "fault", time.time(), tid=0,
            kind=ev.kind, conn=pair.index, hop=hop, direction=direction,
        )
        return ev


class FaultyTransport:
    """In-process fault wrapper around any Transport: raises
    :class:`TransportClosed` / sleeps at exact ``send``/``recv`` call
    indices (0-based), delegating everything else to the wrapped
    transport.  For unit tests that want deterministic faults without
    sockets."""

    def __init__(
        self,
        inner,
        *,
        fail_sends: Tuple[int, ...] = (),
        fail_recvs: Tuple[int, ...] = (),
        delay_sends: Optional[Dict[int, float]] = None,
        delay_recvs: Optional[Dict[int, float]] = None,
    ):
        self.inner = inner
        self.fail_sends = set(fail_sends)
        self.fail_recvs = set(fail_recvs)
        self.delay_sends = dict(delay_sends or {})
        self.delay_recvs = dict(delay_recvs or {})
        self.sends = 0
        self.recvs = 0
        self.faults: List[dict] = []

    def send(self, data: bytes) -> None:
        """Delegate to the wrapped transport, raising
        :class:`TransportClosed` / sleeping at scheduled send indices."""
        idx = self.sends
        self.sends += 1
        if idx in self.delay_sends:
            time.sleep(self.delay_sends[idx])
        if idx in self.fail_sends:
            self.faults.append({"op": "send", "index": idx, "kind": KIND_DROP})
            raise TransportClosed(f"injected fault at send #{idx}")
        self.inner.send(data)

    def recv(self, req_id: int, timeout: Optional[float] = None) -> bytes:
        """Delegate to the wrapped transport, raising
        :class:`TransportClosed` / sleeping at scheduled recv indices."""
        idx = self.recvs
        self.recvs += 1
        if idx in self.delay_recvs:
            time.sleep(self.delay_recvs[idx])
        if idx in self.fail_recvs:
            self.faults.append({"op": "recv", "index": idx, "kind": KIND_DROP})
            raise TransportClosed(f"injected fault at recv #{idx}")
        return self.inner.recv(req_id, timeout)

    def __getattr__(self, name):
        return getattr(self.inner, name)


# ---------------------------------------------------------------------------
# process entry point (standalone proxy)
# ---------------------------------------------------------------------------


def main(argv=None) -> int:
    """Run a standalone seeded-drop proxy until interrupted (the CLI
    entry point; prints a grep-able listen line like the service)."""
    ap = argparse.ArgumentParser(
        description="fault-injecting TCP proxy for repro.net")
    ap.add_argument("--upstream", required=True, help="HOST:PORT of the cloud")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--connections", type=int, default=1,
                    help="how many initial connections get faults")
    ap.add_argument("--drops", type=int, default=1,
                    help="connection drops per faulted connection")
    ap.add_argument("--max-hop", type=int, default=3)
    args = ap.parse_args(argv)

    up_host, up_port = args.upstream.rsplit(":", 1)
    schedule = seeded_schedule(
        args.seed, connections=args.connections,
        drops_per_conn=args.drops, max_hop=args.max_hop,
    )
    proxy = ChaosProxy(up_host, int(up_port), schedule=schedule,
                       host=args.host, port=args.port)
    host, port = proxy.start()
    # same grep-able shape as the service's listen line
    print(f"NET_CHAOS listening on {host}:{port}", flush=True)
    try:
        while True:
            time.sleep(0.5)
    except KeyboardInterrupt:
        pass
    finally:
        proxy.stop()
        print(f"NET_CHAOS done: {len(proxy.faults)} faults over "
              f"{proxy.connections} connections", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
