"""repro.net — real multi-process serving over TCP sockets.

The simulated stack (``DelayModelTransport``) models the link; this
package replaces the model with the thing itself: a length-prefixed
stream protocol carrying ``repro.wire`` frames plus typed control
messages, a :class:`SocketTransport` device endpoint, a
:class:`CloudService` server process, and a launcher that spawns
1 cloud + N device processes on localhost.  TTFT/TBT measured through
this path are wall-clock, not simulated.  Faults are first-class:
sessions resume over reconnect (``MSG_RESUME`` watermarks), retry
behavior is a typed :class:`~repro.net.policy.RetryPolicy` /
:class:`~repro.net.policy.Deadline`, and :mod:`repro.net.chaos`
injects deterministic connection drops / frame faults for tests.

Import layout: :mod:`~repro.net.errors`, :mod:`~repro.net.policy` and
:mod:`~repro.net.protocol` are dependency-free and imported eagerly
(``repro.serving.api`` pulls the error hierarchy and policies in).
Everything that imports ``repro.serving`` back — transport, service,
chaos, worker, launcher — is exposed lazily via module ``__getattr__``
to keep the import graph acyclic.
"""
from __future__ import annotations

from . import errors, policy, protocol
from .errors import (
    ProtocolError,
    RemoteEngineError,
    SessionLostError,
    TransportClosed,
    TransportError,
    TransportTimeout,
)
from .policy import Deadline, RetryPolicy
from .protocol import PROTO_VERSION, StreamDecoder

_LAZY = {
    "SocketTransport": ("transport", "SocketTransport"),
    "CloudService": ("service", "CloudService"),
    "build_server": ("service", "build_server"),
    "run_cluster": ("launcher", "run_cluster"),
    "spawn_cloud": ("launcher", "spawn_cloud"),
    "spawn_worker": ("launcher", "spawn_worker"),
    "device_specs": ("worker", "device_specs"),
    "run_device_workload": ("worker", "run_device_workload"),
    "build_client": ("worker", "build_client"),
    "ChaosProxy": ("chaos", "ChaosProxy"),
    "FaultEvent": ("chaos", "FaultEvent"),
    "FaultyTransport": ("chaos", "FaultyTransport"),
    "seeded_schedule": ("chaos", "seeded_schedule"),
}

__all__ = [
    "errors", "policy", "protocol",
    "ProtocolError", "RemoteEngineError", "SessionLostError",
    "TransportClosed", "TransportError", "TransportTimeout",
    "Deadline", "RetryPolicy",
    "PROTO_VERSION", "StreamDecoder",
    *_LAZY,
]


def __getattr__(name: str):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    mod = importlib.import_module(f".{mod_name}", __name__)
    value = getattr(mod, attr)
    globals()[name] = value
    return value
