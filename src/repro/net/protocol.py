"""Length-prefixed stream protocol for the device-cloud TCP wire.

``repro.wire`` frames are self-delimiting, but a TCP byte stream needs an
envelope that also carries *control* traffic — session lifecycle, version
negotiation, SSM snapshot/restore, and typed errors (so an
``EngineOverflowError`` raised inside the cloud process reaches the device
as data instead of a hung socket).  Every message on the stream is::

    magic   2s  b"HN"
    type    B   MSG_* constant
    length  I   payload byte length (little-endian)
    payload length bytes

Message types and payloads:

========================  =====================================================
``MSG_HELLO``             ``<HHIII`` proto version, wire-frame version,
                          d_model, epoch, restart_epoch (both 0 from the
                          device; ignored by the cloud) — first message on
                          every connection, device -> cloud
``MSG_HELLO_ACK``         same struct, the cloud's values; the epoch field
                          carries the *connection epoch* the cloud just
                          assigned, and restart_epoch counts how many times
                          this cloud endpoint has restored from a checkpoint
                          (a device that sees it change knows it is talking
                          to a new process).  Negotiation is exact-match on
                          the first three fields: any mismatch answers
                          ``MSG_ERROR`` + close instead
``MSG_RESUME``            ``<II`` prev_epoch, n, then n x ``<III`` (req_id,
                          up_sent, down_recv) — sent right after the hello on
                          a *re*connect: re-attach the listed sessions,
                          presenting the epoch they were last owned under and
                          each session's frame-sequence watermarks
``MSG_RESUME_OK``         ``<I`` n, then n x ``<II`` (req_id, up_recv) — the
                          sessions that survived (cloud-side uplink watermark
                          tells the device which frames to replay); sessions
                          missing from the reply are lost
``MSG_OPEN``              ``<II`` req_id, expected_tokens — open a session
``MSG_OPEN_OK``           ``<I`` req_id — slot + KV admitted
``MSG_CLOSE``             ``<I`` req_id — release the session (no reply)
``MSG_FRAME``             ``<I`` session-scoped frame sequence number, then
                          raw ``repro.wire`` frame bytes (uplink chunk frames
                          device -> cloud, deep-state frames cloud -> device).
                          The receiver drops seqs below its watermark
                          (replay/duplication-safe) and treats gaps as
                          protocol errors
``MSG_SNAPSHOT``          ``<I`` req_id — snapshot the slot's recurrent state
``MSG_SNAPSHOT_OK``       ``<II`` req_id, snap_id — handle to a cloud-held
                          snapshot (state never crosses the wire)
``MSG_RESTORE``           ``<II`` req_id, snap_id
``MSG_RESTORE_OK``        ``<I`` req_id
``MSG_ERROR``             ``<HI`` ERR_* code, req_id (0 = connection-wide),
                          then a utf-8 message
``MSG_BYE``               empty — graceful device goodbye
``MSG_PING``              empty — liveness probe (either direction)
``MSG_PONG``              empty — probe answer
``MSG_BUSY``              ``<I`` inflight count — connection-level push-back:
                          the cloud's reader stopped draining this connection
``MSG_READY``             empty — push-back released
``MSG_FRAME_ACK``         ``<II`` req_id, up_processed — cloud -> device
                          progress watermark: the engine has consumed the
                          first ``up_processed`` uplink frames of the session
                          (a contiguous prefix).  Lets a pipelined device
                          prune its replay buffer and bound its in-flight
                          chunk window without waiting for a downlink frame
========================  =====================================================

:class:`StreamDecoder` is the receive half: feed it arbitrary byte chunks
(torn reads, coalesced messages — TCP guarantees neither message
boundaries nor chunk sizes) and it yields complete ``(type, payload)``
messages, rejecting bad magic and oversized lengths with
:class:`~repro.net.errors.ProtocolError` before buffering unbounded data.
"""
from __future__ import annotations

import struct
from typing import Iterator, List, Tuple

from .errors import ProtocolError

# v2: resume handshake (epoch in hello, MSG_RESUME/-OK), per-session frame
# sequence numbers on MSG_FRAME, liveness probes, connection push-back
# v3: MSG_FRAME_ACK uplink progress watermarks (pipelined chunk uplink)
# v4: restart_epoch in hello/ack — sessions survive a cloud *process*
#     restart from a checkpoint, and resume validates against the new one
PROTO_VERSION = 4
MAGIC = b"HN"

MSG_HELLO = 1
MSG_HELLO_ACK = 2
MSG_OPEN = 3
MSG_OPEN_OK = 4
MSG_CLOSE = 5
MSG_FRAME = 6
MSG_SNAPSHOT = 7
MSG_SNAPSHOT_OK = 8
MSG_RESTORE = 9
MSG_RESTORE_OK = 10
MSG_ERROR = 11
MSG_BYE = 12
MSG_RESUME = 13
MSG_RESUME_OK = 14
MSG_PING = 15
MSG_PONG = 16
MSG_BUSY = 17
MSG_READY = 18
MSG_FRAME_ACK = 19

MSG_NAMES = {
    MSG_HELLO: "hello", MSG_HELLO_ACK: "hello_ack",
    MSG_OPEN: "open", MSG_OPEN_OK: "open_ok", MSG_CLOSE: "close",
    MSG_FRAME: "frame",
    MSG_SNAPSHOT: "snapshot", MSG_SNAPSHOT_OK: "snapshot_ok",
    MSG_RESTORE: "restore", MSG_RESTORE_OK: "restore_ok",
    MSG_ERROR: "error", MSG_BYE: "bye",
    MSG_RESUME: "resume", MSG_RESUME_OK: "resume_ok",
    MSG_PING: "ping", MSG_PONG: "pong",
    MSG_BUSY: "busy", MSG_READY: "ready",
    MSG_FRAME_ACK: "frame_ack",
}

# typed error codes carried by MSG_ERROR
ERR_VERSION = 1          # hello negotiation failed
ERR_REJECTED = 2         # open refused: no slot / KV budget
ERR_OVERFLOW = 3         # EngineOverflowError: job past the slot's max_len
ERR_PROTOCOL = 4         # malformed message (the connection is dropped)
ERR_INTERNAL = 5         # unexpected cloud-side failure
ERR_BUSY = 6             # connection storm: accept cap reached, try later

ERR_NAMES = {
    ERR_VERSION: "version", ERR_REJECTED: "rejected",
    ERR_OVERFLOW: "overflow", ERR_PROTOCOL: "protocol",
    ERR_INTERNAL: "internal", ERR_BUSY: "busy",
}

_HEADER = struct.Struct("<2sBI")
HEADER_BYTES = _HEADER.size

# proto_version, frame_version, d_model, connection epoch, restart epoch
_HELLO = struct.Struct("<HHIII")
_U32 = struct.Struct("<I")
_U32_PAIR = struct.Struct("<II")
_ERROR = struct.Struct("<HI")            # code, req_id
_RESUME_HDR = struct.Struct("<II")       # prev_epoch, n_sessions
_RESUME_SESS = struct.Struct("<III")     # req_id, up_sent, down_recv
_RESUME_OK_SESS = struct.Struct("<II")   # req_id, up_recv

# Bounds buffering on a desynced or hostile stream.  The largest honest
# message is a deep-state frame: fp32 x d_model 8192 x a 4096-token chunk
# is 128 MiB; default well above any real frame, still finite.
MAX_MESSAGE_BYTES = 256 * 1024 * 1024


def encode_msg(mtype: int, payload: bytes = b"") -> bytes:
    """Wrap one message for the stream."""
    if mtype not in MSG_NAMES:
        raise ValueError(f"unknown message type {mtype}")
    return _HEADER.pack(MAGIC, mtype, len(payload)) + payload


def encode_hello(d_model: int, *, proto_version: int = PROTO_VERSION,
                 frame_version: int | None = None, epoch: int = 0,
                 restart_epoch: int = 0) -> bytes:
    from ..wire import FRAME_VERSION

    fv = FRAME_VERSION if frame_version is None else frame_version
    return _HELLO.pack(proto_version, fv, d_model, epoch, restart_epoch)


def decode_hello(payload: bytes) -> Tuple[int, int, int, int, int]:
    """-> (proto_version, frame_version, d_model, epoch, restart_epoch)."""
    if len(payload) != _HELLO.size:
        raise ProtocolError(f"hello payload is {len(payload)} B, "
                            f"expected {_HELLO.size}")
    return _HELLO.unpack(payload)


def encode_u32(value: int) -> bytes:
    return _U32.pack(value)


def decode_u32(payload: bytes) -> int:
    if len(payload) != _U32.size:
        raise ProtocolError(f"expected a u32 payload, got {len(payload)} B")
    return _U32.unpack(payload)[0]


def encode_u32_pair(a: int, b: int) -> bytes:
    return _U32_PAIR.pack(a, b)


def decode_u32_pair(payload: bytes) -> Tuple[int, int]:
    if len(payload) != _U32_PAIR.size:
        raise ProtocolError(f"expected a u32 pair payload, got {len(payload)} B")
    return _U32_PAIR.unpack(payload)


def encode_error(code: int, req_id: int, message: str) -> bytes:
    return _ERROR.pack(code, req_id) + message.encode("utf-8")


def decode_error(payload: bytes) -> Tuple[int, int, str]:
    """-> (code, req_id, message)."""
    if len(payload) < _ERROR.size:
        raise ProtocolError("truncated error payload")
    code, req_id = _ERROR.unpack_from(payload)
    return code, req_id, payload[_ERROR.size:].decode("utf-8", "replace")


# --------------------------------------------------------------- resume / seq


def encode_resume(prev_epoch: int,
                  sessions: List[Tuple[int, int, int]]) -> bytes:
    """``MSG_RESUME``: sessions is [(req_id, up_sent, down_recv), ...] —
    the device's per-session frame-sequence watermarks."""
    out = _RESUME_HDR.pack(prev_epoch, len(sessions))
    for rid, up_sent, down_recv in sessions:
        out += _RESUME_SESS.pack(rid, up_sent, down_recv)
    return out


def decode_resume(payload: bytes) -> Tuple[int, List[Tuple[int, int, int]]]:
    """-> (prev_epoch, [(req_id, up_sent, down_recv), ...])."""
    if len(payload) < _RESUME_HDR.size:
        raise ProtocolError("truncated resume payload")
    prev_epoch, n = _RESUME_HDR.unpack_from(payload)
    want = _RESUME_HDR.size + n * _RESUME_SESS.size
    if len(payload) != want:
        raise ProtocolError(
            f"resume payload is {len(payload)} B, expected {want} for "
            f"{n} sessions")
    sessions = [
        _RESUME_SESS.unpack_from(payload, _RESUME_HDR.size + i * _RESUME_SESS.size)
        for i in range(n)
    ]
    return prev_epoch, sessions


def encode_resume_ok(sessions: List[Tuple[int, int]]) -> bytes:
    """``MSG_RESUME_OK``: sessions is [(req_id, up_recv), ...] — the
    cloud's uplink watermark per surviving session."""
    out = _U32.pack(len(sessions))
    for rid, up_recv in sessions:
        out += _RESUME_OK_SESS.pack(rid, up_recv)
    return out


def decode_resume_ok(payload: bytes) -> List[Tuple[int, int]]:
    if len(payload) < _U32.size:
        raise ProtocolError("truncated resume_ok payload")
    n = _U32.unpack_from(payload)[0]
    want = _U32.size + n * _RESUME_OK_SESS.size
    if len(payload) != want:
        raise ProtocolError(
            f"resume_ok payload is {len(payload)} B, expected {want} for "
            f"{n} sessions")
    return [
        _RESUME_OK_SESS.unpack_from(payload, _U32.size + i * _RESUME_OK_SESS.size)
        for i in range(n)
    ]


def encode_seq_frame(seq: int, frame_bytes: bytes) -> bytes:
    """``MSG_FRAME`` payload: session-scoped sequence number + frame."""
    return _U32.pack(seq) + frame_bytes


def decode_seq_frame(payload: bytes) -> Tuple[int, bytes]:
    """-> (seq, frame_bytes)."""
    if len(payload) < _U32.size:
        raise ProtocolError("truncated frame payload (missing seq)")
    return _U32.unpack_from(payload)[0], payload[_U32.size:]


class StreamDecoder:
    """Incremental message decoder over a torn byte stream.

    ``feed(chunk)`` returns every message completed by the chunk, in
    order; partial tails stay buffered for the next feed.  Header
    validation happens as soon as the header bytes are available, so a
    desynced or oversized stream fails fast instead of buffering garbage
    up to a bogus length prefix."""

    def __init__(self, *, max_message_bytes: int = MAX_MESSAGE_BYTES):
        self._buf = bytearray()
        self.max_message_bytes = max_message_bytes
        self.messages_in = 0
        self.bytes_in = 0

    def feed(self, chunk: bytes) -> List[Tuple[int, bytes]]:
        self.bytes_in += len(chunk)
        self._buf += chunk
        out: List[Tuple[int, bytes]] = []
        pos = 0
        while len(self._buf) - pos >= HEADER_BYTES:
            magic, mtype, length = _HEADER.unpack_from(self._buf, pos)
            if magic != MAGIC:
                raise ProtocolError(
                    f"stream desync: bad message magic {bytes(magic)!r}"
                )
            if mtype not in MSG_NAMES:
                raise ProtocolError(f"unknown message type {mtype}")
            if length > self.max_message_bytes:
                raise ProtocolError(
                    f"message of {length} B exceeds the "
                    f"{self.max_message_bytes} B limit"
                )
            end = pos + HEADER_BYTES + length
            if len(self._buf) < end:
                break                              # torn: wait for more bytes
            out.append((mtype, bytes(self._buf[pos + HEADER_BYTES:end])))
            self.messages_in += 1
            pos = end
        del self._buf[:pos]
        return out

    @property
    def pending_bytes(self) -> int:
        """Buffered bytes of the (incomplete) next message."""
        return len(self._buf)


def iter_messages(stream: bytes) -> Iterator[Tuple[int, bytes]]:
    """Decode a complete in-memory stream (tests / trace tooling)."""
    dec = StreamDecoder()
    yield from dec.feed(stream)
    if dec.pending_bytes:
        raise ProtocolError(f"trailing {dec.pending_bytes} B of partial message")
