"""Transport error hierarchy for the device-cloud network path.

Deliberately import-free (no repro dependencies): ``repro.serving.api``
imports these to give the base :class:`~repro.serving.api.Transport` a
typed failure surface, and ``repro.net`` re-exports them next to the
socket implementations — so the hierarchy must sit below both.

All errors subclass :class:`RuntimeError`: historical callers that caught
``RuntimeError`` on starved downlinks keep working unchanged.

* :class:`TransportError` — base class: the wire failed (connect,
  send, protocol desync, starved downlink).
* :class:`TransportTimeout` — a bounded ``recv``/``send`` ran out of
  time.  Subclasses :class:`TimeoutError` too, so generic timeout
  handling sees it.
* :class:`TransportClosed` — the peer hung up (EOF mid-stream or the
  service shut the session's connection down).
* :class:`RemoteEngineError` — the *cloud* failed the request and said
  so over the wire: a typed error frame carrying an error code (e.g.
  ``ERR_OVERFLOW`` when the engine raised ``EngineOverflowError``),
  the owning ``req_id`` and the remote message.  Raising it out of
  ``recv`` releases the waiting session instead of blocking forever.
* :class:`SessionLostError` — recovery gave up on a *session* (resume
  after the cloud's grace period expired, retries exhausted, or a
  watermark the cloud could no longer honor): the request surfaces a
  typed error carrying the tokens generated so far instead of hanging
  or silently truncating.
"""
from __future__ import annotations


class TransportError(RuntimeError):
    """Base class for device-cloud transport failures."""


class TransportTimeout(TransportError, TimeoutError):
    """A bounded transport operation exceeded its deadline."""

    def __init__(self, op: str, timeout_s: float, req_id: int | None = None):
        self.op = op
        self.timeout_s = timeout_s
        self.req_id = req_id
        where = f" for request {req_id}" if req_id is not None else ""
        super().__init__(f"{op}{where} timed out after {timeout_s:.3g}s")


class TransportClosed(TransportError):
    """The connection ended (EOF / peer shutdown) while traffic was due."""


class ProtocolError(TransportError):
    """The byte stream desynced: bad magic, an oversized message, a
    version-mismatch hello, or a message type the receiver cannot route.
    Unrecoverable for the connection — the only safe reaction is to drop
    it (a length-prefixed stream cannot resynchronize mid-garbage)."""


class RemoteEngineError(TransportError):
    """A typed error frame from the cloud: the engine rejected or dropped
    the request (slot overflow, failed admission, internal fault).

    ``code`` is a ``repro.net.protocol`` ``ERR_*`` constant; ``req_id`` is
    the request the error belongs to (0 = connection-wide)."""

    def __init__(self, code: int, req_id: int, message: str):
        self.code = code
        self.req_id = req_id
        self.remote_message = message
        super().__init__(
            f"cloud error (code {code}) for request {req_id}: {message}"
        )


class SessionLostError(TransportError):
    """The session could not be recovered: resume was refused (grace
    expired, epoch mismatch, unreplayable watermark) or reconnects ran
    out.  Graceful degradation: ``partial_tokens`` carries whatever the
    request had already generated, so callers get a truncated-but-typed
    result instead of a hang."""

    def __init__(self, req_id: int, reason: str,
                 partial_tokens: "list | None" = None):
        self.req_id = req_id
        self.reason = reason
        self.partial_tokens = list(partial_tokens) if partial_tokens else []
        super().__init__(f"session {req_id} lost: {reason}")
