"""Process launcher: 1 cloud + N device processes on localhost.

The three-process (and up) topology the paper actually measures —
genuinely disaggregated device and cloud — driven from one parent::

    from repro.net.launcher import run_cluster
    result = run_cluster(arch="internlm2-1.8b", n_devices=2,
                         requests_per_device=2, workdir="out/")

``run_cluster`` spawns ``python -m repro.net.service`` (ephemeral port,
parsed from its startup line), waits for it to listen, spawns one
``python -m repro.net.worker`` per device, collects every worker's result
JSON, terminates the cloud gracefully (SIGTERM => it dumps its trace),
and merges the per-process Chrome traces into one file with disjoint
pids.  Used by ``launch/serve --net tcp``, ``serve_cluster --net``, the
``bench_engine --net tcp`` benchmark and the CI net-smoke job.
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from .errors import TransportError

_LISTEN_PREFIX = "NET_SERVE listening on "


def _src_env() -> Dict[str, str]:
    """Child processes must import repro the same way the parent does."""
    import repro

    # repro is a namespace package (no __init__.py): __file__ is None, the
    # import root is the parent of the first __path__ entry
    src = str(Path(list(repro.__path__)[0]).resolve().parent)
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _tail(path: Path, n: int = 30) -> str:
    try:
        return "\n".join(path.read_text().splitlines()[-n:])
    except OSError:
        return "<no log>"


class CloudProcess:
    """Handle on a spawned ``repro.net.service`` process."""

    def __init__(self, proc: subprocess.Popen, host: str, port: int,
                 log_path: Path, trace_out: Optional[Path]):
        self.proc = proc
        self.host = host
        self.port = port
        self.log_path = log_path
        self.trace_out = trace_out

    def terminate(self, timeout_s: float = 30.0) -> int:
        """SIGTERM (the service dumps its trace on the way down) + wait."""
        if self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        return self.proc.returncode


def spawn_cloud(
    arch: str,
    *,
    workdir: Path,
    slots: int = 8,
    max_len: int = 128,
    max_batch_tokens: int = 256,
    wire_codec: str = "fp16",
    seed: int = 0,
    host: str = "127.0.0.1",
    port: int = 0,
    trace: bool = True,
    startup_timeout_s: float = 240.0,
) -> CloudProcess:
    """Start the cloud service; blocks until it prints its listen line
    (cold JAX import + model build can take a while on CPU)."""
    workdir.mkdir(parents=True, exist_ok=True)
    log_path = workdir / "cloud.log"
    trace_out = workdir / "cloud_trace.json" if trace else None
    cmd = [
        sys.executable, "-m", "repro.net.service",
        "--host", host, "--port", str(port), "--arch", arch,
        "--slots", str(slots), "--max-len", str(max_len),
        "--max-batch-tokens", str(max_batch_tokens),
        "--wire-codec", wire_codec, "--seed", str(seed),
    ]
    if trace_out is not None:
        cmd += ["--trace-out", str(trace_out)]
    log = open(log_path, "w")
    proc = subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                            env=_src_env())
    deadline = time.monotonic() + startup_timeout_s
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise TransportError(
                f"cloud service exited with {proc.returncode} before "
                f"listening; log tail:\n{_tail(log_path)}"
            )
        for line in log_path.read_text().splitlines():
            if line.startswith(_LISTEN_PREFIX):
                addr = line[len(_LISTEN_PREFIX):].strip()
                h, p = addr.rsplit(":", 1)
                return CloudProcess(proc, h, int(p), log_path, trace_out)
        time.sleep(0.1)
    proc.kill()
    raise TransportError(
        f"cloud service did not listen within {startup_timeout_s:.0f}s; "
        f"log tail:\n{_tail(log_path)}"
    )


def spawn_worker(
    device_index: int,
    *,
    host: str,
    port: int,
    arch: str,
    workdir: Path,
    requests: int = 2,
    prompt_len: int = 16,
    new_tokens: int = 4,
    max_len: int = 128,
    wire_codec: str = "fp16",
    draft: bool = False,
    seed: int = 0,
    pipeline_depth: int = 0,
    trace: bool = True,
) -> subprocess.Popen:
    out = workdir / f"dev{device_index}.json"
    cmd = [
        sys.executable, "-m", "repro.net.worker",
        "--host", host, "--port", str(port), "--arch", arch,
        "--device-index", str(device_index),
        "--requests", str(requests), "--prompt-len", str(prompt_len),
        "--new-tokens", str(new_tokens), "--max-len", str(max_len),
        "--wire-codec", wire_codec, "--seed", str(seed),
        "--pipeline-depth", str(pipeline_depth),
        "--out", str(out),
    ]
    if draft:
        cmd.append("--draft")
    if trace:
        cmd += ["--trace-out", str(workdir / f"dev{device_index}_trace.json")]
    log = open(workdir / f"dev{device_index}.log", "w")
    return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                            env=_src_env())


def merge_traces(workdir: Path, n_devices: int) -> Optional[Path]:
    """Merge the cloud + per-device trace dumps into ``merged_trace.json``
    (disjoint pids per process); returns None when no trace was written."""
    from ..obs import merge_chrome_traces, validate_chrome_trace

    paths, labels = [], []
    cloud = workdir / "cloud_trace.json"
    if cloud.exists():
        paths.append(cloud)
        labels.append("cloud")
    for i in range(n_devices):
        p = workdir / f"dev{i}_trace.json"
        if p.exists():
            paths.append(p)
            labels.append(f"device{i}")
    if not paths:
        return None
    objs = [json.loads(p.read_text()) for p in paths]
    merged = merge_chrome_traces(objs, labels)
    validate_chrome_trace(merged)
    out = workdir / "merged_trace.json"
    out.write_text(json.dumps(merged, indent=1))
    return out


def _wait_workers(workers: List[subprocess.Popen], cloud: CloudProcess,
                  timeout_s: float, wd: Path,
                  poll_s: float = 0.2) -> None:
    """Wait for every worker, polling the cloud the whole time.

    A dead cloud used to mean every worker blocked until its own recv
    timeout while ``run_cluster`` sat in ``wait()`` — now it raises
    immediately (the caller's ``finally`` kills the orphans)."""
    deadline = time.monotonic() + timeout_s
    pending = set(range(len(workers)))
    while pending:
        if cloud.proc.poll() is not None:
            raise TransportError(
                f"cloud service exited with {cloud.proc.returncode} while "
                f"{len(pending)} device worker(s) were still running; "
                f"log tail:\n{_tail(cloud.log_path)}"
            )
        for i in sorted(pending):
            rc = workers[i].poll()
            if rc is None:
                continue
            pending.discard(i)
            if rc != 0:
                raise TransportError(
                    f"device worker {i} exited with {rc}; log "
                    f"tail:\n{_tail(wd / f'dev{i}.log')}"
                )
        if pending and time.monotonic() > deadline:
            raise TransportError(
                f"device worker(s) {sorted(pending)} still running after "
                f"{timeout_s:.0f}s; log tail:\n"
                f"{_tail(wd / f'dev{sorted(pending)[0]}.log')}"
            )
        if pending:
            time.sleep(poll_s)


def run_cluster(
    arch: str = "internlm2-1.8b",
    *,
    n_devices: int = 2,
    requests_per_device: int = 2,
    prompt_len: int = 16,
    new_tokens: int = 4,
    slots: int = 8,
    max_len: int = 128,
    max_batch_tokens: int = 256,
    wire_codec: str = "fp16",
    draft: bool = False,
    seed: int = 0,
    pipeline_depth: int = 0,
    link_delay_s: float = 0.0,
    workdir: Optional[str] = None,
    trace: bool = True,
    worker_timeout_s: float = 600.0,
    chaos_schedule: Optional[dict] = None,
) -> dict:
    """The whole topology, end to end; returns aggregated measurements.

    Raises :class:`TransportError` with the failing process's log tail if
    the cloud never listens, dies mid-run (workers are then killed, not
    orphaned), or any worker exits non-zero.

    ``chaos_schedule`` (connection index -> ``[FaultEvent, ...]``, see
    :mod:`repro.net.chaos`) interposes a fault-injecting proxy between
    the workers and the cloud; the result gains ``chaos_faults``.
    ``link_delay_s`` > 0 interposes the same proxy as a link shaper:
    every uplink ``MSG_FRAME`` is delivered ``link_delay_s`` seconds
    after it arrives at the proxy (propagation delay — frames may be in
    flight concurrently), giving localhost a deterministic WAN-like
    uplink latency that a pipelined device can hide."""
    if workdir is None:
        import tempfile

        workdir = tempfile.mkdtemp(prefix="repro_net_")
    wd = Path(workdir)
    wd.mkdir(parents=True, exist_ok=True)

    cloud = spawn_cloud(
        arch, workdir=wd, slots=slots, max_len=max_len,
        max_batch_tokens=max_batch_tokens, wire_codec=wire_codec,
        seed=seed, trace=trace,
    )
    proxy = None
    connect_host, connect_port = cloud.host, cloud.port
    if chaos_schedule is not None or link_delay_s > 0.0:
        from .chaos import ChaosProxy

        proxy = ChaosProxy(cloud.host, cloud.port, schedule=chaos_schedule,
                           up_frame_delay_s=link_delay_s)
        connect_host, connect_port = proxy.start()
    workers: List[subprocess.Popen] = []
    try:
        for i in range(n_devices):
            workers.append(spawn_worker(
                i, host=connect_host, port=connect_port, arch=arch,
                workdir=wd, requests=requests_per_device,
                prompt_len=prompt_len, new_tokens=new_tokens, max_len=max_len,
                wire_codec=wire_codec, draft=draft, seed=seed,
                pipeline_depth=pipeline_depth, trace=trace,
            ))
        _wait_workers(workers, cloud, worker_timeout_s, wd)
    finally:
        for w in workers:
            if w.poll() is None:
                w.kill()
        if proxy is not None:
            proxy.stop()
        cloud_rc = cloud.terminate()

    results = []
    for i in range(n_devices):
        with open(wd / f"dev{i}.json") as f:
            results.append(json.load(f))
    reqs = [r for res in results for r in res["requests"]]
    ttfts = np.asarray([r["ttft_s"] for r in reqs if r["ttft_s"] is not None])
    tbts = np.asarray([r["tbt_s"] for r in reqs if r["tbt_s"] is not None])
    merged = merge_traces(wd, n_devices) if trace else None
    return {
        "workdir": str(wd),
        "host": cloud.host,
        "port": cloud.port,
        "cloud_returncode": cloud_rc,
        "n_devices": n_devices,
        "pipeline_depth": pipeline_depth,
        "workers": results,
        "n_requests": len(reqs),
        "ttft_mean_ms": float(ttfts.mean() * 1e3) if len(ttfts) else None,
        "ttft_p90_ms": (float(np.percentile(ttfts, 90) * 1e3)
                        if len(ttfts) else None),
        "tbt_mean_ms": float(tbts.mean() * 1e3) if len(tbts) else None,
        "bytes_up": sum(r["bytes_up"] for r in results),
        "bytes_down": sum(r["bytes_down"] for r in results),
        "reconnects": sum(r.get("reconnects", 0) for r in results),
        "replayed_frames": sum(r.get("replayed_frames", 0) for r in results),
        "requests_degraded": sum(r.get("requests_degraded", 0)
                                 for r in results),
        "chaos_faults": list(proxy.faults) if proxy is not None else [],
        "merged_trace": str(merged) if merged else None,
        "cloud_log": str(cloud.log_path),
    }
