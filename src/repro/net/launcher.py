"""Process launcher: 1 cloud + N device processes on localhost.

The three-process (and up) topology the paper actually measures —
genuinely disaggregated device and cloud — driven from one parent::

    from repro.net.launcher import run_cluster
    result = run_cluster(arch="internlm2-1.8b", n_devices=2,
                         requests_per_device=2, workdir="out/")

``run_cluster`` spawns ``python -m repro.net.service`` (ephemeral port,
parsed from its startup line), waits for it to listen, spawns one
``python -m repro.net.worker`` per device, collects every worker's result
JSON, terminates the cloud gracefully (SIGTERM => it dumps its trace),
and merges the per-process Chrome traces into one file with disjoint
pids.  Used by ``launch/serve --net tcp``, ``serve_cluster --net``, the
``bench_engine --net tcp`` benchmark and the CI net-smoke job.

Cloud restart orchestration
---------------------------
``run_cluster(cloud_restart=CloudRestartPlan(...))`` proves sessions
survive a cloud *process* death: the cloud runs with periodic
checkpointing, a :class:`~repro.net.chaos.ChaosProxy` counts the fleet's
``MSG_OPEN_OK`` / uplink ``MSG_FRAME`` traffic and fires a seeded
kill trigger mid-run, and a :class:`_CloudSupervisor` SIGKILLs the cloud
only after a checkpoint provably newer than the trigger exists (two
checkpoint generations — the second one's state capture strictly follows
the first one's completed write, which follows the trigger).  A fresh
service boots on the *same* port with ``--restore`` under a bumped
restart epoch; devices ride through on their retry policies (the proxy
holds reconnecting devices' upstream connects until the new process
listens) and resume their sessions, replaying any uplink frames the
checkpoint rolled back.  ``_wait_workers`` is restart-aware: a dead
cloud process is fatal only when no supervisor claims the death (or the
plan's ``on_unexpected_death`` policy says fail).
"""
from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from .errors import TransportError

_LISTEN_PREFIX = "NET_SERVE listening on "


def _src_env() -> Dict[str, str]:
    """Child processes must import repro the same way the parent does."""
    import repro

    # repro is a namespace package (no __init__.py): __file__ is None, the
    # import root is the parent of the first __path__ entry
    src = str(Path(list(repro.__path__)[0]).resolve().parent)
    env = dict(os.environ)
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _tail(path: Path, n: int = 30) -> str:
    try:
        return "\n".join(path.read_text().splitlines()[-n:])
    except OSError:
        return "<no log>"


class CloudProcess:
    """Handle on a spawned ``repro.net.service`` process."""

    def __init__(self, proc: subprocess.Popen, host: str, port: int,
                 log_path: Path, trace_out: Optional[Path]):
        self.proc = proc
        self.host = host
        self.port = port
        self.log_path = log_path
        self.trace_out = trace_out

    def terminate(self, timeout_s: float = 30.0) -> int:
        """SIGTERM (the service dumps its trace on the way down) + wait."""
        if self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)
            try:
                self.proc.wait(timeout=timeout_s)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait()
        return self.proc.returncode


def spawn_cloud(
    arch: str,
    *,
    workdir: Path,
    slots: int = 8,
    max_len: int = 128,
    max_batch_tokens: int = 256,
    wire_codec: str = "fp16",
    seed: int = 0,
    host: str = "127.0.0.1",
    port: int = 0,
    trace: bool = True,
    startup_timeout_s: float = 240.0,
    grace_s: Optional[float] = None,
    checkpoint: Optional[Path] = None,
    checkpoint_every_s: float = 0.0,
    restore: bool = False,
    log_name: str = "cloud.log",
) -> CloudProcess:
    """Start the cloud service; blocks until it prints its listen line
    (cold JAX import + model build can take a while on CPU)."""
    workdir.mkdir(parents=True, exist_ok=True)
    log_path = workdir / log_name
    trace_out = workdir / "cloud_trace.json" if trace else None
    cmd = [
        sys.executable, "-m", "repro.net.service",
        "--host", host, "--port", str(port), "--arch", arch,
        "--slots", str(slots), "--max-len", str(max_len),
        "--max-batch-tokens", str(max_batch_tokens),
        "--wire-codec", wire_codec, "--seed", str(seed),
    ]
    if trace_out is not None:
        cmd += ["--trace-out", str(trace_out)]
    if grace_s is not None:
        cmd += ["--grace-s", str(grace_s)]
    if checkpoint is not None:
        cmd += ["--checkpoint", str(checkpoint),
                "--checkpoint-every-s", str(checkpoint_every_s)]
    if restore:
        cmd += ["--restore"]
    log = open(log_path, "w")
    proc = subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                            env=_src_env())
    deadline = time.monotonic() + startup_timeout_s
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise TransportError(
                f"cloud service exited with {proc.returncode} before "
                f"listening; log tail:\n{_tail(log_path)}"
            )
        for line in log_path.read_text().splitlines():
            if line.startswith(_LISTEN_PREFIX):
                addr = line[len(_LISTEN_PREFIX):].strip()
                h, p = addr.rsplit(":", 1)
                return CloudProcess(proc, h, int(p), log_path, trace_out)
        time.sleep(0.1)
    proc.kill()
    raise TransportError(
        f"cloud service did not listen within {startup_timeout_s:.0f}s; "
        f"log tail:\n{_tail(log_path)}"
    )


def spawn_worker(
    device_index: int,
    *,
    host: str,
    port: int,
    arch: str,
    workdir: Path,
    requests: int = 2,
    prompt_len: int = 16,
    new_tokens: int = 4,
    max_len: int = 128,
    wire_codec: str = "fp16",
    draft: bool = False,
    seed: int = 0,
    pipeline_depth: int = 0,
    trace: bool = True,
    retry_attempts: Optional[int] = None,
    retry_base_s: Optional[float] = None,
    recv_timeout_s: Optional[float] = None,
) -> subprocess.Popen:
    out = workdir / f"dev{device_index}.json"
    cmd = [
        sys.executable, "-m", "repro.net.worker",
        "--host", host, "--port", str(port), "--arch", arch,
        "--device-index", str(device_index),
        "--requests", str(requests), "--prompt-len", str(prompt_len),
        "--new-tokens", str(new_tokens), "--max-len", str(max_len),
        "--wire-codec", wire_codec, "--seed", str(seed),
        "--pipeline-depth", str(pipeline_depth),
        "--out", str(out),
    ]
    if retry_attempts is not None:
        cmd += ["--retry-attempts", str(retry_attempts)]
    if retry_base_s is not None:
        cmd += ["--retry-base-s", str(retry_base_s)]
    if recv_timeout_s is not None:
        cmd += ["--recv-timeout", str(recv_timeout_s)]
    if draft:
        cmd.append("--draft")
    if trace:
        cmd += ["--trace-out", str(workdir / f"dev{device_index}_trace.json")]
    log = open(workdir / f"dev{device_index}.log", "w")
    return subprocess.Popen(cmd, stdout=log, stderr=subprocess.STDOUT,
                            env=_src_env())


def merge_traces(workdir: Path, n_devices: int) -> Optional[Path]:
    """Merge the cloud + per-device trace dumps into ``merged_trace.json``
    (disjoint pids per process); returns None when no trace was written."""
    from ..obs import merge_chrome_traces, validate_chrome_trace

    paths, labels = [], []
    cloud = workdir / "cloud_trace.json"
    if cloud.exists():
        paths.append(cloud)
        labels.append("cloud")
    for i in range(n_devices):
        p = workdir / f"dev{i}_trace.json"
        if p.exists():
            paths.append(p)
            labels.append(f"device{i}")
    if not paths:
        return None
    objs = [json.loads(p.read_text()) for p in paths]
    merged = merge_chrome_traces(objs, labels)
    validate_chrome_trace(merged)
    out = workdir / "merged_trace.json"
    out.write_text(json.dumps(merged, indent=1))
    return out


@dataclass
class CloudRestartPlan:
    """How (and when) to kill + restart the cloud mid-run.

    The kill trigger is chaos-driven: the proxy fires once it has seen
    ``kill_after_open_oks`` session acks *and* ``kill_after_up_frames``
    uplink frames (``None`` derives the frame threshold from ``seed`` via
    :func:`repro.net.chaos.seeded_kill_after_frames`, and the open-ok
    threshold from the fleet size).  Gating on open-oks makes zero-lost-
    sessions deterministic for one-request-per-device storms: every
    session is registered cloud-side before the trigger, so the
    checkpoint the supervisor waits for provably contains them all.

    ``on_unexpected_death`` is the restart-vs-fail policy for cloud
    deaths the plan did *not* cause: ``"fail"`` keeps the fail-fast
    behavior, ``"restart"`` respawns from the latest checkpoint while
    ``max_restarts`` lasts."""

    seed: int = 0
    kill_after_open_oks: Optional[int] = None
    kill_after_up_frames: Optional[int] = None
    checkpoint_every_s: float = 0.25
    grace_s: float = 120.0
    max_restarts: int = 1
    on_unexpected_death: str = "fail"        # "fail" | "restart"
    checkpoint_wait_s: float = 120.0


class _CloudSupervisor:
    """Owns the live :class:`CloudProcess` across planned (chaos-kill)
    and unexpected restarts.  ``current`` is only ever replaced after the
    successor prints its listen line; ``restarting`` is set *before* the
    old process is killed, so ``_wait_workers`` never mistakes a planned
    kill for a crash."""

    def __init__(self, plan: CloudRestartPlan, cloud: CloudProcess,
                 checkpoint: Path, respawn):
        self.plan = plan
        self.current = cloud
        self.checkpoint = checkpoint
        self._respawn = respawn          # (port, log_name) -> CloudProcess
        self.restarting = threading.Event()
        self.restarts = 0
        self.error: Optional[Exception] = None
        self._fired = False

    # -- chaos trigger entry point (proxy thread) -------------------------
    def chaos_kill(self) -> None:
        if self._fired:
            return
        self._fired = True
        threading.Thread(target=self._planned_restart, daemon=True,
                         name="cloud-restart").start()

    def _manifest_mtime(self) -> Optional[float]:
        try:
            return (self.checkpoint / "manifest.json").stat().st_mtime
        except OSError:
            return None

    def _wait_checkpoint_after(self, t_trigger: float) -> None:
        """Block until a checkpoint whose *state capture* strictly follows
        ``t_trigger`` exists: first wait for a manifest written after the
        trigger, then for one more generation — its capture began after
        the previous write completed, which is after the trigger."""
        deadline = time.monotonic() + self.plan.checkpoint_wait_s
        gen = 0
        floor = t_trigger
        while gen < 2:
            if time.monotonic() > deadline:
                raise TransportError(
                    f"no checkpoint newer than the kill trigger appeared "
                    f"within {self.plan.checkpoint_wait_s:.0f}s at "
                    f"{self.checkpoint}")
            m = self._manifest_mtime()
            if m is not None and m > floor:
                floor = m
                gen += 1
            else:
                time.sleep(0.05)

    def _planned_restart(self) -> None:
        self.restarting.set()
        try:
            self._wait_checkpoint_after(time.time())
            old = self.current
            old.proc.kill()              # SIGKILL: a crash, not a shutdown
            old.proc.wait()
            self.current = self._respawn(old.port,
                                         f"cloud{self.restarts + 1}.log")
            self.restarts += 1
        except Exception as e:           # noqa: BLE001 - surfaced by waiter
            self.error = e
        finally:
            self.restarting.clear()

    # -- unexpected-death entry point (_wait_workers thread) --------------
    def handle_death(self, dead: CloudProcess) -> None:
        """Policy verdict for a cloud death the plan didn't cause; raises
        to fail the run, returns after a successful respawn otherwise."""
        if self.plan.on_unexpected_death != "restart" \
                or self.restarts >= self.plan.max_restarts:
            raise TransportError(
                f"cloud service exited with {dead.proc.returncode} "
                f"unexpectedly; log tail:\n{_tail(dead.log_path)}")
        dead.proc.wait()
        self.current = self._respawn(dead.port,
                                     f"cloud{self.restarts + 1}.log")
        self.restarts += 1


def _wait_workers(workers: List[subprocess.Popen], cloud: CloudProcess,
                  timeout_s: float, wd: Path,
                  poll_s: float = 0.2,
                  supervisor: Optional[_CloudSupervisor] = None) -> None:
    """Wait for every worker, polling the cloud the whole time.

    A dead cloud used to mean every worker blocked until its own recv
    timeout while ``run_cluster`` sat in ``wait()`` — now it raises
    immediately (the caller's ``finally`` kills the orphans) *unless* a
    restart supervisor claims the death: a planned chaos kill (or an
    ``on_unexpected_death="restart"`` policy) keeps the fleet alive
    while a successor process boots from the checkpoint."""
    deadline = time.monotonic() + timeout_s
    pending = set(range(len(workers)))
    while pending:
        live = supervisor.current if supervisor is not None else cloud
        if supervisor is not None and supervisor.error is not None:
            raise TransportError(
                f"cloud restart failed: {supervisor.error}"
            ) from supervisor.error
        if live.proc.poll() is not None:
            if supervisor is None:
                raise TransportError(
                    f"cloud service exited with {live.proc.returncode} while "
                    f"{len(pending)} device worker(s) were still running; "
                    f"log tail:\n{_tail(live.log_path)}"
                )
            if not supervisor.restarting.is_set() \
                    and supervisor.current is live:
                supervisor.handle_death(live)
        for i in sorted(pending):
            rc = workers[i].poll()
            if rc is None:
                continue
            pending.discard(i)
            if rc != 0:
                raise TransportError(
                    f"device worker {i} exited with {rc}; log "
                    f"tail:\n{_tail(wd / f'dev{i}.log')}"
                )
        if pending and time.monotonic() > deadline:
            raise TransportError(
                f"device worker(s) {sorted(pending)} still running after "
                f"{timeout_s:.0f}s; log tail:\n"
                f"{_tail(wd / f'dev{sorted(pending)[0]}.log')}"
            )
        if pending:
            time.sleep(poll_s)


def run_cluster(
    arch: str = "internlm2-1.8b",
    *,
    n_devices: int = 2,
    requests_per_device: int = 2,
    prompt_len: int = 16,
    new_tokens: int = 4,
    slots: int = 8,
    max_len: int = 128,
    max_batch_tokens: int = 256,
    wire_codec: str = "fp16",
    draft: bool = False,
    seed: int = 0,
    pipeline_depth: int = 0,
    link_delay_s: float = 0.0,
    workdir: Optional[str] = None,
    trace: bool = True,
    worker_timeout_s: float = 600.0,
    chaos_schedule: Optional[dict] = None,
    cloud_restart: Optional[CloudRestartPlan] = None,
) -> dict:
    """The whole topology, end to end; returns aggregated measurements.

    Raises :class:`TransportError` with the failing process's log tail if
    the cloud never listens, dies mid-run (workers are then killed, not
    orphaned), or any worker exits non-zero.

    ``chaos_schedule`` (connection index -> ``[FaultEvent, ...]``, see
    :mod:`repro.net.chaos`) interposes a fault-injecting proxy between
    the workers and the cloud; the result gains ``chaos_faults``.
    ``link_delay_s`` > 0 interposes the same proxy as a link shaper:
    every uplink ``MSG_FRAME`` is delivered ``link_delay_s`` seconds
    after it arrives at the proxy (propagation delay — frames may be in
    flight concurrently), giving localhost a deterministic WAN-like
    uplink latency that a pipelined device can hide.

    ``cloud_restart`` (a :class:`CloudRestartPlan`) runs the cloud with
    periodic checkpointing and SIGKILLs + restores it mid-run (see the
    module docstring); the result gains ``cloud_restarts`` and
    ``sessions_lost`` (degraded requests — sessions that failed to
    resume across the restart)."""
    if workdir is None:
        import tempfile

        workdir = tempfile.mkdtemp(prefix="repro_net_")
    wd = Path(workdir)
    wd.mkdir(parents=True, exist_ok=True)

    ckpt = wd / "cloud_ckpt" if cloud_restart is not None else None
    cloud = spawn_cloud(
        arch, workdir=wd, slots=slots, max_len=max_len,
        max_batch_tokens=max_batch_tokens, wire_codec=wire_codec,
        seed=seed, trace=trace,
        grace_s=cloud_restart.grace_s if cloud_restart is not None else None,
        checkpoint=ckpt,
        checkpoint_every_s=(cloud_restart.checkpoint_every_s
                            if cloud_restart is not None else 0.0),
    )
    supervisor = None
    if cloud_restart is not None:
        def _respawn(port: int, log_name: str) -> CloudProcess:
            return spawn_cloud(
                arch, workdir=wd, slots=slots, max_len=max_len,
                max_batch_tokens=max_batch_tokens, wire_codec=wire_codec,
                seed=seed, trace=trace, port=port,
                grace_s=cloud_restart.grace_s, checkpoint=ckpt,
                checkpoint_every_s=cloud_restart.checkpoint_every_s,
                restore=True, log_name=log_name,
            )

        supervisor = _CloudSupervisor(cloud_restart, cloud, ckpt, _respawn)
    proxy = None
    connect_host, connect_port = cloud.host, cloud.port
    if (chaos_schedule is not None or link_delay_s > 0.0
            or cloud_restart is not None):
        from .chaos import ChaosProxy, seeded_kill_after_frames

        kill_kwargs = {}
        if cloud_restart is not None:
            opens = cloud_restart.kill_after_open_oks
            frames = cloud_restart.kill_after_up_frames
            if frames is None:
                frames = seeded_kill_after_frames(
                    cloud_restart.seed, n_devices)
            kill_kwargs = dict(
                kill_after_open_oks=(n_devices if opens is None else opens),
                kill_after_up_frames=frames,
                on_cloud_kill=supervisor.chaos_kill,
                # reconnecting devices ride out the successor's cold boot
                # inside one handshake wait instead of burning retries
                upstream_retry_s=240.0,
            )
        proxy = ChaosProxy(cloud.host, cloud.port, schedule=chaos_schedule,
                           up_frame_delay_s=link_delay_s, **kill_kwargs)
        connect_host, connect_port = proxy.start()
    workers: List[subprocess.Popen] = []
    worker_kwargs = {}
    if cloud_restart is not None:
        # one blocking wait must absorb the whole restart window (kill ->
        # checkpoint wait -> cold boot of the successor) on a loaded host
        worker_kwargs = dict(retry_attempts=12, retry_base_s=0.25,
                             recv_timeout_s=300.0)
    try:
        for i in range(n_devices):
            workers.append(spawn_worker(
                i, host=connect_host, port=connect_port, arch=arch,
                workdir=wd, requests=requests_per_device,
                prompt_len=prompt_len, new_tokens=new_tokens, max_len=max_len,
                wire_codec=wire_codec, draft=draft, seed=seed,
                pipeline_depth=pipeline_depth, trace=trace, **worker_kwargs,
            ))
        _wait_workers(workers, cloud, worker_timeout_s, wd,
                      supervisor=supervisor)
    finally:
        for w in workers:
            if w.poll() is None:
                w.kill()
        if proxy is not None:
            proxy.stop()
        if supervisor is not None:
            cloud = supervisor.current
        cloud_rc = cloud.terminate()

    results = []
    for i in range(n_devices):
        with open(wd / f"dev{i}.json") as f:
            results.append(json.load(f))
    reqs = [r for res in results for r in res["requests"]]
    ttfts = np.asarray([r["ttft_s"] for r in reqs if r["ttft_s"] is not None])
    tbts = np.asarray([r["tbt_s"] for r in reqs if r["tbt_s"] is not None])
    merged = merge_traces(wd, n_devices) if trace else None
    return {
        "workdir": str(wd),
        "host": cloud.host,
        "port": cloud.port,
        "cloud_returncode": cloud_rc,
        "n_devices": n_devices,
        "pipeline_depth": pipeline_depth,
        "workers": results,
        "n_requests": len(reqs),
        "ttft_mean_ms": float(ttfts.mean() * 1e3) if len(ttfts) else None,
        "ttft_p90_ms": (float(np.percentile(ttfts, 90) * 1e3)
                        if len(ttfts) else None),
        "tbt_mean_ms": float(tbts.mean() * 1e3) if len(tbts) else None,
        "bytes_up": sum(r["bytes_up"] for r in results),
        "bytes_down": sum(r["bytes_down"] for r in results),
        "reconnects": sum(r.get("reconnects", 0) for r in results),
        "replayed_frames": sum(r.get("replayed_frames", 0) for r in results),
        "requests_degraded": sum(r.get("requests_degraded", 0)
                                 for r in results),
        "chaos_faults": list(proxy.faults) if proxy is not None else [],
        "cloud_restarts": supervisor.restarts if supervisor is not None else 0,
        "cloud_restarts_seen": max(
            (r.get("cloud_restarts_seen", 0) for r in results), default=0),
        "sessions_lost": sum(r.get("requests_degraded", 0) for r in results),
        "merged_trace": str(merged) if merged else None,
        "cloud_log": str(cloud.log_path),
    }
