"""SocketTransport: the device's real TCP handle on a remote cloud.

Implements the :class:`repro.serving.api.Transport` protocol over the
length-prefixed stream of ``repro.net.protocol``, so a ``DeviceClient``
built on it is byte-for-byte the same client that runs over loopback —
only the wire is real: connect retry, a hello/version handshake, bounded
send/recv with :class:`~repro.net.errors.TransportTimeout`, and typed
cloud errors surfacing as :class:`~repro.net.errors.RemoteEngineError`.

The transport is single-threaded by design: every blocking wait drains
the socket inline and demultiplexes what arrives — downlink frames into
per-request inboxes (sessions interleaved through one connection never
steal each other's frames), control replies (open/snapshot/restore acks)
into a separate queue.  The clock is ``time.time()`` — the unix epoch is
the one clock device and cloud processes on a host share, which is what
makes cross-process trace merges and queue-delay attribution meaningful.

Fault tolerance (protocol v2)
-----------------------------
A dropped connection is no longer fatal.  Every blocking wait catches
:class:`TransportClosed` and runs **recovery**: reconnect under the
:class:`~repro.net.policy.RetryPolicy` backoff schedule, re-handshake
(the ``MSG_HELLO_ACK`` carries the *new* connection epoch), then a
``MSG_RESUME`` presenting the previous epoch and each live session's
watermarks — ``up_sent`` (frames sent) and ``down_recv`` (frames seen).
The cloud answers ``MSG_RESUME_OK`` with, per surviving session, its own
``up_recv`` watermark; the device then replays exactly the uplink frames
the cloud never processed (``seq >= up_recv``) from a per-session replay
buffer.  Because every ``MSG_FRAME`` carries a session-scoped sequence
number, duplicates created by replay (or by a chaos proxy) are dropped
by watermark on both ends — the engine never double-steps.

Restart resume (protocol v4)
----------------------------
The hello ack also carries the cloud's **restart epoch**, bumped every
time a cloud process boots from a checkpoint.  A changed restart epoch
after recovery means the peer is a *new process* whose watermarks come
from a checkpoint that may predate frames the old process had already
acknowledged.  Two things make resume correct across that gap: the
replay buffer is **durable** — uplink frames are retained for the whole
session (acks no longer prune them) so any rolled-back suffix can be
re-sent — and ``_resume`` re-syncs ``up_acked`` down to the cloud's
restored watermark so pipelined senders re-wait for the replayed work.

Sessions the cloud *doesn't* list in ``MSG_RESUME_OK`` (grace period
expired, unknown epoch, or absent from the restored checkpoint) are
**lost**: every further operation on them raises
:class:`~repro.net.errors.SessionLostError`, which the client surfaces
with the tokens generated so far instead of hanging.

Half-open connections are caught by heartbeats: if nothing has arrived
for ``heartbeat_s`` while a wait is blocked, the device sends
``MSG_PING``; silence past ``heartbeat_timeout_s`` forces recovery.
``MSG_BUSY``/``MSG_READY`` from the cloud gate ``send`` (connection
backpressure).  Per-op timeouts compose with the transport's
:class:`~repro.net.policy.Deadline` — a reconnect spends the *same*
budget as the wait it interrupted, so a deadline means what it says.
"""
from __future__ import annotations

import socket
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Tuple

from ..obs import NULL_TRACER, Tracer
from ..serving.api import Transport
from ..wire import frame_req_id, frame_t_send, stamp_t_send
from . import protocol as P
from .errors import (
    ProtocolError,
    RemoteEngineError,
    SessionLostError,
    TransportClosed,
    TransportError,
    TransportTimeout,
)
from .policy import Deadline, RetryPolicy

_POLL_S = 0.05           # socket timeout granularity while waiting


@dataclass
class _SessionState:
    """Device-side wire state for one open session."""

    up_seq: int = 0                 # next uplink sequence number to assign
    down_expected: int = 0          # next downlink sequence number expected
    up_acked: int = 0               # uplink frames the cloud has *processed*
    established: bool = False       # OPEN_OK seen (resumable)
    expected_tokens: int = 0
    # durable uplink replay log: every frame sent since open, kept for
    # the session's lifetime.  A restarted cloud restores a *checkpoint*
    # watermark that may roll back behind frames it had already acked,
    # so acks must not prune this (close() drops the whole session).
    replay: List[Tuple[int, bytes]] = field(default_factory=list)


class SocketTransport(Transport):
    """TCP client transport speaking the ``repro.net`` stream protocol.

    * **Connect retry**: the cloud process may still be binding when the
      device comes up — ``connect_timeout_s`` bounds how long to keep
      retrying refused connections.
    * **Handshake**: first traffic is ``MSG_HELLO`` (protocol version,
      wire-frame version, d_model); the service answers ``MSG_HELLO_ACK``
      on exact match or a typed ``MSG_ERROR`` + close.  A d_model or
      version skew therefore fails in milliseconds, not with a shape
      error mid-prefill.
    * **Timeouts**: ``recv_timeout_s``/``send_timeout_s`` default every
      data-plane wait; per-call ``recv(req_id, timeout=...)`` overrides;
      ``deadline.op_timeout_s`` caps both, *including* reconnect time.
    * **Typed errors**: a ``MSG_ERROR`` carrying a req_id parks in that
      request's inbox and raises :class:`RemoteEngineError` out of the
      waiting ``recv``/control call — the session unwinds cleanly (its
      ``finally`` still sends ``MSG_CLOSE``) instead of hanging.
    * **Recovery**: see the module docstring; ``retry=RetryPolicy(
      max_attempts=0)`` restores the pre-v2 first-drop-is-fatal behavior.
    """

    def __init__(
        self,
        host: str,
        port: int,
        *,
        d_model: int,
        connect_timeout_s: float = 10.0,
        retry_interval_s: float = 0.05,
        send_timeout_s: float = 30.0,
        recv_timeout_s: float = 60.0,
        retry: Optional[RetryPolicy] = None,
        deadline: Optional[Deadline] = None,
        heartbeat_s: float = 5.0,
        heartbeat_timeout_s: float = 20.0,
        max_message_bytes: int = P.MAX_MESSAGE_BYTES,
        tracer: Optional[Tracer] = None,
    ):
        self.host, self.port = host, port
        self.d_model = d_model
        self.connect_timeout_s = connect_timeout_s
        self.retry_interval_s = retry_interval_s
        self.send_timeout_s = send_timeout_s
        self.recv_timeout_s = recv_timeout_s
        self.retry = retry if retry is not None else RetryPolicy()
        self.deadline = deadline if deadline is not None else Deadline()
        self.heartbeat_s = heartbeat_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.bytes_up = 0
        self.bytes_down = 0
        # fault-tolerance counters (read by worker result JSON / metrics)
        self.reconnects = 0
        self.replayed_frames = 0
        self.dup_frames_dropped = 0
        self.busy_signals = 0
        self.pings_sent = 0
        self.cloud_restarts_seen = 0
        self._max_message_bytes = max_message_bytes
        self._decoder = P.StreamDecoder(max_message_bytes=max_message_bytes)
        self._inbox: Dict[int, Deque] = {}       # req_id -> frames / errors
        self._control: Deque[Tuple[int, bytes]] = deque()
        self._sessions: Dict[int, _SessionState] = {}
        self._lost: Dict[int, SessionLostError] = {}
        self._retry_rng = self.retry.rng()
        self._deadline_clock = self.deadline.start()
        self._epoch = 0
        self._restart_epoch = -1     # cloud's boot generation (-1: unknown)
        self._busy = False
        self._closed = False
        self._in_recovery = False
        self._last_rx = time.monotonic()
        self._last_ping = 0.0
        self._last_liveness = time.monotonic()
        self._conn_gen = 0       # bumps on every successful reconnect
        self._sock = self._connect(connect_timeout_s, retry_interval_s)
        self._handshake()

    # ------------------------------------------------------------ connection
    def _connect(self, timeout_s: float, interval_s: float) -> socket.socket:
        deadline = time.monotonic() + timeout_s
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            try:
                sock = socket.create_connection(
                    (self.host, self.port), timeout=max(timeout_s, 1.0)
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                return sock
            except OSError as e:                  # refused: server still booting
                last = e
                time.sleep(interval_s)
        raise TransportError(
            f"could not connect to {self.host}:{self.port} within "
            f"{timeout_s:.1f}s: {last}"
        )

    def _handshake(self) -> None:
        self._send_msg(P.MSG_HELLO, P.encode_hello(self.d_model))
        mtype, payload = self._wait_control(
            P.MSG_HELLO_ACK, timeout=self.recv_timeout_s, op="hello"
        )
        proto, frame_ver, d_model, epoch, restart_epoch = \
            P.decode_hello(payload)
        from ..wire import FRAME_VERSION

        if (proto, frame_ver, d_model) != (P.PROTO_VERSION, FRAME_VERSION,
                                           self.d_model):
            raise ProtocolError(
                f"hello mismatch: cloud speaks proto v{proto} / frame "
                f"v{frame_ver} / d_model {d_model}, device speaks "
                f"v{P.PROTO_VERSION}/v{FRAME_VERSION}/{self.d_model}"
            )
        self._epoch = epoch
        if self._restart_epoch >= 0 and restart_epoch != self._restart_epoch:
            # a different boot generation answered: the old process died
            # and a new one restored (or started fresh) behind the same
            # address — resume must expect rolled-back watermarks
            self.cloud_restarts_seen += 1
            self.tracer.instant(
                "cloud_restart", self.clock(), tid=0,
                restart_epoch=restart_epoch,
            )
        self._restart_epoch = restart_epoch
        self._last_rx = time.monotonic()

    def _resume(self, prev_epoch: int) -> None:
        """Re-attach surviving sessions after a reconnect + re-handshake.

        Presents the previous connection epoch plus each established
        session's watermarks; sessions missing from the cloud's answer
        are marked lost; surviving sessions get their unacknowledged
        uplink frames replayed (cloud-side watermark dedupe makes the
        replay exactly-once).  Against a restarted cloud the answered
        watermark may be *behind* frames the old process acked — the
        durable replay log covers the rolled-back suffix, and
        ``up_acked`` re-syncs down so pipelined waits re-block."""
        listed = {
            rid: st for rid, st in self._sessions.items() if st.established
        }
        if not listed:
            return
        self._send_msg(P.MSG_RESUME, P.encode_resume(
            prev_epoch,
            [(rid, st.up_seq, st.down_expected) for rid, st in listed.items()],
        ))
        _, payload = self._wait_control(
            P.MSG_RESUME_OK, timeout=self.recv_timeout_s, op="resume"
        )
        survivors = dict(P.decode_resume_ok(payload))
        for rid, st in listed.items():
            if rid not in survivors:
                self._lost[rid] = SessionLostError(
                    rid, "cloud refused resume (grace expired, unknown "
                    "session, or absent from the restored checkpoint)"
                )
                self._sessions.pop(rid, None)
                self._inbox.pop(rid, None)
                continue
            up_recv = survivors[rid]
            st.up_acked = min(st.up_acked, up_recv)
            for seq, stamped in st.replay:
                if seq < up_recv:
                    continue         # cloud already processed this frame
                self._send_msg(P.MSG_FRAME, P.encode_seq_frame(seq, stamped))
                self.replayed_frames += 1
        self.tracer.instant(
            "resume", self.clock(), tid=0,
            sessions=len(survivors), lost=len(listed) - len(survivors),
        )

    def _recover(self, cause: Exception) -> None:
        """Reconnect + re-handshake + resume under the retry policy.

        Raises the ``cause`` unchanged when recovery is disabled (policy
        allows zero attempts, transport shut down, or the failure struck
        *inside* a recovery attempt)."""
        if self._closed or self._in_recovery or self.retry.max_attempts <= 0:
            raise cause
        self._in_recovery = True
        try:
            prev_epoch = self._epoch
            self.tracer.instant(
                "fault", self.clock(), tid=0, kind=type(cause).__name__,
            )
            last: Exception = cause
            for attempt in range(self.retry.max_attempts):
                time.sleep(self.retry.backoff_s(attempt, self._retry_rng))
                try:
                    try:
                        self._sock.close()
                    except OSError:
                        pass
                    # a new connection is a new stream: any torn message
                    # and stale control replies die with the old one
                    self._decoder = P.StreamDecoder(
                        max_message_bytes=self._max_message_bytes
                    )
                    self._control.clear()
                    self._busy = False
                    self._sock = self._connect(
                        self.connect_timeout_s, self.retry_interval_s
                    )
                    self._handshake()
                    self._resume(prev_epoch)
                except ProtocolError:
                    raise              # version skew etc.: retrying won't help
                except (TransportError, OSError) as e:
                    last = e
                    continue
                self.reconnects += 1
                self._conn_gen += 1
                self.tracer.instant(
                    "reconnect", self.clock(), tid=0, attempt=attempt,
                )
                return
            raise TransportError(
                f"connection recovery failed after "
                f"{self.retry.max_attempts} attempts: {last}"
            ) from cause
        finally:
            self._in_recovery = False

    def shutdown(self) -> None:
        """Graceful goodbye: tell the service, then close the socket."""
        if self._closed:
            return
        try:
            self._send_msg(P.MSG_BYE)
        except TransportError:
            pass
        self._closed = True
        self._sock.close()

    # ---------------------------------------------------------------- clock
    def clock(self) -> float:
        # unix epoch: the clock all processes on the host share, so frame
        # t_send stamps and trace spans line up across process boundaries
        return time.time()

    # ------------------------------------------------------------ low level
    def _send_msg(self, mtype: int, payload: bytes = b"") -> None:
        if self._closed:
            raise TransportClosed("transport already shut down")
        data = P.encode_msg(mtype, payload)
        self._sock.settimeout(self.send_timeout_s)
        try:
            self._sock.sendall(data)
        except socket.timeout:
            raise TransportTimeout("send", self.send_timeout_s) from None
        except OSError as e:
            raise TransportClosed(f"send failed: {e}") from e

    def _route(self, mtype: int, payload: bytes) -> None:
        if mtype == P.MSG_FRAME:
            seq, data = P.decode_seq_frame(payload)
            rid = frame_req_id(data)
            st = self._sessions.get(rid)
            if st is None:
                return                       # frame for a closed session
            if seq < st.down_expected:
                self.dup_frames_dropped += 1  # replay / chaos duplicate
                return
            if seq > st.down_expected:
                raise ProtocolError(
                    f"downlink gap for request {rid}: got seq {seq}, "
                    f"expected {st.down_expected}"
                )
            st.down_expected += 1
            # strict request/response per session: a downlink implies the
            # cloud processed every uplink before it.  The replay log is
            # NOT dropped — a restarted cloud may restore a checkpoint
            # older than this downlink and ask for the frames again.
            st.up_acked = st.up_seq
            self.bytes_down += len(data)
            t_arrive = self.clock()
            t_send = frame_t_send(data)
            if 0.0 < t_send <= t_arrive:
                # sender stamped its send-complete time on our shared
                # (unix-epoch) clock: the gap is the real downlink hop
                self.tracer.add_span(
                    "downlink", t_send, t_arrive, tid=rid, phase="downlink",
                    nbytes=len(data),
                )
            self._inbox.setdefault(rid, deque()).append(("frame", data))
        elif mtype == P.MSG_ERROR:
            code, rid, msg = P.decode_error(payload)
            if code in (P.ERR_VERSION, P.ERR_PROTOCOL) or rid == 0:
                raise ProtocolError(
                    f"cloud rejected the connection "
                    f"({P.ERR_NAMES.get(code, code)}): {msg}"
                )
            self._inbox.setdefault(rid, deque()).append(
                ("error", RemoteEngineError(code, rid, msg))
            )
        elif mtype == P.MSG_BYE:
            self._closed = True
            raise TransportClosed("cloud said goodbye")
        elif mtype == P.MSG_PONG:
            pass                             # _last_rx already advanced
        elif mtype == P.MSG_BUSY:
            if not self._busy:
                self._busy = True
                self.busy_signals += 1
                self.tracer.instant("busy", self.clock(), tid=0)
        elif mtype == P.MSG_READY:
            self._busy = False
        elif mtype == P.MSG_FRAME_ACK:
            rid, processed = P.decode_u32_pair(payload)
            st = self._sessions.get(rid)
            if st is not None and processed > st.up_acked:
                # advance the watermark but keep the replay log: after a
                # cloud restart the restored watermark can sit *behind*
                # this ack, and resume must re-send the acked frames
                st.up_acked = processed
        else:
            self._control.append((mtype, payload))

    def _poll(self, timeout_s: float) -> None:
        """Read once from the socket (bounded) and route what arrived."""
        if self._closed:
            raise TransportClosed("transport already shut down")
        self._sock.settimeout(max(timeout_s, 0.0) or 1e-4)
        try:
            chunk = self._sock.recv(1 << 20)
        except socket.timeout:
            return
        except OSError as e:
            raise TransportClosed(f"recv failed: {e}") from e
        if not chunk:
            raise TransportClosed("connection closed by the cloud")
        self._last_rx = time.monotonic()
        for mtype, payload in self._decoder.feed(chunk):
            self._route(mtype, payload)

    def _check_liveness(self) -> None:
        """Probe a silent connection; force recovery on a half-open one.

        Silence only counts while *we* were listening: if this transport
        went quiet itself (a multi-minute jit compile between handshake
        and first open, a CPU-starved host), the gap since the previous
        liveness check covers it, and ``_last_rx`` is re-armed so a PING
        probes the peer before the timeout can condemn a healthy link."""
        now = time.monotonic()
        away = now - self._last_liveness
        self._last_liveness = now
        if away > self.heartbeat_s:
            self._last_rx = max(self._last_rx, now - self.heartbeat_s)
        idle = now - self._last_rx
        if idle > self.heartbeat_timeout_s:
            self._recover(TransportClosed(
                f"liveness: no traffic for {idle:.1f}s"
            ))
        elif idle > self.heartbeat_s and now - self._last_ping > self.heartbeat_s:
            self._last_ping = now
            try:
                self._send_msg(P.MSG_PING)
                self.pings_sent += 1
            except TransportClosed as e:
                self._recover(e)

    def _op_deadline(self, timeout: Optional[float],
                     default: float) -> Tuple[float, float]:
        """Absolute monotonic deadline for one op + the effective bound.

        The per-call ``timeout`` (or the transport default) composes with
        ``deadline.op_timeout_s`` — whichever is tighter wins — and the
        clock keeps running through reconnects."""
        t = default if timeout is None else timeout
        cap = self.deadline.op_timeout_s
        if cap is not None:
            t = min(t, cap)
        total = self._deadline_clock.total_remaining_s()
        t = min(t, max(total, 0.0))
        return time.monotonic() + t, t

    def _wait_control(
        self, expect: int, *, timeout: float, op: str
    ) -> Tuple[int, bytes]:
        deadline = time.monotonic() + timeout
        while True:
            for i, (mtype, payload) in enumerate(self._control):
                if mtype == expect:
                    del self._control[i]
                    return mtype, payload
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout(op, timeout)
            self._poll(min(remaining, _POLL_S))

    def _control_roundtrip(
        self,
        mtype: int,
        payload: bytes,
        *,
        match: Callable[[int, bytes], Optional[tuple]],
        op: str,
        req_id: Optional[int] = None,
        timeout: Optional[float] = None,
    ):
        """Send a control message and wait for its matching reply,
        re-sending after any reconnect (the service handles the repeats
        idempotently).  ``match`` returns ``None`` for non-matches and a
        tuple ``(value,)`` on match."""
        end, bound = self._op_deadline(timeout, self.recv_timeout_s)
        while True:
            try:
                self._send_msg(mtype, payload)
            except TransportClosed as e:
                self._recover(e)
                if req_id is not None:
                    self._raise_if_lost(req_id)
                continue
            sent_gen = self._conn_gen
            resend = False
            while not resend:
                if req_id is not None:
                    self._raise_if_lost(req_id)
                    self._raise_if_error(req_id)
                for i, (mt, pl) in enumerate(self._control):
                    hit = match(mt, pl)
                    if hit is not None:
                        del self._control[i]
                        return hit[0]
                remaining = end - time.monotonic()
                if remaining <= 0:
                    raise TransportTimeout(op, bound, req_id)
                self._check_liveness()
                if self._conn_gen != sent_gen:
                    # liveness replaced the connection underneath us: the
                    # reply died with the old stream, repeat the request
                    if req_id is not None:
                        self._raise_if_lost(req_id)
                    resend = True
                    continue
                try:
                    self._poll(min(remaining, _POLL_S))
                except TransportClosed as e:
                    self._recover(e)
                    if req_id is not None:
                        self._raise_if_lost(req_id)
                    resend = True    # new connection: repeat the request

    def _raise_if_error(self, req_id: int) -> None:
        q = self._inbox.get(req_id)
        if q and q[0][0] == "error":
            _, exc = q.popleft()
            self._inbox.pop(req_id, None)
            raise exc

    def _raise_if_lost(self, req_id: int) -> None:
        exc = self._lost.get(req_id)
        if exc is not None:
            raise exc

    # ----------------------------------------------------------- data plane
    def send(self, data: bytes) -> None:
        rid = frame_req_id(data)
        self._raise_if_lost(rid)
        self._raise_if_error(rid)            # fail fast: session already dead
        st = self._sessions.setdefault(rid, _SessionState())
        self._wait_ready()
        t0 = self.clock()
        stamped = stamp_t_send(data, t0)
        seq = st.up_seq
        st.up_seq += 1
        st.replay.append((seq, stamped))
        self.bytes_up += len(data)
        try:
            self._send_msg(P.MSG_FRAME, P.encode_seq_frame(seq, stamped))
        except TransportClosed as e:
            self._recover(e)                 # resume replays this frame
            self._raise_if_lost(rid)
        self.tracer.add_span(
            "uplink", t0, self.clock(), tid=rid, phase="uplink",
            nbytes=len(data),
        )

    def _wait_ready(self) -> None:
        """Honor cloud backpressure: hold sends while MSG_BUSY is in
        force, up to the send timeout (then send anyway — the cloud's
        reader has stopped draining, so TCP flow control bounds us)."""
        if not self._busy:
            return
        end = time.monotonic() + self.send_timeout_s
        while self._busy and time.monotonic() < end:
            try:
                self._poll(_POLL_S)
            except TransportClosed as e:
                self._recover(e)

    def has_frame(self, req_id: int) -> bool:
        """Non-blocking: drain the socket once, then check the inbox."""
        q = self._inbox.get(req_id)
        if not q:
            try:
                self._poll(0.0)
            except TransportClosed as e:
                self._recover(e)
            q = self._inbox.get(req_id)
        return bool(q) and q[0][0] == "frame"

    def deliver(self, req_id: int) -> Optional[bytes]:
        """Non-blocking receive (concurrent-scheduler hook)."""
        self._raise_if_lost(req_id)
        self._raise_if_error(req_id)
        q = self._inbox.get(req_id)
        if q and q[0][0] == "frame":
            return q.popleft()[1]
        return None

    def recv(self, req_id: int, timeout: Optional[float] = None) -> bytes:
        end, bound = self._op_deadline(timeout, self.recv_timeout_s)
        t_wait = self.clock()
        while True:
            self._raise_if_lost(req_id)
            self._raise_if_error(req_id)
            q = self._inbox.get(req_id)
            if q and q[0][0] == "frame":
                data = q.popleft()[1]
                t_send = frame_t_send(data)
                if 0.0 < t_send and t_wait < t_send:
                    # everything between entering recv and the cloud's
                    # send stamp is cloud residency (queue + step); the
                    # downlink hop itself was spanned at arrival
                    self.tracer.add_span(
                        "cloud_wait", t_wait, t_send, tid=req_id,
                        phase="cloud_step",
                    )
                return data
            remaining = end - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("recv", bound, req_id)
            self._check_liveness()
            try:
                self._poll(min(remaining, _POLL_S))
            except TransportClosed as e:
                self._recover(e)

    def acked_count(self, req_id: int) -> int:
        """Uplink frames of ``req_id`` the cloud has *processed* (a
        contiguous prefix count, from ``MSG_FRAME_ACK`` watermarks and
        downlink arrivals).  Non-blocking: drains the socket once first."""
        try:
            self._poll(0.0)
        except TransportClosed as e:
            self._recover(e)
        st = self._sessions.get(req_id)
        return st.up_acked if st is not None else 0

    def wait_acked(self, req_id: int, count: int,
                   timeout: Optional[float] = None) -> int:
        """Block until the cloud has processed at least ``count`` uplink
        frames of ``req_id`` (seconds-valued ``timeout`` composes with the
        transport deadline like :meth:`recv`).  Returns the acked count;
        raises :class:`TransportTimeout` / :class:`SessionLostError` /
        :class:`RemoteEngineError` exactly like a blocking ``recv``."""
        end, bound = self._op_deadline(timeout, self.recv_timeout_s)
        t_wait = self.clock()
        waited = False
        while True:
            self._raise_if_lost(req_id)
            self._raise_if_error(req_id)
            st = self._sessions.get(req_id)
            acked = st.up_acked if st is not None else 0
            if acked >= count:
                if waited:
                    # time blocked on the ack is cloud residency: the
                    # engine was consuming our earlier chunks
                    self.tracer.add_span(
                        "ack_wait", t_wait, self.clock(), tid=req_id,
                        phase="cloud_step", count=count,
                    )
                return acked
            waited = True
            remaining = end - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("wait_acked", bound, req_id)
            self._check_liveness()
            try:
                self._poll(min(remaining, _POLL_S))
            except TransportClosed as e:
                self._recover(e)

    # -------------------------------------------------------- session plane
    def open(self, req_id: int, expected_tokens: int) -> None:
        self._raise_if_lost(req_id)
        st = self._sessions.setdefault(req_id, _SessionState())
        st.expected_tokens = expected_tokens

        def _match(mtype: int, payload: bytes):
            if mtype == P.MSG_OPEN_OK and P.decode_u32(payload) == req_id:
                return (None,)
            return None

        self._control_roundtrip(
            P.MSG_OPEN, P.encode_u32_pair(req_id, expected_tokens),
            match=_match, op="open", req_id=req_id,
        )
        st.established = True

    def close(self, req_id: int) -> None:
        self._inbox.pop(req_id, None)
        lost = self._lost.pop(req_id, None)
        self._sessions.pop(req_id, None)
        if self._closed or lost is not None:
            return
        try:
            self._send_msg(P.MSG_CLOSE, P.encode_u32(req_id))
        except TransportClosed:
            # connection is down; the cloud's grace sweep reaps the slot
            # (the session is gone here, so no future resume re-attaches it)
            pass

    # -------------------------------------------------------- control plane
    def snapshot(self, req_id: int):
        """Ask the cloud to snapshot the slot's recurrent state; returns an
        opaque handle (the state itself never crosses the wire)."""
        self._raise_if_lost(req_id)

        def _match(mtype: int, payload: bytes):
            if mtype == P.MSG_SNAPSHOT_OK:
                rid, snap_id = P.decode_u32_pair(payload)
                if rid == req_id:
                    return (snap_id,)
            return None

        return self._control_roundtrip(
            P.MSG_SNAPSHOT, P.encode_u32(req_id),
            match=_match, op="snapshot", req_id=req_id,
        )

    def restore(self, req_id: int, snap) -> None:
        self._raise_if_lost(req_id)

        def _match(mtype: int, payload: bytes):
            if mtype == P.MSG_RESTORE_OK and P.decode_u32(payload) == req_id:
                return (None,)
            return None

        self._control_roundtrip(
            P.MSG_RESTORE, P.encode_u32_pair(req_id, int(snap)),
            match=_match, op="restore", req_id=req_id,
        )
