"""SocketTransport: the device's real TCP handle on a remote cloud.

Implements the :class:`repro.serving.api.Transport` protocol over the
length-prefixed stream of ``repro.net.protocol``, so a ``DeviceClient``
built on it is byte-for-byte the same client that runs over loopback —
only the wire is real: connect retry, a hello/version handshake, bounded
send/recv with :class:`~repro.net.errors.TransportTimeout`, and typed
cloud errors surfacing as :class:`~repro.net.errors.RemoteEngineError`.

The transport is single-threaded by design: every blocking wait drains
the socket inline and demultiplexes what arrives — downlink frames into
per-request inboxes (sessions interleaved through one connection never
steal each other's frames), control replies (open/snapshot/restore acks)
into a separate queue.  The clock is ``time.time()`` — the unix epoch is
the one clock device and cloud processes on a host share, which is what
makes cross-process trace merges and queue-delay attribution meaningful.
"""
from __future__ import annotations

import socket
import time
from collections import deque
from typing import Deque, Dict, Optional, Tuple

from ..obs import NULL_TRACER, Tracer
from ..serving.api import Transport
from ..wire import frame_req_id, frame_t_send, stamp_t_send
from . import protocol as P
from .errors import (
    ProtocolError,
    RemoteEngineError,
    TransportClosed,
    TransportError,
    TransportTimeout,
)

_POLL_S = 0.05           # socket timeout granularity while waiting


class SocketTransport(Transport):
    """TCP client transport speaking the ``repro.net`` stream protocol.

    * **Connect retry**: the cloud process may still be binding when the
      device comes up — ``connect_timeout_s`` bounds how long to keep
      retrying refused connections.
    * **Handshake**: first traffic is ``MSG_HELLO`` (protocol version,
      wire-frame version, d_model); the service answers ``MSG_HELLO_ACK``
      on exact match or a typed ``MSG_ERROR`` + close.  A d_model or
      version skew therefore fails in milliseconds, not with a shape
      error mid-prefill.
    * **Timeouts**: ``recv_timeout_s``/``send_timeout_s`` default every
      data-plane wait; per-call ``recv(req_id, timeout=...)`` overrides.
    * **Typed errors**: a ``MSG_ERROR`` carrying a req_id parks in that
      request's inbox and raises :class:`RemoteEngineError` out of the
      waiting ``recv``/control call — the session unwinds cleanly (its
      ``finally`` still sends ``MSG_CLOSE``) instead of hanging.
    """

    def __init__(
        self,
        host: str,
        port: int,
        *,
        d_model: int,
        connect_timeout_s: float = 10.0,
        retry_interval_s: float = 0.05,
        send_timeout_s: float = 30.0,
        recv_timeout_s: float = 60.0,
        max_message_bytes: int = P.MAX_MESSAGE_BYTES,
        tracer: Optional[Tracer] = None,
    ):
        self.host, self.port = host, port
        self.d_model = d_model
        self.send_timeout_s = send_timeout_s
        self.recv_timeout_s = recv_timeout_s
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.bytes_up = 0
        self.bytes_down = 0
        self._decoder = P.StreamDecoder(max_message_bytes=max_message_bytes)
        self._inbox: Dict[int, Deque] = {}       # req_id -> frames / errors
        self._control: Deque[Tuple[int, bytes]] = deque()
        self._closed = False
        self._sock = self._connect(connect_timeout_s, retry_interval_s)
        self._handshake()

    # ------------------------------------------------------------ connection
    def _connect(self, timeout_s: float, interval_s: float) -> socket.socket:
        deadline = time.monotonic() + timeout_s
        last: Optional[Exception] = None
        while time.monotonic() < deadline:
            try:
                sock = socket.create_connection(
                    (self.host, self.port), timeout=max(timeout_s, 1.0)
                )
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                return sock
            except OSError as e:                  # refused: server still booting
                last = e
                time.sleep(interval_s)
        raise TransportError(
            f"could not connect to {self.host}:{self.port} within "
            f"{timeout_s:.1f}s: {last}"
        )

    def _handshake(self) -> None:
        self._send_msg(P.MSG_HELLO, P.encode_hello(self.d_model))
        mtype, payload = self._wait_control(
            P.MSG_HELLO_ACK, timeout=self.recv_timeout_s, op="hello"
        )
        proto, frame_ver, d_model = P.decode_hello(payload)
        from ..wire import FRAME_VERSION

        if (proto, frame_ver, d_model) != (P.PROTO_VERSION, FRAME_VERSION,
                                           self.d_model):
            raise ProtocolError(
                f"hello mismatch: cloud speaks proto v{proto} / frame "
                f"v{frame_ver} / d_model {d_model}, device speaks "
                f"v{P.PROTO_VERSION}/v{FRAME_VERSION}/{self.d_model}"
            )

    def shutdown(self) -> None:
        """Graceful goodbye: tell the service, then close the socket."""
        if self._closed:
            return
        try:
            self._send_msg(P.MSG_BYE)
        except TransportError:
            pass
        self._closed = True
        self._sock.close()

    # ---------------------------------------------------------------- clock
    def clock(self) -> float:
        # unix epoch: the clock all processes on the host share, so frame
        # t_send stamps and trace spans line up across process boundaries
        return time.time()

    # ------------------------------------------------------------ low level
    def _send_msg(self, mtype: int, payload: bytes = b"") -> None:
        if self._closed:
            raise TransportClosed("transport already shut down")
        data = P.encode_msg(mtype, payload)
        self._sock.settimeout(self.send_timeout_s)
        try:
            self._sock.sendall(data)
        except socket.timeout:
            raise TransportTimeout("send", self.send_timeout_s) from None
        except OSError as e:
            raise TransportClosed(f"send failed: {e}") from e

    def _route(self, mtype: int, payload: bytes) -> None:
        if mtype == P.MSG_FRAME:
            rid = frame_req_id(payload)
            self.bytes_down += len(payload)
            t_arrive = self.clock()
            t_send = frame_t_send(payload)
            if 0.0 < t_send <= t_arrive:
                # sender stamped its send-complete time on our shared
                # (unix-epoch) clock: the gap is the real downlink hop
                self.tracer.add_span(
                    "downlink", t_send, t_arrive, tid=rid, phase="downlink",
                    nbytes=len(payload),
                )
            self._inbox.setdefault(rid, deque()).append(("frame", payload))
        elif mtype == P.MSG_ERROR:
            code, rid, msg = P.decode_error(payload)
            if code in (P.ERR_VERSION, P.ERR_PROTOCOL) or rid == 0:
                raise ProtocolError(
                    f"cloud rejected the connection "
                    f"({P.ERR_NAMES.get(code, code)}): {msg}"
                )
            self._inbox.setdefault(rid, deque()).append(
                ("error", RemoteEngineError(code, rid, msg))
            )
        elif mtype == P.MSG_BYE:
            self._closed = True
            raise TransportClosed("cloud said goodbye")
        else:
            self._control.append((mtype, payload))

    def _poll(self, timeout_s: float) -> None:
        """Read once from the socket (bounded) and route what arrived."""
        if self._closed:
            raise TransportClosed("transport already shut down")
        self._sock.settimeout(max(timeout_s, 0.0) or 1e-4)
        try:
            chunk = self._sock.recv(1 << 20)
        except socket.timeout:
            return
        except OSError as e:
            raise TransportClosed(f"recv failed: {e}") from e
        if not chunk:
            self._closed = True
            raise TransportClosed("connection closed by the cloud")
        for mtype, payload in self._decoder.feed(chunk):
            self._route(mtype, payload)

    def _wait_control(
        self, expect: int, *, timeout: float, op: str
    ) -> Tuple[int, bytes]:
        deadline = time.monotonic() + timeout
        while True:
            for i, (mtype, payload) in enumerate(self._control):
                if mtype == expect:
                    del self._control[i]
                    return mtype, payload
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout(op, timeout)
            self._poll(min(remaining, _POLL_S))

    def _raise_if_error(self, req_id: int) -> None:
        q = self._inbox.get(req_id)
        if q and q[0][0] == "error":
            _, exc = q.popleft()
            self._inbox.pop(req_id, None)
            raise exc

    # ----------------------------------------------------------- data plane
    def send(self, data: bytes) -> None:
        rid = frame_req_id(data)
        self._raise_if_error(rid)            # fail fast: session already dead
        t0 = self.clock()
        self.bytes_up += len(data)
        self._send_msg(P.MSG_FRAME, stamp_t_send(data, t0))
        self.tracer.add_span(
            "uplink", t0, self.clock(), tid=rid, phase="uplink",
            nbytes=len(data),
        )

    def has_frame(self, req_id: int) -> bool:
        """Non-blocking: drain the socket once, then check the inbox."""
        q = self._inbox.get(req_id)
        if not q:
            self._poll(0.0)
            q = self._inbox.get(req_id)
        return bool(q) and q[0][0] == "frame"

    def deliver(self, req_id: int) -> Optional[bytes]:
        """Non-blocking receive (concurrent-scheduler hook)."""
        self._raise_if_error(req_id)
        q = self._inbox.get(req_id)
        if q and q[0][0] == "frame":
            return q.popleft()[1]
        return None

    def recv(self, req_id: int, timeout: Optional[float] = None) -> bytes:
        timeout = self.recv_timeout_s if timeout is None else timeout
        deadline = time.monotonic() + timeout
        t_wait = self.clock()
        while True:
            self._raise_if_error(req_id)
            q = self._inbox.get(req_id)
            if q and q[0][0] == "frame":
                data = q.popleft()[1]
                t_send = frame_t_send(data)
                if 0.0 < t_send and t_wait < t_send:
                    # everything between entering recv and the cloud's
                    # send stamp is cloud residency (queue + step); the
                    # downlink hop itself was spanned at arrival
                    self.tracer.add_span(
                        "cloud_wait", t_wait, t_send, tid=req_id,
                        phase="cloud_step",
                    )
                return data
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("recv", timeout, req_id)
            self._poll(min(remaining, _POLL_S))

    # -------------------------------------------------------- session plane
    def open(self, req_id: int, expected_tokens: int) -> None:
        self._send_msg(P.MSG_OPEN, P.encode_u32_pair(req_id, expected_tokens))
        deadline = time.monotonic() + self.recv_timeout_s
        while True:
            self._raise_if_error(req_id)
            for i, (mtype, payload) in enumerate(self._control):
                if mtype == P.MSG_OPEN_OK and P.decode_u32(payload) == req_id:
                    del self._control[i]
                    return
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("open", self.recv_timeout_s, req_id)
            self._poll(min(remaining, _POLL_S))

    def close(self, req_id: int) -> None:
        self._inbox.pop(req_id, None)
        if not self._closed:
            self._send_msg(P.MSG_CLOSE, P.encode_u32(req_id))

    # -------------------------------------------------------- control plane
    def snapshot(self, req_id: int):
        """Ask the cloud to snapshot the slot's recurrent state; returns an
        opaque handle (the state itself never crosses the wire)."""
        self._send_msg(P.MSG_SNAPSHOT, P.encode_u32(req_id))
        deadline = time.monotonic() + self.recv_timeout_s
        while True:
            self._raise_if_error(req_id)
            for i, (mtype, payload) in enumerate(self._control):
                if mtype == P.MSG_SNAPSHOT_OK:
                    rid, snap_id = P.decode_u32_pair(payload)
                    if rid == req_id:
                        del self._control[i]
                        return snap_id
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("snapshot", self.recv_timeout_s, req_id)
            self._poll(min(remaining, _POLL_S))

    def restore(self, req_id: int, snap) -> None:
        self._send_msg(P.MSG_RESTORE, P.encode_u32_pair(req_id, int(snap)))
        deadline = time.monotonic() + self.recv_timeout_s
        while True:
            self._raise_if_error(req_id)
            for i, (mtype, payload) in enumerate(self._control):
                if mtype == P.MSG_RESTORE_OK and P.decode_u32(payload) == req_id:
                    del self._control[i]
                    return
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TransportTimeout("restore", self.recv_timeout_s, req_id)
            self._poll(min(remaining, _POLL_S))
