"""CloudService: the cloud half of HAT as a real network server.

Wraps a :class:`repro.serving.api.CloudServer` (and its slot-batched
:class:`~repro.serving.engine.CloudEngine`) behind the
``repro.net.protocol`` stream so genuinely separate device *processes*
drive it over TCP:

* one **accept loop** hands each connection to a per-connection **reader
  thread** that decodes messages and — crucially — does the host-side
  framing/codec work (``Frame.from_bytes`` + dequantize) *outside* the
  engine lock, so uplink decode for device B overlaps the engine
  device-step for device A (the async-dispatch follow-up from the
  concurrent-runtime PR);
* one shared **pump loop** thread runs slot-batched engine steps whenever
  jobs are queued and routes each deep-state result back to the owning
  connection (downlink re-encode also happens outside the lock);
* session lifecycle, SSM snapshot/restore (snapshots stay cloud-resident;
  only an opaque handle crosses the wire) and **typed errors** — an
  :class:`~repro.serving.engine.EngineOverflowError` raised at submit
  becomes a ``MSG_ERROR``/``ERR_OVERFLOW`` for the owning request instead
  of a poisoned in-process exception nobody on the device can see.

Run it as a process::

    PYTHONPATH=src python -m repro.net.service --arch internlm2-1.8b --port 0

It prints ``NET_SERVE listening on HOST:PORT`` once ready (port 0 binds an
ephemeral port; the launcher parses the line), serves until SIGTERM/SIGINT,
and dumps its flight-recorder trace (``--trace-out``) on the way down.
All service spans run on the unix-epoch clock (``time.time()``), the one
clock device and cloud processes on a host share — merged traces stay
causally ordered across processes.
"""
from __future__ import annotations

import argparse
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from ..obs import NULL_TRACER, TID_CLOUD, Tracer
from ..serving.api import CloudServer
from ..serving.engine import EngineJob, EngineOverflowError
from ..wire import FRAME_VERSION, Frame, KIND_DEEP, decode_hidden, stamp_t_send
from . import protocol as P
from .errors import ProtocolError

_ACCEPT_POLL_S = 0.2
_PUMP_IDLE_S = 0.05


@dataclass
class _Conn:
    """One device connection: socket + its protocol state."""

    sock: socket.socket
    peer: str
    decoder: P.StreamDecoder
    send_lock: threading.Lock = field(default_factory=threading.Lock)
    hello_done: bool = False
    open_reqs: set = field(default_factory=set)
    snapshots: Dict[int, object] = field(default_factory=dict)
    next_snap_id: int = 1
    alive: bool = True

    def send_msg(self, mtype: int, payload: bytes = b"") -> None:
        data = P.encode_msg(mtype, payload)
        try:
            with self.send_lock:
                self.sock.sendall(data)
        except OSError:
            self.alive = False


class CloudService:
    """TCP server process around a frame-speaking :class:`CloudServer`.

    Thread layout: N reader threads (one per live connection) + 1 pump
    thread + 1 accept thread.  The engine lock serializes every mutation
    of engine state (submit, step, session lifecycle, snapshot/restore);
    codec encode/decode run outside it.  JAX stays effectively
    single-threaded: only the pump thread ever calls ``engine.step``.
    """

    def __init__(
        self,
        server: CloudServer,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        max_message_bytes: int = P.MAX_MESSAGE_BYTES,
        tracer: Optional[Tracer] = None,
    ):
        self.server = server
        self.host = host
        self.port = port
        self.max_message_bytes = max_message_bytes
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._lock = threading.Lock()            # engine + session state
        self._work = threading.Condition()       # pump wake-up
        self._stop = threading.Event()
        self._conns: list = []
        self._conn_of: Dict[int, _Conn] = {}     # req_id -> owning connection
        self._threads: list = []
        self._listener: Optional[socket.socket] = None
        self.sessions_served = 0
        self.frames_in = 0
        self.frames_out = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> Tuple[str, int]:
        """Bind + spawn the accept and pump threads; returns (host, port)."""
        ls = socket.create_server((self.host, self.port))
        ls.settimeout(_ACCEPT_POLL_S)
        self._listener = ls
        self.port = ls.getsockname()[1]
        for fn in (self._accept_loop, self._pump_loop):
            t = threading.Thread(target=fn, daemon=True, name=fn.__name__)
            t.start()
            self._threads.append(t)
        return self.host, self.port

    def stop(self) -> None:
        self._stop.set()
        with self._work:
            self._work.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._listener is not None:
            self._listener.close()
        for conn in list(self._conns):
            conn.sock.close()

    def wait(self, timeout: Optional[float] = None) -> None:
        self._stop.wait(timeout)

    # ---------------------------------------------------------- accept loop
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(
                sock=sock, peer=f"{addr[0]}:{addr[1]}",
                decoder=P.StreamDecoder(max_message_bytes=self.max_message_bytes),
            )
            self._conns.append(conn)
            t = threading.Thread(
                target=self._reader_loop, args=(conn,), daemon=True,
                name=f"reader-{conn.peer}",
            )
            t.start()
            self._threads.append(t)

    # ---------------------------------------------------------- reader loop
    def _reader_loop(self, conn: _Conn) -> None:
        sock = conn.sock
        sock.settimeout(_ACCEPT_POLL_S)
        try:
            while not self._stop.is_set() and conn.alive:
                try:
                    chunk = sock.recv(1 << 20)
                except socket.timeout:
                    continue
                except OSError:
                    break
                if not chunk:
                    break
                for mtype, payload in conn.decoder.feed(chunk):
                    if not self._dispatch(conn, mtype, payload):
                        return
        except ProtocolError as e:
            conn.send_msg(P.MSG_ERROR,
                          P.encode_error(P.ERR_PROTOCOL, 0, str(e)))
        finally:
            self._drop_conn(conn)

    def _dispatch(self, conn: _Conn, mtype: int, payload: bytes) -> bool:
        """Handle one message; returns False to end the connection."""
        if mtype == P.MSG_HELLO:
            return self._on_hello(conn, payload)
        if not conn.hello_done:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, 0, "first message must be hello"))
            return False
        if mtype == P.MSG_FRAME:
            self._on_frame(conn, payload)
        elif mtype == P.MSG_OPEN:
            self._on_open(conn, payload)
        elif mtype == P.MSG_CLOSE:
            self._close_session(conn, P.decode_u32(payload))
        elif mtype == P.MSG_SNAPSHOT:
            self._on_snapshot(conn, P.decode_u32(payload))
        elif mtype == P.MSG_RESTORE:
            self._on_restore(conn, payload)
        elif mtype == P.MSG_BYE:
            return False
        else:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, 0, f"unroutable message type {mtype}"))
            return False
        return True

    def _on_hello(self, conn: _Conn, payload: bytes) -> bool:
        proto, frame_ver, d_model = P.decode_hello(payload)
        ours = (P.PROTO_VERSION, FRAME_VERSION, self.server.d_model)
        if (proto, frame_ver, d_model) != ours:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_VERSION, 0,
                f"device speaks proto v{proto} / frame v{frame_ver} / "
                f"d_model {d_model}; cloud speaks "
                f"v{ours[0]}/v{ours[1]}/{ours[2]}"))
            return False
        conn.hello_done = True
        conn.send_msg(P.MSG_HELLO_ACK, P.encode_hello(self.server.d_model))
        return True

    def _on_open(self, conn: _Conn, payload: bytes) -> None:
        req_id, expected = P.decode_u32_pair(payload)
        with self._lock:
            ok = self.server.open_session(req_id, expected)
            if ok:
                self._conn_of[req_id] = conn
                conn.open_reqs.add(req_id)
                self.sessions_served += 1
        if ok:
            conn.send_msg(P.MSG_OPEN_OK, P.encode_u32(req_id))
        else:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_REJECTED, req_id,
                "no free slot / KV budget for the session"))

    def _on_frame(self, conn: _Conn, payload: bytes) -> None:
        self.frames_in += 1
        engine = self.server.engine
        # the expensive half of ingress — header parse + codec dequantize —
        # runs here in the reader thread, overlapping the pump thread's
        # engine step; only the queue append needs the lock
        frame = Frame.from_bytes(payload)
        if frame.kind == KIND_DEEP:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, frame.req_id,
                "deep frames flow cloud->device"))
            return
        hidden = decode_hidden(frame, engine.d_model)
        engine.wire_bytes_in += frame.nbytes()
        job = EngineJob(frame.req_id, hidden, frame.offset, frame.kind_name,
                        want_deep=frame.want_deep, ready_s=frame.t_send)
        try:
            with self._lock:
                if frame.req_id not in self._conn_of:
                    raise ProtocolError(
                        f"frame for unopened session {frame.req_id}"
                    )
                engine.submit(job)
            with self._work:
                self._work.notify()
        except EngineOverflowError as e:
            # typed propagation: the device's recv for this req raises
            # RemoteEngineError instead of waiting forever on a downlink
            # that will never come (the engine already released the slot)
            with self._lock:
                self._conn_of.pop(e.req_id, None)
                conn.open_reqs.discard(e.req_id)
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_OVERFLOW, e.req_id, str(e)))
        except ProtocolError as e:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_INTERNAL, frame.req_id, str(e)))

    def _on_snapshot(self, conn: _Conn, req_id: int) -> None:
        with self._lock:
            snap = self.server.snapshot_session(req_id)
            snap_id = conn.next_snap_id
            conn.next_snap_id += 1
            conn.snapshots[snap_id] = snap
        conn.send_msg(P.MSG_SNAPSHOT_OK, P.encode_u32_pair(req_id, snap_id))

    def _on_restore(self, conn: _Conn, payload: bytes) -> None:
        req_id, snap_id = P.decode_u32_pair(payload)
        snap = conn.snapshots.get(snap_id)
        if snap is None:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_INTERNAL, req_id, f"unknown snapshot {snap_id}"))
            return
        with self._lock:
            self.server.restore_session(req_id, snap)
        conn.send_msg(P.MSG_RESTORE_OK, P.encode_u32(req_id))

    def _close_session(self, conn: _Conn, req_id: int) -> None:
        with self._lock:
            self.server.close_session(req_id)
            self._conn_of.pop(req_id, None)
            conn.open_reqs.discard(req_id)

    def _drop_conn(self, conn: _Conn) -> None:
        conn.alive = False
        for rid in list(conn.open_reqs):
            self._close_session(conn, rid)
        conn.snapshots.clear()
        if conn in self._conns:
            self._conns.remove(conn)
        conn.sock.close()

    # ------------------------------------------------------------ pump loop
    def _pump_loop(self) -> None:
        engine = self.server.engine
        while not self._stop.is_set():
            with self._work:
                if not engine.queue:
                    self._work.wait(_PUMP_IDLE_S)
            if not engine.queue:
                continue
            t0 = time.time()
            with self._lock:
                if not engine.queue:
                    continue
                results = engine.step()
                info = engine.last_step_info
                tokens = engine.batched_token_history[-1]
            t1 = time.time()
            if self.tracer.enabled:
                # real wall-clock queue/cloud spans, per request, on the
                # shared unix-epoch clock (frame t_send stamps are on it
                # too, so queue_wait = device-send-complete -> step start)
                self.tracer.add_span(
                    "cloud_step", t0, t1, tid=TID_CLOUD,
                    tokens=tokens, jobs=len(info),
                )
                for j in info:
                    if 0.0 < j["ready_s"] <= t0:
                        self.tracer.add_span(
                            "queue_wait", j["ready_s"], t0, tid=j["req_id"],
                            phase="queue", tokens=j["tokens"],
                        )
                    self.tracer.add_span(
                        "cloud_step", t0, t1, tid=j["req_id"],
                        phase="cloud_step", tokens=j["tokens"],
                    )
            for r in results:
                if r.deep is None:
                    continue
                conn = self._conn_of.get(r.req_id)
                if conn is None or not conn.alive:
                    continue                       # device went away mid-step
                data = self.server.engine.encode_result(r)   # outside lock
                conn.send_msg(P.MSG_FRAME, stamp_t_send(data, time.time()))
                self.frames_out += 1


# ---------------------------------------------------------------------------
# process entry point
# ---------------------------------------------------------------------------


def build_server(arch: str, *, slots: int, max_len: int,
                 max_batch_tokens: Optional[int], wire_codec: str,
                 seed: int = 0, tracer: Optional[Tracer] = None) -> CloudServer:
    """Deterministic cloud-side model build: device processes that build
    from the same (arch, seed) hold bit-identical submodel params, which
    is what makes socket-vs-loopback token parity a meaningful check."""
    import jax

    from ..configs import get_config
    from ..core import split_model
    from ..models import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    split = split_model(cfg, params)
    return CloudServer(
        split, n_slots=slots, max_len=max_len,
        max_batch_tokens=max_batch_tokens, wire_codec=wire_codec,
        tracer=tracer,
    )


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="repro.net cloud service process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on stdout)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-batch-tokens", type=int, default=256)
    ap.add_argument("--wire-codec", default="fp16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="dump the service's Chrome trace on shutdown")
    args = ap.parse_args(argv)

    tracer = Tracer(clock=time.time) if args.trace_out else None
    server = build_server(
        args.arch, slots=args.slots, max_len=args.max_len,
        max_batch_tokens=args.max_batch_tokens, wire_codec=args.wire_codec,
        seed=args.seed, tracer=tracer,
    )
    svc = CloudService(server, host=args.host, port=args.port, tracer=tracer)
    host, port = svc.start()
    # the launcher greps for this exact line to learn the ephemeral port
    print(f"NET_SERVE listening on {host}:{port}", flush=True)

    import signal

    def _term(signum, frame):
        svc._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    try:
        while not svc._stop.is_set():
            svc.wait(0.2)
    finally:
        svc.stop()
        if tracer is not None:
            tracer.dump(args.trace_out)
        print(f"NET_SERVE done: {svc.sessions_served} sessions, "
              f"{svc.frames_in} frames in / {svc.frames_out} out, "
              f"{server.engine.steps} engine steps", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
