"""CloudService: the cloud half of HAT as a real network server.

Wraps a :class:`repro.serving.api.CloudServer` (and its slot-batched
:class:`~repro.serving.engine.CloudEngine`) behind the
``repro.net.protocol`` stream so genuinely separate device *processes*
drive it over TCP:

* one **accept loop** hands each connection to a per-connection **reader
  thread** that decodes messages and — crucially — does the host-side
  framing/codec work (``Frame.from_bytes`` + dequantize) *outside* the
  engine lock, so uplink decode for device B overlaps the engine
  device-step for device A (the async-dispatch follow-up from the
  concurrent-runtime PR);
* one shared **pump loop** thread runs slot-batched engine steps whenever
  jobs are queued and routes each deep-state result back to the owning
  connection (downlink re-encode also happens outside the lock);
* session lifecycle, SSM snapshot/restore (snapshots stay cloud-resident;
  only an opaque handle crosses the wire) and **typed errors** — an
  :class:`~repro.serving.engine.EngineOverflowError` raised at submit
  becomes a ``MSG_ERROR``/``ERR_OVERFLOW`` for the owning request instead
  of a poisoned in-process exception nobody on the device can see.

Fault tolerance (protocol v2)
-----------------------------
Sessions now outlive connections.  Each accepted connection gets a
monotonic **epoch** (returned in the hello ack); each session records the
epoch of the connection that owns it.  When a connection dies *without* a
``MSG_BYE``, its sessions **detach** instead of closing: the engine slot
— KV cache, SSM state, cloud-resident snapshots — stays alive for
``grace_s`` seconds.  A reconnecting device presents its previous epoch
and per-session watermarks in ``MSG_RESUME``; the service re-attaches
every session no other live connection owns, answers with its own
``up_expected`` watermark per session (so the device replays exactly the
uplink frames the service never processed), and re-sends any buffered
downlink frames past the device's watermark.  Sequence numbers on every
``MSG_FRAME`` make replays idempotent: a duplicate uplink is dropped by
watermark before it can double-step the engine.  Sessions that stay
detached past the grace period are closed; a later resume simply omits
them, which the device surfaces as ``SessionLostError``.

Backpressure: each connection has a bounded in-flight frame window
(``max_inflight_frames``).  At the bound the reader sends ``MSG_BUSY``
and *stops draining its socket* — TCP flow control pushes back to the
device — until the pump works the window down and sends ``MSG_READY``.
The accept path is bounded too (``max_connections``): excess connections
get a typed ``ERR_BUSY`` and an immediate close, so a connection storm
cannot exhaust reader threads.

Run it as a process::

    PYTHONPATH=src python -m repro.net.service --arch internlm2-1.8b --port 0

It prints ``NET_SERVE listening on HOST:PORT`` once ready (port 0 binds an
ephemeral port; the launcher parses the line), serves until SIGTERM/SIGINT,
and dumps its flight-recorder trace (``--trace-out``) on the way down.
All service spans run on the unix-epoch clock (``time.time()``), the one
clock device and cloud processes on a host share — merged traces stay
causally ordered across processes.
"""
from __future__ import annotations

import argparse
import socket
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

from ..obs import NULL_TRACER, TID_CLOUD, Tracer
from ..serving.api import CloudServer
from ..serving.engine import EngineJob, EngineOverflowError
from ..wire import FRAME_VERSION, Frame, KIND_DEEP, decode_hidden, stamp_t_send
from . import protocol as P
from .errors import ProtocolError

_ACCEPT_POLL_S = 0.2
_PUMP_IDLE_S = 0.05
_DOWN_BUFFER_FRAMES = 4      # strict request/response: >1 outstanding is rare


@dataclass
class _Conn:
    """One device connection: socket + its protocol state."""

    sock: socket.socket
    peer: str
    decoder: P.StreamDecoder
    send_lock: threading.Lock = field(default_factory=threading.Lock)
    hello_done: bool = False
    epoch: int = 0
    open_reqs: set = field(default_factory=set)
    inflight: int = 0            # frames submitted, not yet stepped
    busy_sent: bool = False
    said_bye: bool = False
    alive: bool = True

    def send_msg(self, mtype: int, payload: bytes = b"") -> None:
        data = P.encode_msg(mtype, payload)
        try:
            with self.send_lock:
                self.sock.sendall(data)
        except OSError:
            self.alive = False


@dataclass
class _NetSession:
    """Cloud-side wire state for one session; outlives its connection."""

    req_id: int
    epoch: int                               # epoch of the owning connection
    conn: Optional[_Conn]
    up_expected: int = 0                     # next uplink seq to process
    up_processed: int = 0                    # uplink frames the engine stepped
    down_seq: int = 0                        # next downlink seq to assign
    down_buffer: Deque[Tuple[int, bytes]] = field(
        default_factory=lambda: deque(maxlen=_DOWN_BUFFER_FRAMES)
    )
    snapshots: Dict[int, object] = field(default_factory=dict)
    next_snap_id: int = 1
    detached_at: Optional[float] = None      # monotonic; None while attached


class CloudService:
    """TCP server process around a frame-speaking :class:`CloudServer`.

    Thread layout: N reader threads (one per live connection) + 1 pump
    thread + 1 accept thread.  The engine lock serializes every mutation
    of engine state (submit, step, session lifecycle, snapshot/restore);
    codec encode/decode run outside it.  JAX stays effectively
    single-threaded: only the pump thread ever calls ``engine.step``.

    ``grace_s`` bounds how long a detached session keeps its slot;
    ``max_inflight_frames`` bounds each connection's in-flight window
    (0 disables backpressure); ``max_connections`` caps the accept path
    (0 = unbounded).
    """

    def __init__(
        self,
        server: CloudServer,
        *,
        host: str = "127.0.0.1",
        port: int = 0,
        grace_s: float = 30.0,
        max_inflight_frames: int = 32,
        max_connections: int = 64,
        max_message_bytes: int = P.MAX_MESSAGE_BYTES,
        checkpoint_path: Optional[str] = None,
        checkpoint_every_s: float = 0.0,
        tracer: Optional[Tracer] = None,
    ):
        self.server = server
        self.host = host
        self.port = port
        self.grace_s = grace_s
        self.max_inflight_frames = max_inflight_frames
        self.max_connections = max_connections
        self.max_message_bytes = max_message_bytes
        self.checkpoint_path = checkpoint_path
        self.checkpoint_every_s = checkpoint_every_s
        self._last_checkpoint_t = 0.0
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._lock = threading.Lock()            # engine + session state
        self._work = threading.Condition()       # pump + backpressure wake-up
        # checkpoint consistency gate: True from the moment a pump step
        # mutates the engine cache until every downlink the step produced
        # is sequenced into its session's buffer.  state_dict() waits for
        # it so a snapshot can never see up_processed advanced past a
        # downlink that does not exist yet (restoring such a cut shifts
        # every later downlink seq down by one and the device drops them
        # all as duplicates).  The condition shares self._lock.
        self._pump_busy = False
        self._idle_cv = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._conns: list = []
        self._sessions: Dict[int, _NetSession] = {}
        self._next_epoch = 1
        self._threads: list = []
        self._listener: Optional[socket.socket] = None
        self.sessions_served = 0
        self.frames_in = 0
        self.frames_out = 0
        self.resumes_served = 0
        self.frames_replayed = 0
        self.dup_frames_dropped = 0
        self.conns_rejected = 0
        self.detaches = 0
        self.restart_epoch = 0          # bumps by 1 on every restore-boot
        self.sessions_restored = 0
        self.checkpoints_written = 0

    # ------------------------------------------------------------ lifecycle
    def start(self) -> Tuple[str, int]:
        """Bind + spawn the accept and pump threads; returns (host, port)."""
        ls = socket.create_server((self.host, self.port))
        ls.settimeout(_ACCEPT_POLL_S)
        self._listener = ls
        self.port = ls.getsockname()[1]
        for fn in (self._accept_loop, self._pump_loop):
            t = threading.Thread(target=fn, daemon=True, name=fn.__name__)
            t.start()
            self._threads.append(t)
        return self.host, self.port

    def stop(self) -> None:
        self._stop.set()
        with self._work:
            self._work.notify_all()
        for t in self._threads:
            t.join(timeout=5.0)
        if self._listener is not None:
            self._listener.close()
        for conn in list(self._conns):
            conn.sock.close()

    def wait(self, timeout: Optional[float] = None) -> None:
        self._stop.wait(timeout)

    # ---------------------------------------------------------- accept loop
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            if self.max_connections and len(self._conns) >= self.max_connections:
                # typed rejection: the device sees a connection-wide
                # ERR_BUSY instead of a silent close mid-handshake
                self.conns_rejected += 1
                threading.Thread(target=self._reject_conn, args=(sock,),
                                 daemon=True).start()
                continue
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(
                sock=sock, peer=f"{addr[0]}:{addr[1]}",
                decoder=P.StreamDecoder(max_message_bytes=self.max_message_bytes),
            )
            self._conns.append(conn)
            t = threading.Thread(
                target=self._reader_loop, args=(conn,), daemon=True,
                name=f"reader-{conn.peer}",
            )
            t.start()
            self._threads.append(t)

    def _reject_conn(self, sock: socket.socket) -> None:
        """Send the typed rejection, then linger-drain before closing so
        the error reaches the device instead of being flushed by an RST
        (the device's hello is usually still in flight)."""
        try:
            sock.sendall(P.encode_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_BUSY, 0,
                f"connection limit ({self.max_connections}) reached")))
            sock.shutdown(socket.SHUT_WR)
            sock.settimeout(1.0)
            while sock.recv(1 << 12):
                pass
        except OSError:
            pass
        finally:
            sock.close()

    # ---------------------------------------------------------- reader loop
    def _reader_loop(self, conn: _Conn) -> None:
        sock = conn.sock
        sock.settimeout(_ACCEPT_POLL_S)
        try:
            while not self._stop.is_set() and conn.alive:
                try:
                    chunk = sock.recv(1 << 20)
                except socket.timeout:
                    continue
                except OSError:
                    break
                if not chunk:
                    break
                for mtype, payload in conn.decoder.feed(chunk):
                    if not self._dispatch(conn, mtype, payload):
                        return
        except ProtocolError as e:
            conn.send_msg(P.MSG_ERROR,
                          P.encode_error(P.ERR_PROTOCOL, 0, str(e)))
        finally:
            # BYE is the device saying "done": close its sessions.  Any
            # other exit (EOF, reset, protocol garbage from a faulty link)
            # detaches them instead — the device may be about to resume.
            self._drop_conn(conn, graceful=conn.said_bye)

    def _dispatch(self, conn: _Conn, mtype: int, payload: bytes) -> bool:
        """Handle one message; returns False to end the connection."""
        if mtype == P.MSG_HELLO:
            return self._on_hello(conn, payload)
        if not conn.hello_done:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, 0, "first message must be hello"))
            return False
        if mtype == P.MSG_FRAME:
            self._on_frame(conn, payload)
        elif mtype == P.MSG_OPEN:
            self._on_open(conn, payload)
        elif mtype == P.MSG_CLOSE:
            self._close_session(conn, P.decode_u32(payload))
        elif mtype == P.MSG_RESUME:
            self._on_resume(conn, payload)
        elif mtype == P.MSG_PING:
            conn.send_msg(P.MSG_PONG)
        elif mtype == P.MSG_SNAPSHOT:
            self._on_snapshot(conn, P.decode_u32(payload))
        elif mtype == P.MSG_RESTORE:
            self._on_restore(conn, payload)
        elif mtype == P.MSG_BYE:
            conn.said_bye = True
            return False
        else:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, 0, f"unroutable message type {mtype}"))
            return False
        return True

    def _on_hello(self, conn: _Conn, payload: bytes) -> bool:
        proto, frame_ver, d_model, _epoch, _restart = P.decode_hello(payload)
        ours = (P.PROTO_VERSION, FRAME_VERSION, self.server.d_model)
        if (proto, frame_ver, d_model) != ours:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_VERSION, 0,
                f"device speaks proto v{proto} / frame v{frame_ver} / "
                f"d_model {d_model}; cloud speaks "
                f"v{ours[0]}/v{ours[1]}/{ours[2]}"))
            return False
        with self._lock:
            conn.epoch = self._next_epoch
            self._next_epoch += 1
        conn.hello_done = True
        conn.send_msg(P.MSG_HELLO_ACK, P.encode_hello(
            self.server.d_model, epoch=conn.epoch,
            restart_epoch=self.restart_epoch))
        return True

    # -------------------------------------------------- session transitions
    # detach -> resume and detach -> expire both mutate the same session;
    # the bug class this guards against is a grace sweep firing *between*
    # a resume's "is it still alive?" check and its re-attach.  Every
    # transition therefore happens through these helpers, under self._lock,
    # and expiry is decided by one authoritative predicate at the moment of
    # attach — not by whether the sweep thread happened to run first.

    def _expired_locked(self, sess: _NetSession, now: float) -> bool:
        """Authoritative grace verdict for one session (lock held).

        Strictly-greater: a resume arriving exactly at the grace boundary
        deterministically wins, no matter how the sweep is scheduled."""
        return (sess.conn is None and sess.detached_at is not None
                and self.grace_s is not None
                and now - sess.detached_at > self.grace_s)

    def _expire_locked(self, req_id: int) -> None:
        """Close one grace-expired session (lock held)."""
        self.server.close_session(req_id)
        self._sessions.pop(req_id, None)
        self.tracer.instant("grace_expired", time.time(), tid=req_id)

    def _attach_locked(self, sess: _NetSession, conn: _Conn) -> None:
        """The single detach->attach transition (open + resume paths,
        lock held): once ``detached_at`` clears here, no sweep can expire
        the session."""
        owner = sess.conn
        if owner is not None and owner is not conn:
            owner.open_reqs.discard(sess.req_id)
        sess.conn = conn
        sess.epoch = conn.epoch
        sess.detached_at = None
        conn.open_reqs.add(sess.req_id)

    def _on_open(self, conn: _Conn, payload: bytes) -> None:
        req_id, expected = P.decode_u32_pair(payload)
        with self._lock:
            sess = self._sessions.get(req_id)
            if sess is not None and self._expired_locked(sess, time.monotonic()):
                self._expire_locked(req_id)
                sess = None
            if sess is not None:
                owner = sess.conn
                if owner is not None and owner.alive and owner is not conn:
                    conn.send_msg(P.MSG_ERROR, P.encode_error(
                        P.ERR_REJECTED, req_id,
                        "session owned by another live connection"))
                    return
                # idempotent re-open: a duplicate OPEN after a reconnect
                # (the OPEN_OK was lost) adopts the existing session
                self._attach_locked(sess, conn)
                ok = True
            else:
                ok = self.server.open_session(req_id, expected)
                if ok:
                    self._sessions[req_id] = _NetSession(
                        req_id=req_id, epoch=conn.epoch, conn=conn,
                    )
                    conn.open_reqs.add(req_id)
                    self.sessions_served += 1
        if ok:
            conn.send_msg(P.MSG_OPEN_OK, P.encode_u32(req_id))
        else:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_REJECTED, req_id,
                "no free slot / KV budget for the session"))

    def _on_resume(self, conn: _Conn, payload: bytes) -> None:
        """Re-attach the sessions a reconnecting device presents.

        Each accepted session is answered with the service's own
        ``up_expected`` watermark; buffered downlink frames past the
        device's watermark are re-sent (re-stamped, so downlink spans
        stay honest).  Sessions that are gone (grace expired) or owned
        by another live connection are simply omitted — the device turns
        that into ``SessionLostError``."""
        prev_epoch, entries = P.decode_resume(payload)
        accepted: List[Tuple[int, int]] = []
        replays: List[Tuple[int, List[Tuple[int, bytes]]]] = []
        with self._lock:
            now = time.monotonic()
            for req_id, _up_sent, down_recv in entries:
                sess = self._sessions.get(req_id)
                if sess is None:
                    continue                     # closed / grace expired
                if self._expired_locked(sess, now):
                    # past the grace boundary but unswept: the verdict must
                    # not depend on sweep cadence — expire it here, refuse
                    self._expire_locked(req_id)
                    continue
                owner = sess.conn
                if owner is not None and owner.alive and owner is not conn:
                    continue                     # actively owned elsewhere
                if sess.epoch != prev_epoch and owner is not None and owner.alive:
                    continue                     # stale resume for a live conn
                self._attach_locked(sess, conn)
                accepted.append((req_id, sess.up_expected))
                pending = [(s, d) for s, d in sess.down_buffer
                           if s >= down_recv]
                if pending:
                    replays.append((req_id, pending))
            acks = [(rid, self._sessions[rid].up_processed)
                    for rid, _ in accepted]
            self.resumes_served += len(accepted)
        conn.send_msg(P.MSG_RESUME_OK, P.encode_resume_ok(accepted))
        # re-sync the device's processed watermark: FRAME_ACKs emitted while
        # the session was detached died with the old connection, and a
        # pipelined device may be blocked on one before it sends more chunks
        for rid, processed in acks:
            if processed > 0:
                conn.send_msg(P.MSG_FRAME_ACK,
                              P.encode_u32_pair(rid, processed))
        for req_id, pending in replays:
            for seq, data in pending:
                conn.send_msg(P.MSG_FRAME, P.encode_seq_frame(
                    seq, stamp_t_send(data, time.time())))
                self.frames_replayed += 1
                self.frames_out += 1
        self.tracer.instant(
            "resume", time.time(), tid=TID_CLOUD,
            sessions=len(accepted), refused=len(entries) - len(accepted),
        )

    def _on_frame(self, conn: _Conn, payload: bytes) -> None:
        seq, raw = P.decode_seq_frame(payload)
        engine = self.server.engine
        # the expensive half of ingress — header parse + codec dequantize —
        # runs here in the reader thread, overlapping the pump thread's
        # engine step; only the queue append needs the lock
        frame = Frame.from_bytes(raw)
        if frame.kind == KIND_DEEP:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_PROTOCOL, frame.req_id,
                "deep frames flow cloud->device"))
            return
        sess = self._sessions.get(frame.req_id)
        if sess is not None and seq < sess.up_expected:
            # replayed / duplicated uplink the engine already consumed:
            # watermark dedupe keeps the step exactly-once
            self.dup_frames_dropped += 1
            return
        self.frames_in += 1
        self._apply_backpressure(conn)
        hidden = decode_hidden(frame, engine.d_model)
        engine.wire_bytes_in += frame.nbytes()
        job = EngineJob(frame.req_id, hidden, frame.offset, frame.kind_name,
                        want_deep=frame.want_deep, ready_s=frame.t_send)
        try:
            with self._lock:
                sess = self._sessions.get(frame.req_id)
                if sess is None:
                    raise ProtocolError(
                        f"frame for unopened session {frame.req_id}"
                    )
                if seq != sess.up_expected:
                    raise ProtocolError(
                        f"uplink gap for request {frame.req_id}: got seq "
                        f"{seq}, expected {sess.up_expected}"
                    )
                engine.submit(job)
                sess.up_expected = seq + 1
                conn.inflight += 1
            with self._work:
                self._work.notify()
        except EngineOverflowError as e:
            # typed propagation: the device's recv for this req raises
            # RemoteEngineError instead of waiting forever on a downlink
            # that will never come (the engine already released the slot)
            with self._lock:
                self._sessions.pop(e.req_id, None)
                conn.open_reqs.discard(e.req_id)
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_OVERFLOW, e.req_id, str(e)))
        except ProtocolError as e:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_INTERNAL, frame.req_id, str(e)))

    def _apply_backpressure(self, conn: _Conn) -> None:
        """Hold this reader while the connection's in-flight window is
        full: send ``MSG_BUSY`` once, then stop draining — TCP flow
        control propagates the stall to the device — until the pump
        works the window down and ``MSG_READY`` goes out."""
        if self.max_inflight_frames <= 0:
            return
        if conn.inflight < self.max_inflight_frames:
            return
        if not conn.busy_sent:
            conn.busy_sent = True
            conn.send_msg(P.MSG_BUSY, P.encode_u32(conn.inflight))
            self.tracer.instant(
                "busy", time.time(), tid=TID_CLOUD, inflight=conn.inflight,
            )
        with self._work:
            while (conn.inflight >= self.max_inflight_frames
                   and conn.alive and not self._stop.is_set()):
                self._work.wait(_PUMP_IDLE_S)

    def _on_snapshot(self, conn: _Conn, req_id: int) -> None:
        with self._lock:
            sess = self._sessions.get(req_id)
            if sess is None:
                snap_id = None
            else:
                snap = self.server.snapshot_session(req_id)
                snap_id = sess.next_snap_id
                sess.next_snap_id += 1
                sess.snapshots[snap_id] = snap
        if snap_id is None:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_INTERNAL, req_id, f"unknown session {req_id}"))
            return
        conn.send_msg(P.MSG_SNAPSHOT_OK, P.encode_u32_pair(req_id, snap_id))

    def _on_restore(self, conn: _Conn, payload: bytes) -> None:
        req_id, snap_id = P.decode_u32_pair(payload)
        with self._lock:
            sess = self._sessions.get(req_id)
            snap = sess.snapshots.get(snap_id) if sess is not None else None
            if snap is not None:
                self.server.restore_session(req_id, snap)
        if snap is None:
            conn.send_msg(P.MSG_ERROR, P.encode_error(
                P.ERR_INTERNAL, req_id, f"unknown snapshot {snap_id}"))
            return
        conn.send_msg(P.MSG_RESTORE_OK, P.encode_u32(req_id))

    def _close_session(self, conn: Optional[_Conn], req_id: int) -> None:
        with self._lock:
            self.server.close_session(req_id)
            self._sessions.pop(req_id, None)
            if conn is not None:
                conn.open_reqs.discard(req_id)

    def _drop_conn(self, conn: _Conn, graceful: bool = True) -> None:
        conn.alive = False
        if graceful:
            for rid in list(conn.open_reqs):
                self._close_session(conn, rid)
        else:
            # keep the slots warm: the device gets grace_s to resume
            now = time.monotonic()
            with self._lock:
                for rid in list(conn.open_reqs):
                    sess = self._sessions.get(rid)
                    if sess is not None and sess.conn is conn:
                        sess.conn = None
                        sess.detached_at = now
                        self.detaches += 1
                        self.tracer.instant("detach", time.time(), tid=rid)
                conn.open_reqs.clear()
        if conn in self._conns:
            self._conns.remove(conn)
        conn.sock.close()
        with self._work:
            self._work.notify_all()      # release any backpressure waiters

    def _sweep_grace(self) -> None:
        """Close sessions whose device never came back within grace_s."""
        if self.grace_s is None:
            return
        now = time.monotonic()
        with self._lock:
            for rid, sess in list(self._sessions.items()):
                if self._expired_locked(sess, now):
                    self._expire_locked(rid)

    # ------------------------------------------------------------ pump loop
    def _pump_loop(self) -> None:
        engine = self.server.engine
        while not self._stop.is_set():
            with self._work:
                if not engine.queue:
                    self._work.wait(_PUMP_IDLE_S)
            self._sweep_grace()
            self._maybe_checkpoint()
            if not engine.queue:
                continue
            t0 = time.time()
            acks: List[Tuple[_Conn, int, int]] = []
            with self._lock:
                if not engine.queue:
                    continue
                # step + downlink emission form one atomic unit vs
                # state_dict(): the gate stays up until every downlink this
                # step produced has its seq assigned and is buffered
                self._pump_busy = True
                results = engine.step()
                info = engine.last_step_info
                tokens = engine.batched_token_history[-1]
                for j in info:
                    n_frames = j.get("n_frames", 1)
                    sess = self._sessions.get(j["req_id"])
                    c = sess.conn if sess is not None else None
                    if sess is not None:
                        sess.up_processed += n_frames
                        if not j["want_deep"] and c is not None:
                            # no downlink will implicitly ack this chunk:
                            # tell the pipelined device its window moved
                            acks.append((c, j["req_id"], sess.up_processed))
                    if c is not None and c.inflight > 0:
                        c.inflight = max(0, c.inflight - n_frames)
            try:
                with self._work:
                    self._work.notify_all()  # wake backpressure waiters
                for c, rid, processed in acks:
                    if c.alive:
                        c.send_msg(P.MSG_FRAME_ACK,
                                   P.encode_u32_pair(rid, processed))
                for c in list(self._conns):
                    if (c.busy_sent and c.alive
                            and c.inflight <= self.max_inflight_frames // 2):
                        c.busy_sent = False
                        c.send_msg(P.MSG_READY)
                t1 = time.time()
                if self.tracer.enabled:
                    # real wall-clock queue/cloud spans, per request, on the
                    # shared unix-epoch clock (frame t_send stamps are on it
                    # too, so queue_wait = device-send-complete -> step start)
                    self.tracer.add_span(
                        "cloud_step", t0, t1, tid=TID_CLOUD,
                        tokens=tokens, jobs=len(info),
                    )
                    for j in info:
                        if 0.0 < j["ready_s"] <= t0:
                            self.tracer.add_span(
                                "queue_wait", j["ready_s"], t0,
                                tid=j["req_id"], phase="queue",
                                tokens=j["tokens"],
                            )
                        self.tracer.add_span(
                            "cloud_step", t0, t1, tid=j["req_id"],
                            phase="cloud_step", tokens=j["tokens"],
                        )
                for r in results:
                    if r.deep is None:
                        continue
                    with self._lock:
                        sess = self._sessions.get(r.req_id)
                    if sess is None:
                        continue                   # closed mid-step
                    data = self.server.engine.encode_result(r)  # outside lock
                    self._send_downlink(sess, data)
            finally:
                with self._idle_cv:
                    self._pump_busy = False
                    self._idle_cv.notify_all()

    def _send_downlink(self, sess: _NetSession, data: bytes) -> None:
        """Sequence, buffer and (when the device is attached) send one
        downlink frame.  Buffering first means a frame produced while the
        session is detached is not lost — resume replays it."""
        seq = sess.down_seq
        sess.down_seq += 1
        sess.down_buffer.append((seq, data))
        conn = sess.conn
        if conn is not None and conn.alive:
            conn.send_msg(P.MSG_FRAME, P.encode_seq_frame(
                seq, stamp_t_send(data, time.time())))
            self.frames_out += 1

    # ----------------------------------------------------------- checkpoint
    def state_dict(self) -> Dict:
        """Whole-service snapshot, consistent at the *processed* watermark.

        Per session we persist ``up_processed`` (frames the engine actually
        stepped) as the restored ``up_expected``: frames received but not
        yet stepped are rolled back, and the device's replay buffer — which
        only prunes below cloud-emitted processed watermarks — re-sends
        them on resume.  The engine's pending queue is correspondingly
        dropped on restore.  ``_next_epoch`` is persisted so connection
        epochs stay monotonic across restarts.

        The capture waits out any in-flight pump step (``_pump_busy``):
        between a step and its downlink emission, ``up_processed`` and the
        engine cache already include a frame whose downlink has no seq
        yet — snapshotting that cut would restore a service that assigns
        the next downlink a seq the device has already consumed, which the
        device then drops as a duplicate, wedging the session.
        """
        with self._idle_cv:
            while self._pump_busy:
                self._idle_cv.wait()
            sessions: Dict[int, Dict] = {}
            for rid, sess in self._sessions.items():
                sessions[rid] = {
                    "epoch": int(sess.epoch),
                    "up_processed": int(sess.up_processed),
                    "down_seq": int(sess.down_seq),
                    "down_buffer": [[int(s), bytes(d)]
                                    for s, d in sess.down_buffer],
                    "snapshots": dict(sess.snapshots),
                    "next_snap_id": int(sess.next_snap_id),
                }
            return {
                "engine": self.server.engine.checkpoint_state(),
                "sessions": sessions,
                "next_epoch": int(self._next_epoch),
                "restart_epoch": int(self.restart_epoch),
            }

    def checkpoint(self, path: Optional[str] = None) -> str:
        """Atomically persist :meth:`state_dict` to ``path`` (defaults to
        the configured ``checkpoint_path``)."""
        from ..training.checkpoint import save_state

        path = path or self.checkpoint_path
        if path is None:
            raise ValueError("no checkpoint path configured")
        out = save_state(path, self.state_dict(),
                         extra={"kind": "cloud-service"})
        self.checkpoints_written += 1
        self._last_checkpoint_t = time.monotonic()
        self.tracer.instant("checkpoint", time.time(), tid=TID_CLOUD,
                            sessions=len(self._sessions))
        return out

    def restore(self, path: Optional[str] = None) -> int:
        """Load a checkpoint into this (fresh) service: engine pool state,
        per-session wire state, epoch counters.  Bumps ``restart_epoch``
        so reconnecting devices can tell they reached a new process.
        Restored sessions boot *detached* with a fresh grace window —
        their devices have ``grace_s`` from now to resume.  Returns the
        number of sessions restored.
        """
        from ..training.checkpoint import load_state

        path = path or self.checkpoint_path
        state, _extra = load_state(path)
        now = time.monotonic()
        with self._lock:
            self.server.engine.restore_state(state["engine"])
            self._sessions.clear()
            for rid, s in state["sessions"].items():
                sess = _NetSession(
                    req_id=int(rid), epoch=int(s["epoch"]), conn=None,
                    up_expected=int(s["up_processed"]),
                    up_processed=int(s["up_processed"]),
                    down_seq=int(s["down_seq"]),
                    detached_at=now,
                )
                for seq, data in s["down_buffer"]:
                    sess.down_buffer.append((int(seq), bytes(data)))
                sess.snapshots = dict(s["snapshots"])
                sess.next_snap_id = int(s["next_snap_id"])
                self._sessions[sess.req_id] = sess
                # keep the in-process CloudServer view consistent too
                self.server._processed[sess.req_id] = sess.up_processed
            self._next_epoch = int(state["next_epoch"])
            self.restart_epoch = int(state["restart_epoch"]) + 1
            self.sessions_restored = len(self._sessions)
        self.tracer.instant("restore", time.time(), tid=TID_CLOUD,
                            sessions=self.sessions_restored,
                            restart_epoch=self.restart_epoch)
        return self.sessions_restored

    def _maybe_checkpoint(self) -> None:
        """Periodic checkpoint from the pump loop — only once sessions
        exist, so an on-disk checkpoint always witnesses real state (the
        kill-on-checkpoint chaos harness keys off its existence)."""
        if not self.checkpoint_path or self.checkpoint_every_s <= 0:
            return
        if not self._sessions:
            return
        if time.monotonic() - self._last_checkpoint_t < self.checkpoint_every_s:
            return
        self.checkpoint()


# ---------------------------------------------------------------------------
# process entry point
# ---------------------------------------------------------------------------


def build_server(arch: str, *, slots: int, max_len: int,
                 max_batch_tokens: Optional[int], wire_codec: str,
                 seed: int = 0, tracer: Optional[Tracer] = None) -> CloudServer:
    """Deterministic cloud-side model build: device processes that build
    from the same (arch, seed) hold bit-identical submodel params, which
    is what makes socket-vs-loopback token parity a meaningful check."""
    import jax

    from ..configs import get_config
    from ..core import split_model
    from ..models import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    split = split_model(cfg, params)
    server = CloudServer(
        split, n_slots=slots, max_len=max_len,
        max_batch_tokens=max_batch_tokens, wire_codec=wire_codec,
        tracer=tracer,
    )
    # a pipelined device lands several small chunks between pump wakeups;
    # one merged prefill row per session costs one engine step instead of N
    server.engine.coalesce_prefill = True
    return server


def main(argv=None) -> int:
    # SIGUSR1 dumps every thread's stack to stderr (the cloud log) — the
    # first tool to reach for when a storm run wedges on a loaded host
    import faulthandler
    import signal
    faulthandler.register(signal.SIGUSR1, all_threads=True)

    ap = argparse.ArgumentParser(description="repro.net cloud service process")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port (printed on stdout)")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-batch-tokens", type=int, default=256)
    ap.add_argument("--wire-codec", default="fp16")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grace-s", type=float, default=30.0,
                    help="how long a detached session keeps its slot")
    ap.add_argument("--max-inflight-frames", type=int, default=32,
                    help="per-connection in-flight window (0 = unbounded)")
    ap.add_argument("--max-connections", type=int, default=64,
                    help="accept-path cap (0 = unbounded)")
    ap.add_argument("--trace-out", default=None,
                    help="dump the service's Chrome trace on shutdown")
    ap.add_argument("--checkpoint", default=None,
                    help="directory to persist whole-service state into "
                         "(periodically and on SIGTERM)")
    ap.add_argument("--checkpoint-every-s", type=float, default=0.0,
                    help="periodic checkpoint cadence (0 = only on SIGTERM)")
    ap.add_argument("--restore", action="store_true",
                    help="restore --checkpoint on boot (bumps the restart "
                         "epoch); missing checkpoint boots fresh")
    args = ap.parse_args(argv)

    tracer = Tracer(clock=time.time) if args.trace_out else None
    server = build_server(
        args.arch, slots=args.slots, max_len=args.max_len,
        max_batch_tokens=args.max_batch_tokens, wire_codec=args.wire_codec,
        seed=args.seed, tracer=tracer,
    )
    svc = CloudService(
        server, host=args.host, port=args.port, grace_s=args.grace_s,
        max_inflight_frames=args.max_inflight_frames,
        max_connections=args.max_connections, tracer=tracer,
        checkpoint_path=args.checkpoint,
        checkpoint_every_s=args.checkpoint_every_s,
    )
    if args.restore and args.checkpoint:
        import os

        if os.path.exists(os.path.join(args.checkpoint, "manifest.json")):
            n = svc.restore()
            print(f"NET_SERVE restored {n} sessions "
                  f"(restart epoch {svc.restart_epoch})", flush=True)
    host, port = svc.start()
    # the launcher greps for this exact line to learn the ephemeral port
    print(f"NET_SERVE listening on {host}:{port}", flush=True)

    import signal

    def _term(signum, frame):
        svc._stop.set()

    signal.signal(signal.SIGTERM, _term)
    signal.signal(signal.SIGINT, _term)
    try:
        while not svc._stop.is_set():
            svc.wait(0.2)
    finally:
        svc.stop()
        if args.checkpoint:
            # final checkpoint on the way down (SIGTERM = planned restart);
            # a SIGKILLed process relies on the periodic checkpoints instead
            try:
                svc.checkpoint()
                print("NET_SERVE checkpointed on shutdown", flush=True)
            except Exception as e:          # noqa: BLE001 - best effort
                print(f"NET_SERVE checkpoint failed: {e}", flush=True)
        if tracer is not None:
            tracer.dump(args.trace_out)
        print(f"NET_SERVE done: {svc.sessions_served} sessions, "
              f"{svc.frames_in} frames in / {svc.frames_out} out, "
              f"{svc.resumes_served} resumes, "
              f"{svc.frames_replayed} frames replayed, "
              f"{server.engine.steps} engine steps", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
