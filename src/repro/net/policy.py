"""Typed retry/deadline policies for the device-cloud network path.

Deliberately import-free (stdlib only), like :mod:`repro.net.errors`:
``repro.serving.api`` embeds these in :class:`ServeConfig` and the socket
transport consumes them, so the module must sit below both.

* :class:`RetryPolicy` — how hard to fight a dead connection: capped
  exponential backoff with deterministic, seedable jitter.  Attempt 0
  waits ``base_s``; each further attempt multiplies by ``multiplier``
  up to ``max_backoff_s``; ±``jitter`` fraction of the wait is drawn
  from the policy's own :class:`random.Random` so two runs with the
  same seed reconnect on the same schedule (the chaos tests rely on
  this).
* :class:`Deadline` — how long an operation may take *end to end*.
  ``op_timeout_s`` bounds one data-plane wait (a single ``recv`` /
  control round trip) **inclusive of any reconnects it absorbs**: the
  per-attempt transport timeout no longer resets the clock, it composes
  with the deadline.  ``total_s`` (optional) bounds a whole session.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Iterator, Optional


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff + jitter for connection recovery.

    ``max_attempts`` bounds reconnect attempts per disconnect event; a
    value of 0 disables recovery entirely (the first drop is fatal, the
    pre-fault behavior)."""

    max_attempts: int = 6
    base_s: float = 0.05
    multiplier: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.1            # fraction of the backoff, drawn ±
    seed: int = 0

    def rng(self) -> random.Random:
        """A fresh jitter RNG at this policy's seed (deterministic)."""
        return random.Random(self.seed)

    def backoff_s(self, attempt: int, rng: Optional[random.Random] = None) -> float:
        """Wait before reconnect ``attempt`` (0-based), jitter applied."""
        base = min(self.base_s * (self.multiplier ** attempt), self.max_backoff_s)
        if self.jitter <= 0.0:
            return base
        r = rng if rng is not None else self.rng()
        return max(base + base * self.jitter * (2.0 * r.random() - 1.0), 0.0)

    def delays(self, rng: Optional[random.Random] = None) -> Iterator[float]:
        """The full backoff schedule, one delay per allowed attempt."""
        r = rng if rng is not None else self.rng()
        for attempt in range(self.max_attempts):
            yield self.backoff_s(attempt, r)


@dataclass(frozen=True)
class Deadline:
    """End-to-end time budgets that compose with transport timeouts.

    ``op_timeout_s`` is the default bound on one blocking wait (recv /
    open / snapshot / restore), measured across reconnects; ``total_s``
    optionally bounds a whole session's wall clock.  ``None`` means
    unbounded."""

    op_timeout_s: Optional[float] = 60.0
    total_s: Optional[float] = None

    def start(self) -> "DeadlineClock":
        """Start the session clock for ``total_s`` accounting (monotonic,
        wall seconds)."""
        return DeadlineClock(self)

    def op_deadline(self, now: float, timeout: Optional[float] = None) -> float:
        """Absolute monotonic deadline for one op starting at ``now``.

        ``timeout`` (a per-call override) wins over ``op_timeout_s``;
        both ``None`` means effectively unbounded."""
        t = timeout if timeout is not None else self.op_timeout_s
        return now + (t if t is not None else float("inf"))


class DeadlineClock:
    """A started :class:`Deadline`: tracks the session's total budget."""

    def __init__(self, deadline: Deadline):
        self.deadline = deadline
        self.started_at = time.monotonic()

    def total_remaining_s(self) -> float:
        """Wall seconds left in the session budget (inf = unbounded;
        negative once overrun).  Never blocks."""
        if self.deadline.total_s is None:
            return float("inf")
        return self.deadline.total_s - (time.monotonic() - self.started_at)

    def expired(self) -> bool:
        """Has the session's total budget run out?  Never blocks."""
        return self.total_remaining_s() <= 0.0
