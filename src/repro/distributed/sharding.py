"""Logical-axis sharding rules.

Model code never mentions mesh axes.  It calls ``constrain(x, "act_btd")``
with a *logical* name; the active :class:`ShardingRules` (installed with
``use_rules``) maps logical names to ``PartitionSpec``s for the current mesh.
Outside any ``use_rules`` context (unit tests, single-device smoke runs)
``constrain`` is the identity, so the substrate is mesh-agnostic.

Axis conventions (see launch/mesh.py):
  data axes:  ("data",) single-pod, ("pod", "data") multi-pod  — batch dim
  model axis: ("model",)                                        — tensor dim
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_local = threading.local()


@dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: Dict[str, P]

    def spec(self, name: str) -> P:
        if name not in self.rules:
            raise KeyError(f"no sharding rule for logical name {name!r}")
        return self.rules[name]

    def sharding(self, name: str) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(name))


def current_rules() -> Optional[ShardingRules]:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = current_rules()
    _local.rules = rules
    try:
        yield
    finally:
        _local.rules = prev


def constrain(x, name: str):
    """Apply a sharding constraint if rules are active; identity otherwise."""
    r = current_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.sharding(name))


# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------


def make_rules(
    mesh: Mesh,
    *,
    seq_shard_cache: bool = True,
    seq_parallel_acts: bool = False,
    shard_experts: bool = True,
    fsdp_params: bool = False,
    batch_shardable: bool = True,
) -> ShardingRules:
    """Build the logical→physical rule table for ``mesh``.

    ``dp`` is the (pod, data) super-axis on multi-pod meshes, plain "data"
    on single-pod.  ``tp`` is the "model" axis.

    seq_shard_cache:    shard KV caches over sequence on the model axis
                        (flash-decoding style; XLA inserts the softmax
                        all-reduces).  Without it, long caches replicate
                        over the model axis and blow HBM.
    seq_parallel_acts:  Megatron sequence parallelism — shard inter-block
                        activations over seq on the model axis.
    fsdp_params:        additionally shard "replicated" param dims over the
                        data axis (ZeRO-3 style) — used by hillclimbs.
    """
    axes = mesh.axis_names
    dp: Tuple[str, ...] = tuple(a for a in axes if a in ("pod", "data"))
    dp_axes = dp
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    tp = "model" if "model" in axes else None
    fs = dp if fsdp_params else None  # optional ZeRO axis for param dim 0
    # long-context single-sequence shapes (long_500k: B=1) cannot shard the
    # batch dim; the KV cache then sequence-shards over the ENTIRE mesh
    # (ring-attention-style) and activations replicate over data
    seq_all = (tuple(dp_axes) + ("model",)) if tp else dp
    if not batch_shardable:
        dp = None

    rules: Dict[str, P] = {
        # ---- activations -------------------------------------------------
        "act_btd": P(dp, "model" if seq_parallel_acts else None, None),
        "act_btd_tp": P(dp, None, tp),        # used around vocab matmuls
        "logits": P(dp, None, tp),            # [B, T, V] vocab-sharded
        # heads dim deliberately unsharded here: several archs have head
        # counts not divisible by the model axis; XLA propagates the head
        # sharding from the weight matrices where it divides.
        "act_bthd": P(dp, None, None, None),  # [B, T, heads, hd]
        # ---- embeddings / head -------------------------------------------
        "embed_vd": P(tp, fs),                # [V, D] vocab-sharded
        "head_dv": P(fs, tp),                 # [D, V]
        # ---- dense attention params ---------------------------------------
        "attn_q": P(fs, tp),                  # [D, nh*hd]
        "attn_kv": P(fs, tp),                 # [D, nkv*hd]
        "attn_o": P(tp, fs),                  # [nh*hd, D]
        "attn_bias": P(tp),
        # ---- mlp ----------------------------------------------------------
        "mlp_in": P(fs, tp),                  # [D, F]
        "mlp_out": P(tp, fs),                 # [F, D]
        # ---- moe ----------------------------------------------------------
        "router": P(fs, None),                # [D, E] tiny, replicated
        "moe_in": P(tp if shard_experts else None, fs, None),   # [E, D, F]
        "moe_out": P(tp if shard_experts else None, None, fs),  # [E, F, D]
        "moe_buf": P(tp if shard_experts else None, None, None),  # [E, C, D]
        # ---- ssm (small per-channel params; shard inner dim) ---------------
        "ssm_in": P(fs, tp),                  # [D, d_inner-ish]
        "ssm_out": P(tp, fs),                 # [d_inner, D]
        "ssm_vec": P(tp),                     # per-inner-channel vectors
        # ---- caches (UNstacked; scan groups add "*" for a leading None) ----
        # [B, nkv, S, hd]: batch over dp; seq over model (flash-decoding:
        # XLA inserts the softmax-stat all-reduces across the model axis)
        "kv_cache": (
            P(dp, None, tp if seq_shard_cache else None, None)
            if batch_shardable
            else P(None, None, seq_all if seq_shard_cache else None, None)
        ),
        "kv_xmem": P(dp, None, None, None),   # [B, M, nkv, hd] cross-attn KV
        "ssm_small": P(dp),                   # small recurrent tensors [B,...]
        "ssm_state": P(dp, None, None, None), # [B, nh, hd, state]
        "mlstm_C": P(dp, None, None, None),   # [B, nh, hd, hd]
        # ---- per-layer scalars/norms ---------------------------------------
        "norm": P(None),
        "replicated": P(),
        # ---- batch-only tensors --------------------------------------------
        "tokens": P(dp, None),
        "batch_vec": P(dp),
        "memory_bmd": P(dp, None, None),      # frontend embeddings [B, M, D]
    }
    return ShardingRules(mesh=mesh, rules=rules)


def spec_for_name(rules: ShardingRules, name: str) -> P:
    """Logical name → PartitionSpec.  A leading ``*`` marks a layer-stacked
    leaf (scan groups): its spec gets a leading unsharded repeat dim."""
    if name.startswith("*"):
        base = rules.spec(name[1:])
        return P(None, *base)
    return rules.spec(name)


def param_shardings(rules: ShardingRules, param_specs) -> Dict:
    """Map a pytree of logical names (str) to NamedShardings."""
    return jax.tree.map(
        lambda name: NamedSharding(rules.mesh, spec_for_name(rules, name)),
        param_specs,
        is_leaf=lambda x: isinstance(x, str),
    )
