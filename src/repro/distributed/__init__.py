from .sharding import (
    ShardingRules,
    constrain,
    current_rules,
    make_rules,
    param_shardings,
    use_rules,
)

__all__ = [
    "ShardingRules", "constrain", "current_rules", "make_rules",
    "param_shardings", "use_rules",
]
