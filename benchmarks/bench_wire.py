"""Wire-codec sweep: HAT fleet TTFT/TBT vs transport codec × uplink rate.

The wire subsystem's headline artifact: per-token-quantized hidden-state
transport (repro.wire) shrinks A = bytes/token, which (a) cuts chunk upload
time directly and (b) lets the Eq. 3 solver pick larger chunks on the same
link.  Rows report both effects; the final row pins the acceptance anchor —
int8 cuts TTFT ≥ 25% vs the fp16 wire at 5 MB/s uplink.

    PYTHONPATH=src python benchmarks/bench_wire.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_wire.py --smoke    # CI smoke
"""
from __future__ import annotations

import argparse

import numpy as np

from common import emit, fleet_run, n_requests

CODECS = ["fp16", "bf16-trunc", "int8", "int4"]
BWS_MBPS = [2.5, 5.0, 10.0]
D_MODEL = 4096                       # vicuna-7b (paper anchor: fp16 = 8 KiB/tok)


def _one(codec: str, bw_mbps: float, n: int):
    from repro.data import SPECBENCH

    m = fleet_run(
        "hat", SPECBENCH, rate=6.0, n=n,
        overrides=dict(
            wire_codec=codec,
            uplink_bps=bw_mbps * 1e6,
            downlink_bps=2.0 * bw_mbps * 1e6,
        ),
    )
    s = m.summary()
    chunks = [max(r.chunk_sizes) for r in m.requests if r.chunk_sizes]
    return s, float(np.mean(chunks)) if chunks else 0.0


def main(argv=None) -> None:
    from repro.wire import get_codec

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (fp16/int8 at 5 MB/s)")
    ap.add_argument("--trace-out", default=None,
                    help="dump a Chrome-trace JSON from a traced fleet run")
    args, _ = ap.parse_known_args(argv)

    codecs = ["fp16", "int8"] if args.smoke else CODECS
    bws = [5.0] if args.smoke else BWS_MBPS
    n = 20 if args.smoke else n_requests(60, 300)

    ttft = {}
    for bw in bws:
        for codec in codecs:
            s, chunk = _one(codec, bw, n)
            ttft[(codec, bw)] = s["ttft_mean_ms"]
            bpt = get_codec(codec).bytes_per_token(D_MODEL)
            emit(
                f"wire_{codec}_{bw:g}MBps",
                s["ttft_mean_ms"] * 1e3,          # TTFT in us_per_call slot
                f"tbt_ms={s['tbt_mean_ms']:.1f};accept={s['accept_length']:.2f};"
                f"chunk={chunk:.0f};B_per_tok={bpt:.0f}",
            )

    anchor_bw = 5.0
    if ("fp16", anchor_bw) in ttft and ("int8", anchor_bw) in ttft:
        cut = 1.0 - ttft[("int8", anchor_bw)] / ttft[("fp16", anchor_bw)]
        emit("wire_int8_ttft_cut_5MBps", 0.0, f"{cut:.1%}")
        if cut < 0.25:
            raise SystemExit(
                f"int8 wire TTFT cut {cut:.1%} < 25% acceptance bar at 5 MB/s"
            )

    if args.trace_out:
        # flight-recorded fleet run (discrete-event simulator on its
        # virtual clock): same trace format as the engine benches
        from repro.data import SPECBENCH, sample_workload
        from repro.obs import Tracer, validate_chrome_trace
        from repro.serving import ServeConfig, SimulatorRuntime

        tracer = Tracer()
        rng = np.random.default_rng(0)
        reqs = sample_workload(SPECBENCH, rng, n_requests=min(n, 20),
                               rate_per_s=6.0)
        SimulatorRuntime(
            ServeConfig.hat(wire_codec="int8", uplink_bps=5e6,
                            downlink_bps=10e6),
            rng=np.random.default_rng(1), tracer=tracer,
        ).serve(reqs)
        obj = tracer.to_chrome_trace()
        validate_chrome_trace(obj)
        tracer.dump(args.trace_out)
        emit("wire_trace_events", 0.0, f"{len(obj['traceEvents'])}")


if __name__ == "__main__":
    main()
