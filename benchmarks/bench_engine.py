"""Engine throughput: sequential vs concurrent EngineRuntime, per codec.

The tentpole artifact of the concurrent runtime: with N sessions in flight,
the sequential path runs one engine step per round-trip of one session
(every step mostly idle slots), while the concurrent scheduler batches all
sessions' prefill chunks and verify strips into shared slot-batched steps.

The headline metric is **engine tokens/s** — batched tokens divided by the
wall time spent inside ``CloudEngine.step`` (``engine.step_wall_s``).  That
is the cloud hot path the paper's A6000 server runs; cross-session batching
amortizes each step's fixed cost (dispatch, padding, scatter) over ~N×
more real work, so it scales with the batch instead of the session count.
End-to-end wall time is reported alongside, but on CPU JAX it is dominated
by the un-jitted *device*-side submodels (input model + draft model), which
do identical work in both modes.

Emits the standard ``name,us_per_call,derived`` CSV rows plus a JSON anchor
file (``--json``) with the raw sweep, and enforces the acceptance bar:
concurrent ≥ 1.5× sequential engine tokens/s at 8 sessions.

``--trace-out PATH`` runs one extra *traced* concurrent pass after the
(untraced) timing sweep, asserts every request's per-phase TTFT breakdown
sums to its measured TTFT within 1%, and dumps the Chrome-trace JSON —
open it in chrome://tracing or ui.perfetto.dev.

``--net tcp`` benchmarks the *real* wire instead: it spawns 1 cloud +
N device processes on localhost (``repro.net``), measures wall-clock
TTFT/TBT through actual sockets, replays the identical workload through an
in-process ``LoopbackTransport``, and asserts the two token streams match
per request — the measured numbers are only meaningful because the
computation is provably the same.  It then sweeps the pipelined uplink
window (``net_tcp_pipelined_d{depth}`` rows, ``--net-pipeline-depths``):
long-prompt TTFT per depth, token parity across depths, and — fault-free —
the bar that some depth>1 beats the sequential (depth 1) baseline.

``--net tcp --cloud-restart`` runs the restart storm instead:
``--net-devices`` device processes (one session each) stream through one
cloud process, a seeded chaos trigger SIGKILLs it mid-run once every
session is registered, and a successor restores the latest checkpoint on
the same port.  Hard bars: ``cloud_restarts >= 1``, ``sessions_lost=0``,
and per-request token parity with an uninterrupted loopback replay
(``net_tcp_restart_parity`` row) — CI's ``storm-smoke`` job greps them.

    PYTHONPATH=src python benchmarks/bench_engine.py            # full sweep
    PYTHONPATH=src python benchmarks/bench_engine.py --smoke    # CI smoke
    PYTHONPATH=src python benchmarks/bench_engine.py --smoke --net tcp
    PYTHONPATH=src python benchmarks/bench_engine.py --smoke --net tcp \
        --cloud-restart --net-devices 32                        # storm
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from common import emit

ACCEPT_SESSIONS = 8
ACCEPT_SPEEDUP = 1.5


def _build(arch: str):
    import jax

    from repro.configs import get_config
    from repro.core import init_adapter, split_model
    from repro.models import Model

    cfg = get_config(arch).reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    split = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    return cfg, split, adapter


def _specs(cfg, n, *, prompt_len, new_tokens):
    from repro.data import RequestSpec

    rng = np.random.default_rng(0)
    return [
        RequestSpec(
            req_id=i, device_id=i, arrival_s=0.05 * i,
            prompt_len=prompt_len, max_new_tokens=new_tokens,
            prompt=rng.integers(3, cfg.vocab_size, prompt_len).astype(np.int32),
        )
        for i in range(n)
    ]


def _run(cfg, split, adapter, *, codec, n_sessions, concurrent,
         prompt_len, new_tokens, max_len, repeats=2):
    from repro.serving import EngineRuntime, ServeConfig

    config = ServeConfig.hat(
        wire_codec=codec, n_devices=max(n_sessions, 1),
        dynamic_chunks=False, fixed_chunk=16,
    )
    reqs = _specs(cfg, n_sessions, prompt_len=prompt_len,
                  new_tokens=new_tokens)
    # one runtime across repeats: the engine's jitted step variants persist,
    # so the first pass pays the compiles and the timed pass measures the
    # steady-state hot path
    runtime = EngineRuntime(
        config, split, adapter_params=adapter,
        rng=np.random.default_rng(1), n_slots=max(n_sessions, 8),
        max_len=max_len, concurrent=concurrent,
    )
    engine = runtime.server.engine
    best = None
    for _ in range(max(repeats, 1)):
        wall0, tok0 = engine.step_wall_s, sum(engine.batched_token_history)
        t0 = time.perf_counter()
        m = runtime.serve(reqs)
        dt = time.perf_counter() - t0
        engine_s = engine.step_wall_s - wall0
        engine_tokens = sum(engine.batched_token_history) - tok0
        tokens = sum(len(r.generated) for r in m.requests)
        s = m.summary()
        row = {
            "mode": "concurrent" if concurrent else "sequential",
            "codec": codec, "sessions": n_sessions,
            "tokens": tokens, "wall_s": dt,
            "engine_s": engine_s,
            "engine_tokens": engine_tokens,
            "engine_tokens_per_s": engine_tokens / max(engine_s, 1e-9),
            "steps": s["cloud_steps"],
            "batch_tokens_per_step_mean": s["batch_tokens_per_step_mean"],
            "jit_compiles": s["engine_jit_compiles"],
        }
        if best is None or row["engine_tokens_per_s"] > best["engine_tokens_per_s"]:
            best = row
    return best


def _traced_pass(cfg, split, adapter, *, n_sessions, prompt_len, new_tokens,
                 max_len, trace_out):
    """One flight-recorded concurrent run: dump the Chrome trace and check
    the per-request phase breakdown tiles TTFT (the obs contract)."""
    from repro.obs import Tracer, validate_chrome_trace
    from repro.serving import EngineRuntime, ServeConfig

    tracer = Tracer()
    config = ServeConfig.hat(
        wire_codec="fp16", n_devices=max(n_sessions, 1),
        dynamic_chunks=False, fixed_chunk=16,
    )
    runtime = EngineRuntime(
        config, split, adapter_params=adapter,
        rng=np.random.default_rng(1), n_slots=max(n_sessions, 8),
        max_len=max_len, concurrent=True, tracer=tracer,
    )
    m = runtime.serve(_specs(cfg, n_sessions, prompt_len=prompt_len,
                             new_tokens=new_tokens))
    worst = 0.0
    for r in m.requests:
        assert r.phase_ttft_s is not None, f"req {r.req_id} has no breakdown"
        err = abs(sum(r.phase_ttft_s.values()) - r.ttft_s) / max(r.ttft_s, 1e-12)
        worst = max(worst, err)
        if err > 0.01:
            raise SystemExit(
                f"req {r.req_id}: phase breakdown off by {err:.2%} "
                f"(> 1% of TTFT) — span tiling broke"
            )
    obj = tracer.to_chrome_trace()
    validate_chrome_trace(obj)
    tracer.dump(trace_out)
    bd = m.summary()["ttft_breakdown_ms"]
    emit(
        "engine_trace_ttft_breakdown", 0.0,
        ";".join(f"{k}={v:.1f}ms" for k, v in bd.items())
        + f";worst_err={worst:.2e};events={len(obj['traceEvents'])}",
    )


def _net_bench(args) -> None:
    """Measured sockets vs in-process loopback, token parity asserted.

    The socket path runs first (3 real processes); then the *same* specs —
    ``repro.net.worker.device_specs`` is deterministic in (seed, device
    index) — replay through one in-process server over
    ``LoopbackTransport``.  Any per-request token divergence is a hard
    failure: real-wire timings are only comparable when the computation is
    identical."""
    from repro.configs import get_config
    from repro.net import run_cluster
    from repro.net.service import build_server
    from repro.net.worker import build_client, device_specs, run_device_workload
    from repro.serving import LoopbackTransport

    n_devices = 2
    requests_per_device = 2 if args.smoke else 3
    prompt_len = 16 if args.smoke else 32
    new_tokens = 4 if args.smoke else 8
    max_len = 128
    codec = "fp16"

    chaos_schedule = None
    if args.net_chaos_drops:
        from repro.net import seeded_schedule

        # seeded drops against the first n_devices connections: the run
        # must still produce loopback-identical tokens, now via resume
        chaos_schedule = seeded_schedule(
            args.net_chaos_seed, connections=n_devices,
            drops_per_conn=args.net_chaos_drops,
        )

    result = run_cluster(
        args.arch, n_devices=n_devices,
        requests_per_device=requests_per_device, prompt_len=prompt_len,
        new_tokens=new_tokens, max_len=max_len, wire_codec=codec,
        seed=0, workdir=args.net_workdir, chaos_schedule=chaos_schedule,
    )
    socket_tokens = {
        r["req_id"]: list(r["tokens"])
        for w in result["workers"] for r in w["requests"]
    }

    cfg = get_config(args.arch).reduced()
    server = build_server(args.arch, slots=8, max_len=max_len,
                         max_batch_tokens=256, wire_codec=codec, seed=0)
    transport = LoopbackTransport(server)
    client = build_client(args.arch, transport, max_len=max_len,
                          wire_codec=codec, draft=False, seed=0)
    loop_tokens = {}
    t0 = time.perf_counter()
    for k in range(n_devices):
        specs = device_specs(cfg, k, n_requests=requests_per_device,
                             prompt_len=prompt_len, new_tokens=new_tokens,
                             seed=0)
        for r in run_device_workload(client, transport, specs):
            loop_tokens[r.req_id] = list(r.generated)
    loop_wall_s = time.perf_counter() - t0

    if sorted(socket_tokens) != sorted(loop_tokens):
        raise SystemExit(
            f"request sets diverge: socket {sorted(socket_tokens)} vs "
            f"loopback {sorted(loop_tokens)}"
        )
    for rid in sorted(socket_tokens):
        if socket_tokens[rid] != loop_tokens[rid]:
            raise SystemExit(
                f"token parity broken for req {rid}: socket "
                f"{socket_tokens[rid]} vs loopback {loop_tokens[rid]}"
            )

    emit(
        "net_tcp_ttft", result["ttft_mean_ms"] * 1e3,  # us
        f"ttft_p90_ms={result['ttft_p90_ms']:.1f};"
        f"tbt_mean_ms={result['tbt_mean_ms']:.1f};"
        f"requests={result['n_requests']};devices={n_devices};"
        f"bytes_up={result['bytes_up']};bytes_down={result['bytes_down']}",
    )
    emit("net_tcp_token_parity", 0.0,
         f"{len(socket_tokens)}/{len(socket_tokens)} requests byte-identical "
         f"to loopback;loopback_wall_s={loop_wall_s:.1f}")
    if chaos_schedule is not None:
        if result["reconnects"] < 1:
            raise SystemExit(
                f"chaos schedule injected {len(result['chaos_faults'])} "
                f"faults but no device ever reconnected"
            )
        emit(
            "net_tcp_reconnects", float(result["reconnects"]),
            f"faults={len(result['chaos_faults'])};"
            f"replayed_frames={result['replayed_frames']};"
            f"requests_degraded={result['requests_degraded']};"
            f"parity_held_under_faults=True",
        )
    pipelined_rows = _net_pipelined_bench(args)

    with open(args.json, "w") as f:
        json.dump({
            "mode": "net-tcp",
            "n_devices": n_devices,
            "n_requests": result["n_requests"],
            "ttft_mean_ms": result["ttft_mean_ms"],
            "ttft_p90_ms": result["ttft_p90_ms"],
            "tbt_mean_ms": result["tbt_mean_ms"],
            "bytes_up": result["bytes_up"],
            "bytes_down": result["bytes_down"],
            "token_parity": True,
            "reconnects": result["reconnects"],
            "replayed_frames": result["replayed_frames"],
            "requests_degraded": result["requests_degraded"],
            "chaos_faults": len(result["chaos_faults"]),
            "merged_trace": result["merged_trace"],
            "pipelined": pipelined_rows,
        }, f, indent=1)


def _net_restart_bench(args) -> None:
    """Device storm across a mid-run cloud kill + checkpoint restore.

    ``--net-devices`` device processes (one session each) stream through
    one cloud process; a seeded chaos trigger SIGKILLs the cloud once
    every session is provably registered (``MSG_OPEN_OK`` observed at the
    proxy) and the fleet has pushed its seeded uplink-frame quota.  A
    successor process restores the latest checkpoint on the same port
    under a bumped restart epoch; every device resumes and finishes.

    Hard bars, enforced here (and grepped by the CI ``storm-smoke`` job):

    * ``cloud_restarts >= 1`` — the kill + restore actually happened;
    * ``sessions_lost=0`` — no request degraded across the restart
      (one-request-per-device makes this deterministic: the checkpoint
      the supervisor waits for post-dates every ``MSG_OPEN_OK``);
    * ``net_tcp_restart_parity`` — every token stream byte-identical to
      an uninterrupted in-process loopback replay of the same specs.
    """
    from repro.configs import get_config
    from repro.net import run_cluster
    from repro.net.launcher import CloudRestartPlan
    from repro.net.service import build_server
    from repro.net.worker import build_client, device_specs, run_device_workload
    from repro.serving import LoopbackTransport

    n_devices = args.net_devices
    prompt_len = 16 if args.smoke else 32
    # enough decode steps that the storm is still in flight when the
    # seeded kill lands (the trigger needs every session open first)
    new_tokens = 8
    max_len = 128
    codec = "fp16"

    result = run_cluster(
        args.arch, n_devices=n_devices, requests_per_device=1,
        prompt_len=prompt_len, new_tokens=new_tokens,
        slots=n_devices, max_len=max_len, wire_codec=codec,
        seed=0, workdir=args.net_workdir,
        # a 32-process storm on a small CI runner serializes every
        # worker's jax init through a few cores — budget generously
        worker_timeout_s=3600.0,
        cloud_restart=CloudRestartPlan(seed=args.net_chaos_seed),
    )
    if result["cloud_restarts"] < 1:
        raise SystemExit(
            f"cloud restart never happened: cloud_restarts="
            f"{result['cloud_restarts']}, faults={result['chaos_faults']}")
    if result["cloud_restarts_seen"] < 1:
        raise SystemExit(
            "no device observed the bumped restart epoch — the fleet "
            "never actually resumed against the successor process")
    if result["sessions_lost"] != 0:
        raise SystemExit(
            f"{result['sessions_lost']} session(s) lost across the "
            f"restart (degraded requests) — expected zero")

    socket_tokens = {
        r["req_id"]: list(r["tokens"])
        for w in result["workers"] for r in w["requests"]
    }
    cfg = get_config(args.arch).reduced()
    server = build_server(args.arch, slots=n_devices, max_len=max_len,
                          max_batch_tokens=256, wire_codec=codec, seed=0)
    transport = LoopbackTransport(server)
    client = build_client(args.arch, transport, max_len=max_len,
                          wire_codec=codec, draft=False, seed=0)
    loop_tokens = {}
    for k in range(n_devices):
        specs = device_specs(cfg, k, n_requests=1, prompt_len=prompt_len,
                             new_tokens=new_tokens, seed=0)
        for r in run_device_workload(client, transport, specs):
            loop_tokens[r.req_id] = list(r.generated)
    if sorted(socket_tokens) != sorted(loop_tokens):
        raise SystemExit(
            f"request sets diverge: socket {sorted(socket_tokens)} vs "
            f"loopback {sorted(loop_tokens)}")
    for rid in sorted(socket_tokens):
        if socket_tokens[rid] != loop_tokens[rid]:
            raise SystemExit(
                f"token parity broken across restart for req {rid}: "
                f"socket {socket_tokens[rid]} vs loopback {loop_tokens[rid]}")

    emit(
        "net_tcp_restart_parity", 0.0,
        f"{len(socket_tokens)}/{len(socket_tokens)} requests "
        f"byte-identical to loopback across a cloud restart;"
        f"devices={n_devices};cloud_restarts={result['cloud_restarts']};"
        f"restarts_seen={result['cloud_restarts_seen']};"
        f"sessions_lost={result['sessions_lost']};"
        f"reconnects={result['reconnects']};"
        f"replayed_frames={result['replayed_frames']}",
    )
    emit(
        "net_tcp_restart_ttft", result["ttft_mean_ms"] * 1e3,  # us
        f"ttft_p90_ms={result['ttft_p90_ms']:.1f};"
        f"tbt_mean_ms={result['tbt_mean_ms']:.1f};"
        f"requests={result['n_requests']};devices={n_devices};"
        f"restart_window_included=True",
    )
    with open(args.json, "w") as f:
        json.dump({
            "mode": "net-tcp-restart",
            "n_devices": n_devices,
            "n_requests": result["n_requests"],
            "cloud_restarts": result["cloud_restarts"],
            "cloud_restarts_seen": result["cloud_restarts_seen"],
            "sessions_lost": result["sessions_lost"],
            "reconnects": result["reconnects"],
            "replayed_frames": result["replayed_frames"],
            "ttft_mean_ms": result["ttft_mean_ms"],
            "ttft_p90_ms": result["ttft_p90_ms"],
            "tbt_mean_ms": result["tbt_mean_ms"],
            "token_parity": True,
            "chaos_faults": len(result["chaos_faults"]),
            "merged_trace": result["merged_trace"],
        }, f, indent=1)


def _net_pipelined_bench(args) -> list:
    """TTFT vs uplink window depth on long prompts over real sockets.

    One cluster run per depth in ``--net-pipeline-depths``; depth 1 is the
    strictly-sequential baseline (one chunk in flight, ack-gated), deeper
    windows overlap uploads with cloud processing.  The chaos proxy shapes
    the uplink with a constant per-frame propagation delay
    (``--net-link-delay``): localhost transfer is microseconds, so without
    real link latency there is nothing for the window to hide — and the
    delay must exceed the per-chunk shallow compute time (~0.4 s un-jitted
    on CPU), which overlaps the link even at depth 1.  The comparison metric is **warm**
    TTFT — each worker's first request pays the cloud's one-time jit
    compiles and is excluded.  Token streams must be identical across
    depths — the windows reorder *waiting*, never computation — and with
    ``--net-chaos-drops`` each run must also survive seeded connection
    drops with parity intact.  Drop-free runs enforce the tentpole bar:
    best depth>1 warm TTFT < depth-1 warm TTFT."""
    from repro.net import run_cluster

    depths = [int(d) for d in args.net_pipeline_depths.split(",") if d.strip()]
    if not depths:
        return []
    prompt_len = 64 if args.smoke else 128   # long prompts: 4 / 8 chunks
    new_tokens = 3
    rows, tokens_by_depth, warm_by_depth = [], {}, {}
    for depth in depths:
        chaos_schedule = None
        if args.net_chaos_drops:
            from repro.net import seeded_schedule

            chaos_schedule = seeded_schedule(
                args.net_chaos_seed, connections=1,
                drops_per_conn=args.net_chaos_drops,
            )
        result = run_cluster(
            args.arch, n_devices=1, requests_per_device=3,
            prompt_len=prompt_len, new_tokens=new_tokens, max_len=256,
            wire_codec="fp16", seed=0, pipeline_depth=depth,
            link_delay_s=args.net_link_delay,
            chaos_schedule=chaos_schedule, trace=False,
        )
        toks = {
            r["req_id"]: list(r["tokens"])
            for w in result["workers"] for r in w["requests"]
        }
        tokens_by_depth[depth] = toks
        # warm TTFT: drop each worker's first request (one-time compiles)
        warm = [
            r["ttft_s"] for w in result["workers"]
            for r in w["requests"][1:] if r["ttft_s"] is not None
        ]
        warm_ms = float(np.mean(warm)) * 1e3 if warm else float("nan")
        warm_by_depth[depth] = warm_ms
        rows.append({
            "depth": depth,
            "prompt_len": prompt_len,
            "link_delay_s": args.net_link_delay,
            "ttft_warm_ms": warm_ms,
            "ttft_mean_ms": result["ttft_mean_ms"],
            "ttft_p90_ms": result["ttft_p90_ms"],
            "tbt_mean_ms": result["tbt_mean_ms"],
            "reconnects": result["reconnects"],
            "replayed_frames": result["replayed_frames"],
            "requests_degraded": result["requests_degraded"],
            "chaos_faults": len(result["chaos_faults"]),
        })
        emit(
            f"net_tcp_pipelined_d{depth}", warm_ms * 1e3,  # us
            f"ttft_warm_ms={warm_ms:.1f};ttft_mean_ms="
            f"{result['ttft_mean_ms']:.1f};prompt_len={prompt_len};"
            f"link_delay_s={args.net_link_delay};"
            f"reconnects={result['reconnects']};"
            f"faults={len(result['chaos_faults'])}",
        )
        if chaos_schedule is not None and result["reconnects"] < 1:
            raise SystemExit(
                f"pipelined depth {depth}: chaos schedule injected "
                f"{len(result['chaos_faults'])} faults but no reconnect"
            )

    base = tokens_by_depth[depths[0]]
    for depth in depths[1:]:
        if tokens_by_depth[depth] != base:
            raise SystemExit(
                f"pipelined token parity broken: depth {depth} streams "
                f"diverge from depth {depths[0]}"
            )
    deeper = [d for d in depths if d > 1]
    if 1 in depths and deeper and not args.net_chaos_drops:
        best = min(warm_by_depth[d] for d in deeper)
        if not (best < warm_by_depth[1]):
            raise SystemExit(
                f"pipelined uplink did not beat sequential: best depth>1 "
                f"warm TTFT {best:.1f}ms >= depth-1 warm TTFT "
                f"{warm_by_depth[1]:.1f}ms"
            )
        emit("net_tcp_pipelined_speedup", 0.0,
             f"{warm_by_depth[1] / best:.2f}x warm TTFT over sequential "
             f"(depth 1) on {prompt_len}-token prompts at "
             f"{args.net_link_delay * 1e3:.0f}ms/frame uplink")
    emit("net_tcp_pipelined_parity", 0.0,
         f"{len(base)} requests byte-identical across depths {depths}"
         + (";under_chaos=True" if args.net_chaos_drops else ""))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (fp16, 1/8 sessions)")
    ap.add_argument("--json", default="bench_engine.json",
                    help="JSON anchor output path")
    ap.add_argument("--trace-out", default=None,
                    help="dump a Chrome-trace JSON from a traced extra pass")
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--net", default=None, choices=["tcp"],
                    help="benchmark the real socket path (1 cloud + 2 "
                         "device processes) against in-process loopback "
                         "with token parity asserted")
    ap.add_argument("--net-chaos-drops", type=int, default=0,
                    help="with --net: seeded connection drops per device "
                         "connection (0 = fault-free); token parity is "
                         "still asserted — the run must survive via resume")
    ap.add_argument("--net-chaos-seed", type=int, default=7,
                    help="seed for the chaos drop schedule")
    ap.add_argument("--net-pipeline-depths", default="1,2,4",
                    help="with --net: comma list of uplink window depths "
                         "for the pipelined-prefill rows (depth 1 = "
                         "sequential baseline; empty string skips)")
    ap.add_argument("--net-link-delay", type=float, default=0.6,
                    help="with --net: per-uplink-frame propagation delay "
                         "(s) the chaos proxy shapes into the pipelined "
                         "rows — localhost needs real latency for the "
                         "window to hide, and it must exceed the ~0.4s "
                         "per-chunk shallow compute that overlaps the "
                         "link even at depth 1")
    ap.add_argument("--net-workdir", default=None,
                    help="with --net: directory for per-process logs and "
                         "the merged Chrome trace")
    ap.add_argument("--cloud-restart", action="store_true",
                    help="with --net: storm bench across a mid-run cloud "
                         "SIGKILL + checkpoint restore — asserts zero lost "
                         "sessions and token parity across the restart")
    ap.add_argument("--net-devices", type=int, default=2,
                    help="with --net --cloud-restart: device processes in "
                         "the storm (CI uses 32)")
    args, _ = ap.parse_known_args(argv)

    if args.net == "tcp":
        if args.cloud_restart:
            _net_restart_bench(args)
        else:
            _net_bench(args)
        return

    codecs = ["fp16"] if args.smoke else ["fp16", "int8"]
    session_counts = [1, ACCEPT_SESSIONS] if args.smoke else [1, 4, ACCEPT_SESSIONS]
    prompt_len = 16 if args.smoke else 32
    new_tokens = 6 if args.smoke else 12
    max_len = 64 if args.smoke else 128

    cfg, split, adapter = _build(args.arch)
    rows = []
    for codec in codecs:
        for n in session_counts:
            for concurrent in (False, True):
                row = _run(
                    cfg, split, adapter, codec=codec, n_sessions=n,
                    concurrent=concurrent, prompt_len=prompt_len,
                    new_tokens=new_tokens, max_len=max_len,
                )
                rows.append(row)
                emit(
                    f"engine_{row['mode']}_{codec}_{n}sess",
                    1e6 / max(row["engine_tokens_per_s"], 1e-9),  # us/token
                    f"engine_tok_per_s={row['engine_tokens_per_s']:.0f};"
                    f"steps={row['steps']};"
                    f"batch_mean={row['batch_tokens_per_step_mean']:.1f};"
                    f"compiles={row['jit_compiles']};"
                    f"wall_s={row['wall_s']:.1f}",
                )

    anchors = {}
    for codec in codecs:
        seq = next(r for r in rows if r["codec"] == codec
                   and r["sessions"] == ACCEPT_SESSIONS
                   and r["mode"] == "sequential")
        con = next(r for r in rows if r["codec"] == codec
                   and r["sessions"] == ACCEPT_SESSIONS
                   and r["mode"] == "concurrent")
        speedup = con["engine_tokens_per_s"] / seq["engine_tokens_per_s"]
        anchors[codec] = speedup
        emit(f"engine_concurrent_speedup_{codec}_{ACCEPT_SESSIONS}sess",
             0.0, f"{speedup:.2f}x")

    with open(args.json, "w") as f:
        json.dump({"rows": rows, "speedup_at_8_sessions": anchors,
                   "accept_bar": ACCEPT_SPEEDUP}, f, indent=1)

    if args.trace_out:
        # separate pass so the timing rows above stay untraced
        _traced_pass(
            cfg, split, adapter, n_sessions=ACCEPT_SESSIONS,
            prompt_len=prompt_len, new_tokens=new_tokens, max_len=max_len,
            trace_out=args.trace_out,
        )

    worst = min(anchors.values())
    if worst < ACCEPT_SPEEDUP:
        raise SystemExit(
            f"concurrent engine speedup {worst:.2f}x < {ACCEPT_SPEEDUP}x "
            f"acceptance bar at {ACCEPT_SESSIONS} sessions"
        )


if __name__ == "__main__":
    main()
