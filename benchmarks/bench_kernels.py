"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle timings and
allclose deltas on serving-shaped inputs.  On CPU these time the REFERENCE
path (the production-relevant numbers come from the dry-run roofline); the
interpret-mode runs exist to pin correctness cheaply in CI."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from common import emit
from repro.kernels import attention_ref, prefill_attention, verify_attention


def _time(fn, *args, n=3, **kw):
    fn(*args, **kw).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args, **kw)
    out.block_until_ready()
    return (time.perf_counter() - t0) / n * 1e6


def main(quick: bool = True) -> None:
    rng = jax.random.PRNGKey(0)
    cases = [
        ("prefill_chunk", 1, 128, 512, 8, 2, 64, None),
        ("verify_k8", 2, 8, 1024, 8, 2, 64, None),
        ("decode_sw", 1, 1, 2048, 4, 4, 64, 256),
    ]
    for name, B, T, S, nh, nkv, hd, window in cases:
        ks = jax.random.split(rng, 3)
        q = jax.random.normal(ks[0], (B, T, nh, hd))
        k = jax.random.normal(ks[1], (B, S, nkv, hd))
        v = jax.random.normal(ks[2], (B, S, nkv, hd))
        off, vlen = S - T - 1, S - 1
        ref_us = _time(
            lambda: attention_ref(q, k, v, offset=off, valid_len=vlen, window=window)
        )
        kern = verify_attention if T <= 16 else prefill_attention
        out = kern(q, k, v, off, vlen, window=window, interpret=True)
        ref = attention_ref(q, k, v, offset=off, valid_len=vlen, window=window)
        err = float(jnp.max(jnp.abs(out - ref)))
        emit(f"kernels.{name}.ref_us", ref_us, f"interpret_allclose_err={err:.1e}")
        assert err < 1e-4, (name, err)


if __name__ == "__main__":
    main()
