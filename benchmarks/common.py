"""Shared helpers for the paper-artifact benchmarks."""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

FULL = bool(int(os.environ.get("REPRO_BENCH_FULL", "0")))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    """CSV contract expected by benchmarks.run: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def n_requests(default_quick: int, default_full: int) -> int:
    return default_full if FULL else default_quick


def fleet_run(framework: str, spec, *, rate: float, n: int, seed: int = 1,
              pipeline_len: int = 4, hidden_bytes: float = 4096 * 2,
              backend=None, overrides=None):
    """Workload sampling + the legacy run_fleet wrapper (which owns the
    codec-vs-hidden_bytes precedence via ServeConfig)."""
    from repro.data import sample_workload
    from repro.serving import run_fleet

    rng = np.random.default_rng(0)
    reqs = sample_workload(spec, rng, n_requests=n, rate_per_s=rate)
    return run_fleet(
        framework, reqs, rng=np.random.default_rng(seed),
        pipeline_len=pipeline_len, hidden_bytes=hidden_bytes,
        backend=backend, overrides=overrides,
    )
