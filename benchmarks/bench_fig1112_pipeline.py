"""Paper Figs. 11–12 — effect of the cloud pipeline length (1/2/4/8)."""
from __future__ import annotations

from common import emit, fleet_run, n_requests
from repro.data import CNN_DM, SPECBENCH


def main(quick: bool = True) -> None:
    n = n_requests(150, 500)
    for spec, hidden, rate in ((SPECBENCH, 4096 * 2, 6), (CNN_DM, 5120 * 2, 4)):
        for P in (1, 2, 4, 8):
            for fw in ("u-shape", "u-sarathi", "u-medusa", "hat"):
                m = fleet_run(fw, spec, rate=rate, n=n, hidden_bytes=hidden,
                              pipeline_len=P)
                s = m.summary()
                emit(
                    f"fig1112.{spec.name}.P{P}.{fw}.ttft_ms",
                    s["ttft_mean_ms"] * 1e3,
                    f"tbt_ms={s['tbt_mean_ms']:.1f}",
                )


if __name__ == "__main__":
    main()
