"""Paper Figs. 6–7 — TTFT / TBT vs request generation rate.

SpecBench-like workload (Vicuna-7B wire size) at rates 4–9 req/s and
CNN/DM-like (Vicuna-13B) at 2–5 req/s, all four frameworks, 30 devices,
pipeline length 4 (paper §4.2)."""
from __future__ import annotations

from common import emit, fleet_run, n_requests
from repro.data import CNN_DM, SPECBENCH


def main(quick: bool = True) -> None:
    n = n_requests(150, 600)
    for spec, hidden, rates in (
        (SPECBENCH, 4096 * 2, (4, 6, 9)),
        (CNN_DM, 5120 * 2, (2, 4, 5)),
    ):
        for rate in rates:
            base = {}
            for fw in ("u-shape", "u-sarathi", "u-medusa", "hat"):
                m = fleet_run(fw, spec, rate=rate, n=n, hidden_bytes=hidden)
                s = m.summary()
                base[fw] = s
                emit(
                    f"fig67.{spec.name}.r{rate}.{fw}.ttft_ms",
                    s["ttft_mean_ms"] * 1e3,
                    f"tbt_ms={s['tbt_mean_ms']:.1f};accept={s['accept_length']:.2f}",
                )
            hat, ush = base["hat"], base["u-shape"]
            emit(
                f"fig67.{spec.name}.r{rate}.hat_vs_ushape",
                0.0,
                f"ttft{(hat['ttft_mean_ms']/ush['ttft_mean_ms']-1)*100:+.0f}%;"
                f"tbt{(hat['tbt_mean_ms']/ush['tbt_mean_ms']-1)*100:+.0f}%",
            )


if __name__ == "__main__":
    main()
