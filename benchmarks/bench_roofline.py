"""Roofline table (deliverable g) — reads reports/dryrun/*.json.

Emits the three roofline terms, dominant bottleneck, and useful-FLOPs ratio
per (arch × shape × mesh) produced by ``python -m repro.launch.dryrun``."""
from __future__ import annotations

import glob
import json
import os

from common import emit


def main(quick: bool = True) -> None:
    files = sorted(glob.glob("reports/dryrun/*.json"))
    if not files:
        emit("roofline.no_dryrun_reports", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for f in files:
        rec = json.load(open(f))
        if rec.get("skipped") or not rec.get("ok"):
            continue
        if rec.get("tag"):
            continue                       # perf-iteration variants listed separately
        rf = rec["roofline"]
        name = f"roofline.{rec['arch']}.{rec['shape']}.{rec['mesh']}"
        dom_ms = {"compute": rf["compute_s"], "memory": rf["memory_s"],
                  "collective": rf["collective_s"]}[rf["dominant"]] * 1e3
        emit(
            name,
            dom_ms * 1e3,
            f"dom={rf['dominant']};compute_ms={rf['compute_s']*1e3:.1f};"
            f"mem_ms={rf['memory_s']*1e3:.1f};coll_ms={rf['collective_s']*1e3:.1f};"
            f"useful={rf['useful_flops_ratio']:.2f}",
        )


if __name__ == "__main__":
    main()
