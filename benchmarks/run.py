"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set REPRO_BENCH_FULL=1 for
paper-scale request counts; the default sizes finish on one CPU core.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig67 table4
  PYTHONPATH=src python -m benchmarks.run --list     # what exists & why

See docs/BENCHMARKS.md for the catalogue, the JSON anchor schema and
which of these run in CI.
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))

# (key, module, paper anchor, one-line description)
MODULES = [
    ("fig1", "bench_fig1_preliminary", "Fig. 1",
     "preliminary: delay decomposition, U-shaped TTFT vs prompt length, "
     "chunking trade-off"),
    ("fig67", "bench_fig67_rates", "Figs. 6-7",
     "fleet TTFT/TBT vs request rate, 4 frameworks, 30 devices"),
    ("fig8", "bench_fig8_stability", "Fig. 8",
     "per-pipeline-stage compute delay mean±std (chunking stability)"),
    ("fig910", "bench_fig910_sla", "Figs. 9-10",
     "prefill/decode SLA compliance rates"),
    ("table4", "bench_table4_sd", "Table 4",
     "speculative decoding with REAL trained models (adapter Λ + Medusa)"),
    ("table5", "bench_table5_ablation", "Table 5",
     "SD / PC / PD strategy ablation grid"),
    ("fig1112", "bench_fig1112_pipeline", "Figs. 11-12",
     "effect of cloud pipeline length (1/2/4/8)"),
    ("wire", "bench_wire", "§3.3 wire",
     "codec × uplink-rate sweep; int8 ≥25% TTFT cut anchor"),
    ("engine", "bench_engine", "§4 serving",
     "CloudEngine vs simulator; --net tcp adds measured-socket + "
     "pipelined-uplink rows"),
    ("kernels", "bench_kernels", "impl",
     "Pallas(interpret) vs jnp-oracle timings + allclose deltas"),
    ("roofline", "bench_roofline", "deliverable g",
     "roofline terms per arch×shape×mesh from reports/dryrun/*.json"),
]


def list_modules() -> None:
    """Print the catalogue: key, paper figure/table, what it measures."""
    wk = max(len(k) for k, *_ in MODULES)
    wp = max(len(p) for _, _, p, _ in MODULES)
    for key, modname, paper, desc in MODULES:
        print(f"{key:<{wk}}  {paper:<{wp}}  {desc}  [{modname}]")


def main() -> None:
    argv = sys.argv[1:]
    if "--list" in argv or "-l" in argv:
        list_modules()
        return
    want = set(argv)
    unknown = want - {k for k, *_ in MODULES}
    if unknown:
        raise SystemExit(
            f"unknown benchmark key(s) {sorted(unknown)}; "
            f"run with --list to see what exists")
    print("name,us_per_call,derived")
    failures = []
    for key, modname, _paper, _desc in MODULES:
        if want and key not in want:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname)
            mod.main()
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(key)
            print(f"# {key} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
