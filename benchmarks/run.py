"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Set REPRO_BENCH_FULL=1 for
paper-scale request counts; the default sizes finish on one CPU core.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig67 table4
"""
from __future__ import annotations

import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(__file__))

MODULES = [
    ("fig1", "bench_fig1_preliminary"),
    ("fig67", "bench_fig67_rates"),
    ("fig8", "bench_fig8_stability"),
    ("fig910", "bench_fig910_sla"),
    ("table4", "bench_table4_sd"),
    ("table5", "bench_table5_ablation"),
    ("fig1112", "bench_fig1112_pipeline"),
    ("wire", "bench_wire"),
    ("engine", "bench_engine"),
    ("kernels", "bench_kernels"),
    ("roofline", "bench_roofline"),
]


def main() -> None:
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = []
    for key, modname in MODULES:
        if want and key not in want:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname)
            mod.main()
            print(f"# {key} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures.append(key)
            print(f"# {key} FAILED:\n{traceback.format_exc()}", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")


if __name__ == "__main__":
    main()
