"""Paper Table 4 — speculative-decoding performance, with REAL models.

Trains a small LM (reduced config), distills HAT's adapter Λ (Eq. 4) and
trains real Medusa heads, then serves single-device workloads through the
simulator with the RealBackend: every draft/verify round runs actual JAX
models, so accept lengths and the trained-parameter counts are measured,
not sampled.  Speedup is decode-rate vs the U-shape baseline (accept=1.00),
with ONE device collaborating with the cloud (paper §4.3)."""
from __future__ import annotations

import numpy as np

from common import emit, n_requests

ARCH = "internlm2-1.8b"


def _setup():
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core import init_adapter, make_distill_step, split_model
    from repro.data import markov_corpus, token_batches
    from repro.models import Model
    from repro.serving import init_medusa, medusa_loss
    from repro.training import AdamW, train_loop

    cfg = get_config(ARCH).reduced()
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(rng, cfg.vocab_size, 30_000)
    params, _ = train_loop(
        model, params, AdamW(lr=3e-3),
        token_batches(rng, corpus, 8, 48), max_steps=80, log_every=0,
    )
    split = split_model(cfg, params)

    # --- HAT adapter: knowledge distillation (Eq. 4) ------------------------
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    opt = AdamW(lr=1e-3)
    dstep = make_distill_step(split, model, params, opt)
    ost = opt.init(adapter)
    for i, b in zip(range(100), token_batches(rng, corpus, 8, 48)):
        adapter, ost, dmetrics = dstep(adapter, ost, jnp.asarray(b["tokens"][:, :48]))

    # --- Medusa heads: CE to t+1+i (real U-Medusa baseline) ----------------
    medusa, _ = init_medusa(cfg, jax.random.PRNGKey(8))
    mopt = AdamW(lr=1e-3)
    most = mopt.init(medusa)

    def mstep(mp, mo, toks):
        deep, _, _ = model.apply(params, toks, return_hidden=True)
        deep = jax.lax.stop_gradient(deep)
        loss, grads = jax.value_and_grad(medusa_loss)(mp, deep, toks)
        ups, mo = mopt.update(grads, mo, mp)
        return jax.tree.map(lambda a, u: a + u, mp, ups), mo, loss

    mstep = jax.jit(mstep)
    for i, b in zip(range(100), token_batches(rng, corpus, 8, 48)):
        medusa, most, mloss = mstep(medusa, most, jnp.asarray(b["tokens"][:, :48]))

    return cfg, model, params, split, adapter, medusa, corpus, float(dmetrics["agree"])


def main(quick: bool = True) -> None:
    import jax

    from repro.configs import get_config
    from repro.core import adapter_param_count
    from repro.data import RequestSpec
    from repro.serving import RealBackend, medusa_param_count, run_fleet

    cfg, model, params, split, adapter, medusa, corpus, agree = _setup()
    emit("table4.adapter_agreement", agree * 1e6, f"top1_agree={agree:.3f}")

    n_req = n_requests(3, 12)
    gen = 20

    def reqs():
        out = []
        for i in range(n_req):
            start = 100 * i % (len(corpus) - 80)
            out.append(RequestSpec(
                req_id=i, device_id=0, arrival_s=3.0 * i, prompt_len=24,
                max_new_tokens=gen,
                prompt=corpus[start : start + 24].astype(np.int32),
            ))
        return out

    results = {}
    for fw in ("u-shape", "u-medusa", "hat"):
        backend = RealBackend(
            split,
            adapter_params=adapter if fw == "hat" else None,
            medusa_params=medusa if fw == "u-medusa" else None,
            max_len=256,
        )
        m = run_fleet(fw, reqs(), rng=np.random.default_rng(3),
                      hidden_bytes=cfg.d_model * 2, backend=backend,
                      n_devices=1)
        s = m.summary()
        results[fw] = s
    base_tbt = results["u-shape"]["tbt_mean_ms"]
    for fw, s in results.items():
        n_train = {"u-shape": 0, "hat": adapter_param_count(cfg),
                   "u-medusa": medusa_param_count(cfg)}[fw]
        emit(
            f"table4.{fw}",
            s["tbt_mean_ms"] * 1e3,
            f"accept={s['accept_length']:.2f};"
            f"speedup_x={base_tbt / s['tbt_mean_ms']:.2f};"
            f"trained_params={n_train}",
        )


if __name__ == "__main__":
    main()
