"""Paper Fig. 1 — preliminary experiments.

(a) per-framework delay decomposition for a 128-token prompt,
(b) U-shaped TTFT vs prompt length (comm dominates, ~linear),
(c) in-cloud computation delay vs prompt length batched with 9 decodes,
(d) chunking trade-off: total compute delay reduction vs TTFT growth.
"""
from __future__ import annotations

import numpy as np

from common import emit
from repro.core.chunking import chunk_prompt
from repro.data import RequestSpec
from repro.serving import CloudDelayModel, run_fleet


def _single_request(framework: str, plen: int, pipeline_len: int = 4):
    reqs = [RequestSpec(req_id=0, device_id=0, arrival_s=0.0,
                        prompt_len=plen, max_new_tokens=16)]
    m = run_fleet(framework, reqs, rng=np.random.default_rng(7),
                  pipeline_len=pipeline_len)
    r = m.requests[0]
    return r.ttft_s * 1e3, (r.tbt_s or 0.0) * 1e3


def main(quick: bool = True) -> None:
    # (a) frameworks at 128-token prompt
    for fw in ("u-shape", "u-sarathi", "u-medusa", "hat"):
        ttft, tbt = _single_request(fw, 128)
        emit(f"fig1a.{fw}.ttft_ms", ttft * 1e3, f"tbt_ms={tbt:.1f}")

    # (b) U-shape TTFT vs prompt length — linear comm growth
    base = None
    for plen in (128, 256, 512, 1024, 2048):
        ttft, _ = _single_request("u-shape", plen)
        base = base or ttft
        emit(f"fig1b.u-shape.ttft_ms.p{plen}", ttft * 1e3,
             f"x{ttft / base:.2f}_vs_128")

    # (c) in-cloud computation delay vs prefill length batched with 9 decodes
    cloud = CloudDelayModel(pipeline_len=1)
    d1 = cloud.delay(1 + 9)
    for plen in (1, 32, 128, 512, 1024, 2048):
        d = cloud.delay(plen + 9)
        emit(f"fig1c.cloud_delay_ms.p{plen}", d * 1e6,
             f"+{(d / d1 - 1) * 100:.1f}%_vs_1tok")

    # (d) chunking a 2k prompt: total-compute reduction vs TTFT growth
    cloud = CloudDelayModel(pipeline_len=1)
    plen, n_decode = 2048, 9
    bulk_compute = cloud.delay(plen + n_decode) + 63 * cloud.delay(n_decode)
    bulk_ttft = cloud.delay(plen + n_decode)
    for chunk in (32, 128, 256, 512, 2048):
        chunks = chunk_prompt(plen, chunk)
        total = sum(cloud.delay(c + n_decode) for c in chunks)
        total += max(0, 64 - len(chunks)) * cloud.delay(n_decode)
        ttft = sum(cloud.delay(c + n_decode) for c in chunks)
        emit(
            f"fig1d.chunk{chunk}.ttft_ms", ttft * 1e6,
            f"total_compute_delta_ms={(bulk_compute - total) * 1e3:+.1f};"
            f"ttft_x={ttft / bulk_ttft:.2f}",
        )


if __name__ == "__main__":
    main()
