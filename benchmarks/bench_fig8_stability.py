"""Paper Fig. 8 — per-GPU (pipeline-stage) computation delay mean ± std.

Chunking (HAT, U-Sarathi) keeps the cloud's per-stage delay stable; the
naive-batched baselines show long-prompt interference spikes."""
from __future__ import annotations

import numpy as np

from common import emit, fleet_run, n_requests
from repro.data import CNN_DM, SPECBENCH


def main(quick: bool = True) -> None:
    n = n_requests(200, 600)
    for spec, hidden, rate in ((SPECBENCH, 4096 * 2, 6), (CNN_DM, 5120 * 2, 4)):
        for fw in ("u-shape", "u-sarathi", "u-medusa", "hat"):
            m = fleet_run(fw, spec, rate=rate, n=n, hidden_bytes=hidden)
            d = np.asarray(m.cloud_step_delays_s) * 1e3
            emit(
                f"fig8.{spec.name}.{fw}.cloud_delay_ms",
                float(d.mean() * 1e3),
                f"std_ms={d.std():.2f};p99_ms={np.percentile(d, 99):.1f}",
            )


if __name__ == "__main__":
    main()
