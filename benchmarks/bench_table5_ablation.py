"""Paper Table 5 — effect of key strategies (SD / PC / PD ablation).

Rows: (SD, PC, PD) on/off combinations over the U-shaped substrate —
exactly the paper's grid, on both workloads."""
from __future__ import annotations

from common import emit, fleet_run, n_requests
from repro.data import CNN_DM, SPECBENCH

ROWS = [
    ("---", dict(sd=None, pc=None, pd=False, max_batch_tokens=None)),
    ("-P-", dict(sd=None, pc="device", pd=False)),
    ("S--", dict(sd="draft", pc=None, pd=False, max_batch_tokens=None)),
    ("S-D", dict(sd="draft", pc=None, pd=True, max_batch_tokens=None)),
    ("SP-", dict(sd="draft", pc="device", pd=False)),
    ("SPD", dict(sd="draft", pc="device", pd=True)),
]


def main(quick: bool = True) -> None:
    n = n_requests(200, 600)
    for spec, hidden, rate in ((SPECBENCH, 4096 * 2, 6), (CNN_DM, 5120 * 2, 4)):
        base = None
        for label, overrides in ROWS:
            m = fleet_run("hat", spec, rate=rate, n=n, hidden_bytes=hidden,
                          overrides=overrides)
            s = m.summary()
            base = base or s
            emit(
                f"table5.{spec.name}.{label}.ttft_ms",
                s["ttft_mean_ms"] * 1e3,
                f"tbt_ms={s['tbt_mean_ms']:.1f};"
                f"ttft_vs_base{(s['ttft_mean_ms']/base['ttft_mean_ms']-1)*100:+.0f}%;"
                f"tbt_vs_base{(s['tbt_mean_ms']/base['tbt_mean_ms']-1)*100:+.0f}%",
            )


if __name__ == "__main__":
    main()
