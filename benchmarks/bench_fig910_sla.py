"""Paper Figs. 9–10 — SLA compliance rates.

prefill SLA: delay budget per 128 prompt tokens; decode SLA: delay budget
per 10 generated tokens.  Pipeline length 1 (paper §4.2)."""
from __future__ import annotations

from common import emit, fleet_run, n_requests
from repro.data import CNN_DM, SPECBENCH


def main(quick: bool = True) -> None:
    n = n_requests(150, 500)
    for spec, hidden, rate in ((SPECBENCH, 4096 * 2, 4), (CNN_DM, 5120 * 2, 2)):
        runs = {
            fw: fleet_run(fw, spec, rate=rate, n=n, hidden_bytes=hidden,
                          pipeline_len=1)
            for fw in ("u-shape", "u-sarathi", "u-medusa", "hat")
        }
        for sla_ms in (200, 350, 500, 800):
            for fw, m in runs.items():
                r = m.prefill_sla_rate(sla_ms / 1e3)
                emit(f"fig910.{spec.name}.prefill_sla{sla_ms}.{fw}",
                     r * 1e6, f"rate={r:.3f}")
        for sla_ms in (400, 600, 900, 1400):
            for fw, m in runs.items():
                r = m.decode_sla_rate(sla_ms / 1e3)
                emit(f"fig910.{spec.name}.decode_sla{sla_ms}.{fw}",
                     r * 1e6, f"rate={r:.3f}")


if __name__ == "__main__":
    main()
