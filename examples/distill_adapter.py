"""Train the HAT adapter Λ for any assigned architecture family and report
the Table-4 quantities: trained parameters and measured accept length.

    PYTHONPATH=src python examples/distill_adapter.py --arch gemma3-12b --steps 120
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    adapter_param_count,
    init_adapter,
    make_distill_step,
    split_model,
)
from repro.data import RequestSpec, markov_corpus, token_batches
from repro.models import Model
from repro.serving import RealBackend, medusa_param_count, run_fleet
from repro.training import AdamW, save_checkpoint, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--teacher-steps", type=int, default=80)
    ap.add_argument("--eta", type=float, default=0.6)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(rng, cfg.vocab_size, 30_000)
    print(f"[1/3] teacher: {cfg.name}")
    params, res = train_loop(model, params, AdamW(lr=3e-3),
                             token_batches(rng, corpus, 8, 48),
                             max_steps=args.teacher_steps, log_every=0)
    print(f"      loss {res.losses[0]:.2f} -> {res.losses[-1]:.2f}")

    split = split_model(cfg, params)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    opt = AdamW(lr=1e-3)
    dstep = make_distill_step(split, model, params, opt)
    ost = opt.init(adapter)
    print(f"[2/3] distilling Λ ({args.steps} steps, Eq. 4)")
    for i, b in zip(range(args.steps), token_batches(rng, corpus, 8, 48)):
        adapter, ost, m = dstep(adapter, ost, jnp.asarray(b["tokens"][:, :48]))
        if i % max(args.steps // 5, 1) == 0:
            print(f"      step {i:4d} loss {float(m['loss']):.3f} "
                  f"agree {float(m['agree']):.2f}")

    full_cfg = get_config(args.arch)
    print(f"      adapter params at FULL config: "
          f"{adapter_param_count(full_cfg)/1e6:.0f}M "
          f"(U-Medusa heads would train {medusa_param_count(full_cfg)/1e6:.0f}M)")

    print("[3/3] measuring accept length with real speculative serving")
    backend = RealBackend(split, adapter_params=adapter, max_len=256, eta=args.eta)
    reqs = [RequestSpec(req_id=i, device_id=0, arrival_s=2.0 * i,
                        prompt_len=32, max_new_tokens=24,
                        prompt=corpus[200 * i:200 * i + 32].astype(np.int32))
            for i in range(3)]
    metrics = run_fleet("hat", reqs, rng=np.random.default_rng(3),
                        hidden_bytes=cfg.d_model * 2, backend=backend,
                        n_devices=1)
    s = metrics.summary()
    print(f"      accept length = {s['accept_length']:.2f} "
          f"(U-shape baseline = 1.00);  TBT = {s['tbt_mean_ms']:.1f} ms")
    if args.checkpoint:
        save_checkpoint(args.checkpoint, adapter, step=args.steps)
        print("      adapter checkpoint ->", args.checkpoint)


if __name__ == "__main__":
    main()
