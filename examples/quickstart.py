"""Quickstart: the whole HAT pipeline on one small model, in one file.

    PYTHONPATH=src python examples/quickstart.py

1. trains a small LM on a synthetic corpus,
2. splits it U-shaped (device shallow layers + head / cloud middle),
3. distills the adapter Λ (Eq. 4),
4. runs one full speculative round — draft (Eq. 5 threshold), U-shaped
   verification, greedy acceptance — and checks losslessness vs plain
   greedy decoding.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (
    DraftModel,
    accept_greedy_rows,
    draft_until_threshold,
    init_adapter,
    make_distill_step,
    split_model,
)
from repro.data import markov_corpus, token_batches
from repro.models import Model
from repro.training import AdamW, train_loop


def main():
    # 1. a small LM (reduced InternLM2 family config)
    cfg = get_config("internlm2-1.8b").reduced()
    model = Model(cfg)
    rng = np.random.default_rng(0)
    params = model.init(jax.random.PRNGKey(0))
    corpus = markov_corpus(rng, cfg.vocab_size, 20_000)
    print(f"config: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")
    params, res = train_loop(model, params, AdamW(lr=3e-3),
                             token_batches(rng, corpus, 8, 32),
                             max_steps=60, log_every=20)

    # 2. U-shaped split: input (m shallow layers) / middle (cloud) / head
    split = split_model(cfg, params)
    print(f"split: device holds layers [0,{split.m}) + head; "
          f"cloud holds layers [{split.m},{cfg.n_layers})")

    # 3. adapter distillation (SmoothL1 + 0.1*CE on pre-head states, Eq. 4)
    adapter, _ = init_adapter(cfg, jax.random.PRNGKey(7))
    opt = AdamW(lr=1e-3)
    dstep = make_distill_step(split, model, params, opt)
    ost = opt.init(adapter)
    for i, b in zip(range(80), token_batches(rng, corpus, 8, 32)):
        adapter, ost, m = dstep(adapter, ost, jnp.asarray(b["tokens"][:, :32]))
    print(f"adapter distilled: top-1 agreement with teacher = {float(m['agree']):.2f}")

    # 4. one speculative round, end to end
    draft_model = DraftModel(split, adapter)
    prompt = jnp.asarray(corpus[:24], jnp.int32)[None]
    dcache = draft_model.init_cache(1, 128)
    lg, dcache, _ = draft_model.forward(prompt, cache=dcache, offset=0)

    in_cache = split.input_model.init_cache(split.input_params, 1, 128)
    mid_cache = split.middle_model.init_cache(split.middle_params, 1, 128)
    sh, in_cache, _ = split.input_model.apply(
        split.input_params, prompt, cache=in_cache, offset=0, return_hidden=True)
    dp, mid_cache, _ = split.middle_model.apply(
        split.middle_params, None, inputs_embeds=sh, cache=mid_cache,
        offset=0, return_hidden=True)
    first = int(split.head_logits(dp)[0, -1].argmax())
    print(f"first token: {first}")

    result, dcache, off = draft_until_threshold(
        draft_model, dcache, jnp.asarray([[first]], jnp.int32), 24,
        eta=0.6, max_draft=6)
    print(f"drafted {result.steps} tokens: {result.tokens.tolist()} "
          f"(probs {np.round(result.probs, 2).tolist()})")

    ver = jnp.asarray([[first, *result.tokens]], jnp.int32)
    sh, in_cache, _ = split.input_model.apply(
        split.input_params, ver, cache=in_cache, offset=24, return_hidden=True)
    dp, mid_cache, _ = split.middle_model.apply(
        split.middle_params, None, inputs_embeds=sh, cache=mid_cache,
        offset=24, return_hidden=True)
    logits = np.asarray(split.head_logits(dp)[0])
    n, bonus = accept_greedy_rows(result.tokens, logits)
    print(f"verification: accepted {n}/{result.steps} drafts + bonus {bonus} "
          f"-> {n + 1} tokens for one round trip")

    # losslessness check against plain greedy decoding
    cache = model.init_cache(params, 1, 128)
    lg, cache, _ = model.apply(params, prompt, cache=cache, offset=0)
    ref = [int(lg[0, -1].argmax())]
    o = 24
    for _ in range(n + 1):
        lg, cache, _ = model.apply(params, jnp.asarray([[ref[-1]]], jnp.int32),
                                   cache=cache, offset=o)
        o += 1
        ref.append(int(lg[0, -1].argmax()))
    emitted = [first, *result.tokens[:n], bonus]
    assert emitted == ref[: len(emitted)], (emitted, ref)
    print("losslessness: speculative output == greedy output ✓")


if __name__ == "__main__":
    main()
